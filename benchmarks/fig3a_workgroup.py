"""Paper Fig. 3(a): thread-level (static) vs workgroup-level (dynamic)
load balancing.

In the lock-step TPU formulation, "thread-level" pre-assigns every lane
a fixed photon quota (idle lanes = divergence waste); "workgroup-level"
regenerates photons from the shared counter.  We report throughput and
the lane-utilization advantage (steps executed per photon).
"""

from __future__ import annotations

import json

import jax

from benchmarks.common import get_bench, time_sim
from repro.core import simulator as S
from repro.core.volume import SimConfig


def run(n_photons=30_000, lanes=4096, size=40, quick=False):
    if quick:
        n_photons, size = 15_000, 30
    vol, phys = get_bench("B1", size)
    cfg = SimConfig(do_reflect=phys["do_reflect"])
    out = {}
    for mode in ("static", "dynamic"):
        t = time_sim(vol, cfg, n_photons, lanes, mode=mode)
        fn = S.make_simulator(vol, cfg, lanes, mode)
        res = fn(vol.labels.reshape(-1), vol.media, n_photons, 11)
        jax.block_until_ready(res)
        out[mode] = {
            "photons_per_ms": n_photons / t / 1e3,
            "loop_steps": int(res.steps),
        }
        print(f"[fig3a] {mode}: {out[mode]}", flush=True)
    speedup = out["dynamic"]["photons_per_ms"] / out["static"]["photons_per_ms"]
    out["dynamic_speedup"] = speedup
    print(f"[fig3a] dynamic/static speedup: {speedup:.3f}x "
          f"(paper: 1.01x NVIDIA, 1.13x AMD)", flush=True)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
