"""Resilient-pool overhead benchmark (DESIGN.md §resilience).

The PR-7 `DevicePool` adds a robustness layer to chunked execution —
host-side harvest + `validate_chunk` merge guards, chunk-id-order
frontier merging, retry/deadline bookkeeping.  The acceptance bar is
that all of it costs **< 10% wall time when nothing fails**: this
benchmark times the same fault-free chunked workload through

  * a faithful replica of the pre-PR greedy async scheduler (dispatch
    to every device, merge in completion order, no validation) — the
    committed baseline the gate compares against, kept here so the
    pre-PR loop stays measurable after `ChunkScheduler` moved onto the
    pool; and
  * the resilient `ChunkScheduler`/`DevicePool` path with validation
    on (the default),

and writes ``BENCH_resilience.json`` at the repo root with
``resilience.pool_overhead_frac`` = (t_pool - t_baseline)/t_baseline —
gated by ``check_regression.py`` like every other ``_overhead_frac``
key (limit +0.10 points), alongside the gated throughput keys.  A
seeded chaos row (faults + NaN corruption + delays) is recorded for
trend-watching but not gated: its wall time is dominated by the
injected delays, not scheduler work.

  PYTHONPATH=src python -m benchmarks.resilience [--quick]
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import SCHEMA_VERSION
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler
from repro.core.rng import split_id64
from repro.core.simulator import build_sim_fn
from repro.resilience import FaultInjector, RetryPolicy

REPO_ROOT = Path(__file__).resolve().parent.parent


def _make_baseline(vol, cfg, lanes):
    """The pre-PR-7 ChunkScheduler loop: greedy async dispatch, merge in
    completion order, chunks lost on error, no validation/deadlines.
    Returns a ``run_once(n_photons, chunk_size, seed)`` closure with the
    executor compiled once, like the real scheduler's per-source cache.
    """
    fn = jax.jit(build_sim_fn(vol.shape, vol.unitinmm, cfg, lanes,
                              "dynamic", None, "jnp"))
    devices = jax.devices()
    labels = vol.labels.reshape(-1)

    def run_once(n_photons, chunk_size, seed):
        chunks = [(s, min(chunk_size, n_photons - s))
                  for s in range(0, n_photons, chunk_size)]
        queue = list(reversed(chunks))
        inflight = {}

        def dispatch(dev):
            start, count = queue.pop()
            lo, hi = split_id64(start)
            inflight[dev] = (count, fn(jax.device_put(labels, dev),
                                       jax.device_put(vol.media, dev),
                                       count, seed, lo, hi))

        energy = None
        n_launched = 0
        for dev in devices:
            if queue:
                dispatch(dev)
        while inflight:
            progressed = False
            for dev in list(inflight):
                count, res = inflight[dev]
                if res.energy.is_ready():
                    del inflight[dev]
                    e = np.asarray(res.energy)
                    energy = e if energy is None else energy + e
                    n_launched += int(res.n_launched)
                    progressed = True
                    if queue:
                        dispatch(dev)
            if not progressed:
                time.sleep(0.001)
        assert n_launched == n_photons
        return energy

    return run_once


def run(quick=False,
        out_path: Path | str = REPO_ROOT / "BENCH_resilience.json"):
    size = 20 if quick else 40
    vol = V.benchmark_b1((size, size, size))
    cfg = V.SimConfig(do_reflect=False, steps_per_round=4)
    n_photons, chunk, lanes = ((6_000, 750, 512) if quick
                               else (40_000, 5_000, 2048))
    seed = 11
    # the overhead fraction is a ratio of two short wall times and feeds
    # the CI regression gate — interleaved pairs + median, like
    # benchmarks/replay.py, so one contended sample can't swing it
    repeats = 5 if quick else 3

    sched = ChunkScheduler(vol, cfg, n_lanes=lanes)  # validate=True default
    baseline = _make_baseline(vol, cfg, lanes)

    # warm both paths (compile + device_put caches)
    baseline(n_photons, chunk, seed)
    sched.run(n_photons, chunk, seed=seed)

    best = [float("inf"), float("inf")]
    fracs = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        baseline(n_photons, chunk, seed)
        t_base = time.perf_counter() - t0
        t0 = time.perf_counter()
        sched.run(n_photons, chunk, seed=seed)
        t_pool = time.perf_counter() - t0
        best[0] = min(best[0], t_base)
        best[1] = min(best[1], t_pool)
        fracs.append((t_pool - t_base) / t_base)
    t_base, t_pool = best
    overhead = float(np.median(fracs))

    # chaos trend row (not gated): seeded faults over the same workload
    injector = FaultInjector(seed=5, p_fail=0.15, p_nan=0.1, p_delay=0.15,
                             delay_s=0.02)
    chaos_sched = ChunkScheduler(vol, cfg, n_lanes=lanes,
                                 fault_injector=injector,
                                 retry_policy=RetryPolicy(max_attempts=10))
    t0 = time.perf_counter()
    chaos_sched.run(n_photons, chunk, seed=seed)
    t_chaos = time.perf_counter() - t0
    chaos = chaos_sched.last_report.counters()

    results = {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "bench": "B1",
            "size": size,
            "quick": quick,
            "steps_per_round": cfg.steps_per_round,
            "n_photons": n_photons,
            "chunk_size": chunk,
            "lanes": lanes,
            "devices": len(jax.devices()),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "machine": platform.machine(),
        },
        "resilience": {
            "photons_per_s_baseline": n_photons / t_base,
            "photons_per_s": n_photons / t_pool,
            "pool_overhead_frac": overhead,
        },
        "chaos": {
            # wall time here is injected-delay-dominated: trend only
            "wall_s_cold": t_chaos,
            **{k: v for k, v in chaos.items()
               if k in ("retries", "speculative", "validation_failures",
                        "dispatch_failures", "injected_faults",
                        "quarantine_events")},
        },
    }
    print(f"baseline scheduler : {n_photons/t_base/1e3:8.2f} photons/ms "
          f"({t_base:.3f}s)")
    print(f"resilient pool     : {n_photons/t_pool/1e3:8.2f} photons/ms "
          f"({t_pool:.3f}s)  fault-free overhead "
          f"{100*overhead:+.1f}%")
    print(f"chaos drill        : {t_chaos:.3f}s with {chaos['retries']} "
          f"retries, {chaos['validation_failures']} rejected merges, "
          f"{chaos['injected_faults']} injected faults", flush=True)

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
