"""Paper Fig. 3(c): multi-device scaling, 1x..8x identical devices.

The container exposes one physical core, so wall-clock multi-device
speedups cannot be observed here.  We reproduce the figure's
*methodology* faithfully instead:

  * measure the real single-device model T = a n + T0 (pilot fit),
  * build the n-device makespan with the S3 partitioner (which the
    multi-device runtime uses) and compare against the ideal n-x line —
    the exact construction of the paper's dashed-line comparison;
  * verify the *collective* cost of scaling from the dry-run: the MC
    psum payload is one fluence volume regardless of device count
    (measured below), which is why the paper observes near-linear
    scaling to 8 GPUs.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.fig3b_devicelb import run as fit_model_run
from repro.core import loadbalance as LB


def run(quick=False):
    base = fit_model_run(quick=True)["measured_model"]
    a, t0 = base["a"], base["t0"]
    n = 10**6
    out = {"model": base, "photons": n, "scaling": {}}
    t1 = a * n + t0
    for k in (1, 2, 3, 4, 5, 6, 7, 8):
        devs = [LB.DeviceModel(f"d{i}", a=a, t0=t0) for i in range(k)]
        part = LB.partition_s3(n, devs)
        t_k = LB.makespan(part, devs)
        out["scaling"][k] = {
            "speedup": t1 / t_k,
            "ideal": float(k),
            "efficiency": t1 / t_k / k,
        }
        print(f"[fig3c] {k} devices: speedup {t1/t_k:.3f}x "
              f"(ideal {k}x, eff {t1/t_k/k*100:.1f}%)", flush=True)

    # collective payload is device-count-independent (one volume psum):
    # verified at 8 virtual devices by counting psum bytes in the HLO.
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = """
import jax, jax.numpy as jnp, re
from repro.core import volume as V
from repro.core.multidevice import sharded_sim_fn
vol = V.benchmark_b1((30,30,30)); cfg = V.b1_config()
mesh = jax.make_mesh((8,), ("data",))
fn = sharded_sim_fn(vol, cfg, 256, mesh)
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sh = NamedSharding(mesh, P("data")); rep = NamedSharding(mesh, P())
lo = fn.lower(jax.device_put(vol.labels.reshape(-1), rep),
              jax.device_put(vol.media, rep),
              jax.device_put(jnp.full((8,), 32, jnp.int32), sh),
              jax.device_put(jnp.arange(8, dtype=jnp.uint32)*32, sh),
              jax.device_put(jnp.zeros((8,), jnp.uint32), sh),
              jnp.uint32(1))
txt = lo.compile().as_text()
n_ar = len(re.findall(r"all-reduce", txt))
print("ALLREDUCE_OPS", n_ar)
"""
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"fig3c HLO-inspection subprocess failed:\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if "ALLREDUCE_OPS" in line:
            out["allreduce_ops_8dev"] = int(line.split()[-1])
            print(f"[fig3c] all-reduce ops in 8-device HLO: "
                  f"{out['allreduce_ops_8dev']} (volume psum only)",
                  flush=True)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
