"""Aggregate the dry-run reports into the §Roofline table.

Reads reports/dryrun/*.json (produced by repro.launch.dryrun) and emits
a markdown table with the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs and the roofline fraction per cell.
"""

from __future__ import annotations

import glob
import json
import os


def load(report_dir="reports/dryrun"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "bottleneck | useful/HLO flops | roofline frac | GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['useful_flops_frac']:.2f} | "
            f"{r['roofline_frac']:.3f} | "
            f"{r['bytes_per_device']['peak']/2**30:.1f} |")
    return "\n".join(lines)


def summary(recs) -> dict:
    out = {"n_cells": len(recs)}
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in recs if r["mesh"] == mesh]
        if not rows:
            continue
        worst = min(rows, key=lambda r: r["roofline_frac"])
        coll = max(rows, key=lambda r: (r["collective_s"]
                                        / max(max(r["compute_s"],
                                                  r["memory_s"]), 1e-12)))
        out[mesh] = {
            "cells": len(rows),
            "bottlenecks": {
                b: sum(1 for r in rows if r["bottleneck"] == b)
                for b in ("compute", "memory", "collective")
            },
            "worst_roofline": (worst["arch"], worst["shape"],
                               round(worst["roofline_frac"], 4)),
            "most_collective_bound": (coll["arch"], coll["shape"]),
        }
    return out


def run(quick=False, report_dir="reports/dryrun"):
    recs = load(report_dir)
    if not recs:
        print("[roofline] no dry-run reports found — run "
              "`python -m repro.launch.dryrun --all` first", flush=True)
        return {}
    s = summary(recs)
    print(f"[roofline] {s['n_cells']} cell reports", flush=True)
    for mesh, info in s.items():
        if mesh == "n_cells":
            continue
        print(f"[roofline] {mesh}: {info}", flush=True)
    print(table(recs, "16x16"))
    return s


if __name__ == "__main__":
    run()
