"""Per-source-type throughput sweep (regeneration-mode launch cost).

Every dead lane re-samples its source each lock-step iteration
(simulator regeneration), so launch cost rides the hot loop: a source
drawing more launch uniforms or touching a pattern table pays per
regeneration, not per run.  This sweep measures photons/ms per source
type against the pencil baseline, in both workload modes.

  PYTHONPATH=src python -m benchmarks.sources [--quick]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import get_bench, time_sim
from repro import sources as SRC
from repro.core.volume import SimConfig


def run(n_photons=30_000, lanes=4096, size=40, quick=False,
        modes=("dynamic", "static")):
    if quick:
        n_photons, size, lanes = 10_000, 30, 2048
    vol, phys = get_bench("B1", size)
    cfg = SimConfig(do_reflect=phys["do_reflect"])
    out = {}
    for mode in modes:
        per_source = {}
        for name, src in SRC.demo_menu(size).items():
            t = time_sim(vol, cfg, n_photons, lanes, mode=mode, source=src)
            per_source[name] = n_photons / t / 1e3
            print(f"[sources] {mode:7s} {name:18s} "
                  f"{per_source[name]:8.2f} photons/ms", flush=True)
        base = per_source["pencil"]
        out[mode] = {
            "photons_per_ms": per_source,
            "relative_to_pencil": {k: v / base for k, v in per_source.items()},
        }
        worst = min(out[mode]["relative_to_pencil"].values())
        print(f"[sources] {mode}: worst source at {worst * 100:.0f}% of "
              f"pencil throughput", flush=True)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(json.dumps(run(quick=args.quick), indent=2))
