"""Time-gated recording overhead: photons/s across gate counts.

Measures the cost of widening the fluence accumulator from the CW
``(nvox,)`` grid to the gate-major ``(nvox * ntg,)`` time-resolved grid
(DESIGN.md §time-resolved) for both round executors on the pencil-beam
B1 benchmark, and writes a machine-readable ``BENCH_timegates.json`` at
the repo root: the gate-count overhead trajectory tracked per PR by CI
alongside ``BENCH_fused.json``.

  PYTHONPATH=src python -m benchmarks.timegates [--quick] [--engines jnp]

Every row also cross-checks physics: the gate-summed fluence of the
ntg>1 run must match the CW run of the same photon set (the runs
simulate the identical id-keyed photon set, so only fp accumulation
order differs).  The full (non-quick) sweep runs the acceptance-size
60^3 B1 volume up to ntg=32.

Note on the Pallas numbers off-TPU: the kernel auto-detects the backend
and runs under the Pallas *interpreter* on CPU/GPU (correctness rig,
not a perf path), so off-TPU the jnp engine rows are the meaningful
overhead trajectory.  ``meta.interpreted_pallas`` records which mode
ran.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import SCHEMA_VERSION, get_bench, time_sim
from repro.core import simulator as S
from repro.core.volume import SimConfig
from repro.kernels.photon_step.photon_step import default_interpret

REPO_ROOT = Path(__file__).resolve().parent.parent
GATES = (1, 4, 16, 32)


def run(quick=False, engines=("jnp", "pallas"), gates=GATES,
        out_path: Path | str = REPO_ROOT / "BENCH_timegates.json"):
    size = 24 if quick else 60
    vol, phys = get_bench("B1", size)
    cfg0 = SimConfig(do_reflect=phys["do_reflect"], steps_per_round=4)
    interpreted = default_interpret()
    jnp_load = (6_000, 1024) if quick else (40_000, 4096)
    workload = {
        "jnp": jnp_load,
        "pallas": (1_500, 512) if interpreted else jnp_load,
    }

    results: dict = {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "bench": "B1-pencil",
            "size": size,
            "quick": quick,
            "steps_per_round": cfg0.steps_per_round,
            "backend": jax.default_backend(),
            "interpreted_pallas": interpreted,
            "jax": jax.__version__,
            "machine": platform.machine(),
            "gates": list(gates),
        },
        "engines": {},
    }
    for engine in engines:
        n_photons, lanes = workload[engine]
        rows = {}
        cw_energy = None
        for ntg in gates:
            cfg = dataclasses.replace(cfg0, n_time_gates=int(ntg))
            secs = time_sim(vol, cfg, n_photons, lanes, engine=engine,
                            repeats=2 if quick else 3)
            # physics cross-check: gate-summed fluence matches CW
            res = S.simulate(vol, cfg, n_photons, lanes, seed=11,
                             engine=engine)
            energy = np.asarray(res.energy)
            gate_summed = energy if ntg == 1 else energy.sum(axis=-1)
            if cw_energy is None:
                cw_energy = gate_summed
            max_rel = float(
                np.abs(gate_summed - cw_energy).max()
                / max(cw_energy.max(), 1e-20))
            assert max_rel < 1e-3, (engine, ntg, max_rel)
            rows[str(ntg)] = {
                "seconds": secs,
                "photons_per_s": n_photons / secs,
                "gate_sum_max_rel_err_vs_cw": max_rel,
            }
            print(f"[timegates] {engine:6s} ntg={ntg:3d}: "
                  f"{n_photons / secs / 1e3:8.2f} photons/ms "
                  f"({secs * 1e3:.1f} ms, gate-sum err {max_rel:.1e})",
                  flush=True)
        base = rows[str(min(int(g) for g in rows))]["photons_per_s"]
        worst = min(rows.values(), key=lambda r: r["photons_per_s"])
        rows_meta = {
            "n_photons": n_photons,
            "lanes": lanes,
            "max_overhead_vs_cw": base / worst["photons_per_s"],
        }
        print(f"[timegates] {engine}: worst gate-count overhead "
              f"{rows_meta['max_overhead_vs_cw']:.3f}x vs CW", flush=True)
        results["engines"][engine] = {"rows": rows, **rows_meta}

    out_path = Path(out_path)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"[timegates] wrote {out_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced photon counts / domain (CI smoke)")
    ap.add_argument("--engines", default="jnp,pallas",
                    help="comma-separated subset of {jnp,pallas}")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_timegates.json"))
    args = ap.parse_args(argv)
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip())
    for e in engines:
        if e not in S.ENGINES:
            ap.error(f"unknown engine {e!r}")
    run(quick=args.quick, engines=engines, out_path=args.out)


if __name__ == "__main__":
    main()
