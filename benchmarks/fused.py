"""Fused-round throughput: photons/s across K = steps_per_round.

Measures the DESIGN.md §rounds tradeoff — regeneration/flush
amortization vs masked-lane waste — for both round executors
(``engine="jnp"`` and ``engine="pallas"``) on the pencil-beam B1
benchmark, and writes a machine-readable ``BENCH_fused.json`` at the
repo root: the perf-trajectory record tracked per PR by CI.

  PYTHONPATH=src python -m benchmarks.fused [--quick] [--engines jnp]

Note on the Pallas numbers off-TPU: the kernel auto-detects the backend
and runs under the Pallas *interpreter* on CPU/GPU (correctness rig,
not a perf path), so off-TPU the jnp engine rows are the meaningful
throughput trajectory and the pallas rows only track interpreter
overhead.  ``meta.interpreted`` records which mode ran.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import SCHEMA_VERSION, get_bench, time_sim
from repro.core import simulator as S
from repro.core.volume import SimConfig
from repro.kernels.photon_step.photon_step import default_interpret

REPO_ROOT = Path(__file__).resolve().parent.parent
ROUNDS = (1, 4, 8, 16, 32)


def _time_stats_pair(vol, cfg, n_photons, lanes, engine, repeats, seed=11):
    """Median per-pair overhead fraction of ``collect_stats=True``.

    Times the stats-off and stats-on simulators as back-to-back
    interleaved pairs (same pattern as benchmarks/replay.py's recording
    overhead): the fraction feeds the CI regression gate, and a ratio of
    two independently best-of timings lets one contended sample swing it
    by tens of points, while the median of per-pair ratios drops
    contention spikes entirely.
    """
    fns = [S.make_simulator(vol, dataclasses.replace(cfg, collect_stats=c),
                            lanes, engine=engine)
           for c in (False, True)]
    args = (vol.labels.reshape(-1), vol.media, n_photons, seed)
    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile + warm
    fracs = []
    for _ in range(repeats):
        pair = []
        for fn in fns:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            pair.append(time.perf_counter() - t0)
        fracs.append((pair[1] - pair[0]) / pair[0])
    return float(np.median(fracs))


def run(quick=False, engines=("jnp", "pallas"), rounds=ROUNDS,
        out_path: Path | str = REPO_ROOT / "BENCH_fused.json"):
    vol, phys = get_bench("B1", 24 if quick else 40)
    cfg0 = SimConfig(do_reflect=phys["do_reflect"])
    # sizing: the pallas interpreter is orders of magnitude slower than
    # compiled XLA, so off-TPU it gets a reduced workload
    interpreted = default_interpret()
    jnp_load = (8_000, 1024) if quick else (40_000, 4096)
    workload = {
        "jnp": jnp_load,
        "pallas": (2_000, 512) if interpreted else jnp_load,
    }

    results: dict = {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "bench": "B1-pencil",
            "size": 24 if quick else 40,
            "quick": quick,
            "backend": jax.default_backend(),
            "interpreted_pallas": interpreted,
            "jax": jax.__version__,
            "machine": platform.machine(),
            "rounds": list(rounds),
        },
        "engines": {},
    }
    for engine in engines:
        n_photons, lanes = workload[engine]
        rows = {}
        for k in rounds:
            cfg = dataclasses.replace(cfg0, steps_per_round=int(k))
            secs = time_sim(vol, cfg, n_photons, lanes, engine=engine,
                            repeats=2 if quick else 3)
            rows[str(k)] = {
                "seconds": secs,
                "photons_per_s": n_photons / secs,
            }
            print(f"[fused] {engine:6s} K={k:2d}: "
                  f"{n_photons / secs / 1e3:8.2f} photons/ms "
                  f"({secs * 1e3:.1f} ms)", flush=True)
        # baseline for the speedup column: K=1 when swept, else smallest K
        base_k = "1" if "1" in rows else str(min(int(k) for k in rows))
        base = rows[base_k]["photons_per_s"]
        best_k = max(rows, key=lambda k: rows[k]["photons_per_s"])
        # telemetry budget (DESIGN.md §observability): collect_stats must
        # stay under ~10% at the production-relevant K; the gate enforces
        # growth on every *_overhead_frac leaf
        stats_overhead = _time_stats_pair(
            vol, dataclasses.replace(cfg0, steps_per_round=int(best_k)),
            n_photons, lanes, engine, repeats=5 if quick else 3)
        rows_meta = {
            "n_photons": n_photons,
            "lanes": lanes,
            "baseline_k": int(base_k),
            "best_k": int(best_k),
            "best_speedup_vs_k1": rows[best_k]["photons_per_s"] / base,
            "collect_stats_overhead_frac": stats_overhead,
        }
        print(f"[fused] {engine}: best K={best_k} "
              f"({rows_meta['best_speedup_vs_k1']:.3f}x vs K={base_k}), "
              f"collect_stats overhead {100 * stats_overhead:+.1f}%",
              flush=True)
        results["engines"][engine] = {"rows": rows, **rows_meta}

    out_path = Path(out_path)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"[fused] wrote {out_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced photon counts / domain (CI smoke)")
    ap.add_argument("--engines", default="jnp,pallas",
                    help="comma-separated subset of {jnp,pallas}")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_fused.json"))
    args = ap.parse_args(argv)
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip())
    for e in engines:
        if e not in S.ENGINES:
            ap.error(f"unknown engine {e!r}")
    run(quick=args.quick, engines=engines, out_path=args.out)


if __name__ == "__main__":
    main()
