"""Detected-photon recording overhead + replay Jacobian throughput.

Measures, on the B2 benchmark (the heterogeneous sphere the replay
Jacobian validation uses), the cost of the PR-4 replay machinery
(DESIGN.md §replay) and writes a machine-readable ``BENCH_replay.json``
at the repo root — the perf trajectory tracked per PR by CI alongside
``BENCH_fused.json`` / ``BENCH_timegates.json``:

  * forward overhead: photons/s of the detector-equipped forward run
    with the detected-photon id buffer off vs on, per round executor —
    the id buffer adds one prefix-sum + one tiny scatter per round, so
    the overhead should be small;
  * replay throughput: records/s of ``replay_jacobian`` over the
    recorded ids (two transport passes + the (nvox, n_det) scatter),
    measured **per round executor** (``engine="jnp"`` and
    ``engine="pallas"``, DESIGN.md §replay);
  * physics cross-check: the replay Jacobian's per-medium row sums must
    match the forward run's ``det_ppath`` (the §replay identity),
    every replayed photon must land in its recorded detector, and the
    per-record Pallas outputs must be bit-identical to the jnp engine.

  PYTHONPATH=src python -m benchmarks.replay [--quick] [--engines jnp]

Note on the Pallas numbers off-TPU: the kernel auto-detects the backend
and runs under the Pallas *interpreter* on CPU/GPU (correctness rig,
not a perf path), so off-TPU the jnp rows are the meaningful overhead
trajectory.  ``meta.interpreted_pallas`` records which mode ran.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import SCHEMA_VERSION
from repro.core import analysis as An
from repro.core import simulator as S
from repro.core import volume as V
from repro.detectors import Detector
from repro.kernels.photon_step.photon_step import default_interpret
from repro.replay import detected_records, replay_jacobian

REPO_ROOT = Path(__file__).resolve().parent.parent


def _time_forward_pair(vol, cfg, n_photons, lanes, dets, cap, engine, seed,
                       src, repeats):
    """Time the record-off and record-on forward runs as interleaved
    pairs and estimate the recording overhead as the *median of the
    per-pair ratios*.

    The overhead fraction feeds the CI regression gate
    (benchmarks/check_regression.py), and a ratio of two independently
    best-of timings lets a single contended sample swing it by tens of
    points; back-to-back pairs see the same machine state, and the
    median drops contention spikes entirely.  Returns
    ``(t_off, t_on, overhead_frac, res_on)`` with the times best-of
    (the throughput trajectory keeps its historical meaning).
    """
    fns = [S.make_simulator(vol, cfg, lanes, source=src, engine=engine,
                            detectors=dets, record_detected=c)
           for c in (0, cap)]
    args = (vol.labels.reshape(-1), vol.media, n_photons, seed)
    jax.block_until_ready(fns[0](*args))  # compile + warm
    res = jax.block_until_ready(fns[1](*args))
    best = [float("inf"), float("inf")]
    fracs = []
    for _ in range(repeats):
        pair = []
        for i in (0, 1):
            t0 = time.perf_counter()
            res_i = jax.block_until_ready(fns[i](*args))
            pair.append(time.perf_counter() - t0)
            best[i] = min(best[i], pair[i])
        res = res_i
        fracs.append((pair[1] - pair[0]) / pair[0])
    return best[0], best[1], float(np.median(fracs)), res


def run(quick=False, engines=("jnp", "pallas"),
        out_path: Path | str = REPO_ROOT / "BENCH_replay.json"):
    size = 20 if quick else 40
    vol = V.benchmark_b2((size, size, size))
    cfg = V.SimConfig(do_reflect=True, steps_per_round=4)
    src = {"type": "pencil", "pos": (size / 2.0, size / 2.0, 0.0)}
    dets = (Detector(size * 0.7, size / 2.0, size * 0.15),
            Detector(size * 0.3, size * 0.3, size * 0.1))
    seed = 7
    interpreted = default_interpret()
    jnp_load = (3_000, 512) if quick else (20_000, 2048)
    workload = {
        "jnp": jnp_load,
        "pallas": (1_000, 256) if interpreted else jnp_load,
    }
    # the recording-overhead fraction is a ratio of two ~1 s timings and
    # feeds the CI regression gate (benchmarks/check_regression.py):
    # best-of-2 lets one contended sample swing it by tens of points, so
    # quick mode spends a few extra repeats on stability
    repeats = 5 if quick else 3
    cap = 1 << 16

    results: dict = {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "bench": "B2-pencil",
            "size": size,
            "quick": quick,
            "steps_per_round": cfg.steps_per_round,
            "detectors": len(dets),
            "record_capacity": cap,
            "backend": jax.default_backend(),
            "interpreted_pallas": interpreted,
            "jax": jax.__version__,
            "machine": platform.machine(),
        },
        "engines": {},
        "replay": {},
    }

    res_for_replay = None
    for engine in engines:
        n_photons, lanes = workload[engine]
        t_off, t_on, overhead, res = _time_forward_pair(
            vol, cfg, n_photons, lanes, dets, cap, engine, seed, src,
            repeats)
        n_rec = int(np.asarray(res.det_rec_n))
        row = {
            "n_photons": n_photons,
            "lanes": lanes,
            "photons_per_s_record_off": n_photons / t_off,
            "photons_per_s_record_on": n_photons / t_on,
            "recording_overhead_frac": overhead,
            "records": n_rec,
            "overflow": int(np.asarray(res.det_rec_overflow)),
        }
        results["engines"][engine] = row
        print(f"[{engine:6s}] {n_photons} photons: "
              f"{n_photons/t_off/1e3:8.2f} -> {n_photons/t_on/1e3:8.2f} "
              f"photons/ms (recording overhead "
              f"{100*row['recording_overhead_frac']:+.1f}%), "
              f"{n_rec} records", flush=True)
        # replay transports with the jnp engine; prefer its forward
        # records, but any engine's records are valid (same id set)
        if engine == "jnp" or res_for_replay is None:
            res_for_replay = res
            replay_lanes = lanes

    # -- per-engine replay throughput + physics cross-check -------------
    recs = detected_records(res_for_replay)
    results["replay"] = {"records": recs.shape[0], "engines": {}}
    rep_jnp = None
    # replay jnp first regardless of CLI order so the pallas pass always
    # has the reference for the bit-identity cross-check
    for engine in sorted(engines, key=lambda e: e != "jnp"):
        # the interpreted Pallas kernel is a correctness rig, not a perf
        # path — replay a subset there so CI smoke runs stay fast
        e_recs = recs
        if engine == "pallas" and interpreted:
            e_recs = recs[: min(recs.shape[0], 64 if quick else 256)]
        lanes = min(replay_lanes, max(e_recs.shape[0], 1))
        t0 = time.perf_counter()
        rep = replay_jacobian(vol, cfg, e_recs, dets, source=src, seed=seed,
                              n_lanes=lanes, engine=engine)
        t_cold = time.perf_counter() - t0  # includes compile: one-shot
        t0 = time.perf_counter()
        rep = replay_jacobian(vol, cfg, e_recs, dets, source=src, seed=seed,
                              n_lanes=lanes, engine=engine)
        t_warm = time.perf_counter() - t0
        det_exact = int((rep.replayed_det == rep.det).sum())
        assert det_exact == rep.n_records, (
            f"[{engine}] replay must reproduce every recorded detector: "
            f"{det_exact}/{rep.n_records}")
        results["replay"]["engines"][engine] = {
            "records": rep.n_records,
            "n_lanes": lanes,
            "records_per_s_cold": rep.n_records / t_cold,
            "records_per_s": rep.n_records / t_warm,
            "detector_exact": det_exact,
        }
        print(f"[replay {engine:6s}] {rep.n_records} records in "
              f"{t_warm:.2f}s ({rep.n_records/t_warm/1e3:.3f} records/ms), "
              f"{det_exact}/{rep.n_records} detector-exact", flush=True)
        if engine == "jnp":
            rep_jnp = rep
        elif rep_jnp is not None:
            # determinism contract: per-record outputs are engine-exact
            # (e_recs is a prefix of recs, so compare against the slice)
            n = rep.n_records
            assert np.array_equal(rep.w_exit, rep_jnp.w_exit[:n]), \
                "pallas replay exit weights diverge from jnp"
            assert np.array_equal(rep.gate, rep_jnp.gate[:n]), \
                "pallas replay exit gates diverge from jnp"
            assert np.array_equal(rep.replayed_det,
                                  rep_jnp.replayed_det[:n]), \
                "pallas replay detectors diverge from jnp"

    if rep_jnp is not None:
        M = An.jacobian_medium_sums(rep_jnp.jacobian, vol)
        ppath = np.asarray(res_for_replay.det_ppath, np.float64)
        ppath_err = float(np.abs(M - ppath).max() / max(ppath.max(), 1e-12))
        assert ppath_err < 1e-4, \
            f"jacobian/ppath identity violated: {ppath_err}"
        results["replay"]["jacobian_ppath_rel_err"] = ppath_err
        print(f"[replay] ppath identity rel err {ppath_err:.2e}", flush=True)

    out_path = Path(out_path)
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engines", nargs="+", default=("jnp", "pallas"),
                    choices=("jnp", "pallas"))
    args = ap.parse_args()
    run(quick=args.quick, engines=tuple(args.engines))


if __name__ == "__main__":
    main()
