"""Detected-photon recording overhead + replay Jacobian throughput.

Measures, on the B2 benchmark (the heterogeneous sphere the replay
Jacobian validation uses), the cost of the PR-4 replay machinery
(DESIGN.md §replay) and writes a machine-readable ``BENCH_replay.json``
at the repo root — the perf trajectory tracked per PR by CI alongside
``BENCH_fused.json`` / ``BENCH_timegates.json``:

  * forward overhead: photons/s of the detector-equipped forward run
    with the detected-photon id buffer off vs on, per round executor —
    the id buffer adds one prefix-sum + one tiny scatter per round, so
    the overhead should be small;
  * replay throughput: records/s of ``replay_jacobian`` over the
    recorded ids (two transport passes + the (nvox, n_det) scatter);
  * physics cross-check: the replay Jacobian's per-medium row sums must
    match the forward run's ``det_ppath`` (the §replay identity) and
    every replayed photon must land in its recorded detector.

  PYTHONPATH=src python -m benchmarks.replay [--quick] [--engines jnp]

Note on the Pallas numbers off-TPU: the kernel auto-detects the backend
and runs under the Pallas *interpreter* on CPU/GPU (correctness rig,
not a perf path), so off-TPU the jnp rows are the meaningful overhead
trajectory.  ``meta.interpreted_pallas`` records which mode ran.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import analysis as An
from repro.core import simulator as S
from repro.core import volume as V
from repro.detectors import Detector
from repro.kernels.photon_step.photon_step import default_interpret
from repro.replay import detected_records, replay_jacobian

REPO_ROOT = Path(__file__).resolve().parent.parent


def _time_forward(vol, cfg, n_photons, lanes, dets, cap, engine, seed,
                  src, repeats):
    fn = S.make_simulator(vol, cfg, lanes, source=src, engine=engine,
                         detectors=dets, record_detected=cap)
    args = (vol.labels.reshape(-1), vol.media, n_photons, seed)
    res = jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best, res


def run(quick=False, engines=("jnp", "pallas"),
        out_path: Path | str = REPO_ROOT / "BENCH_replay.json"):
    size = 20 if quick else 40
    vol = V.benchmark_b2((size, size, size))
    cfg = V.SimConfig(do_reflect=True, steps_per_round=4)
    src = {"type": "pencil", "pos": (size / 2.0, size / 2.0, 0.0)}
    dets = (Detector(size * 0.7, size / 2.0, size * 0.15),
            Detector(size * 0.3, size * 0.3, size * 0.1))
    seed = 7
    interpreted = default_interpret()
    jnp_load = (3_000, 512) if quick else (20_000, 2048)
    workload = {
        "jnp": jnp_load,
        "pallas": (1_000, 256) if interpreted else jnp_load,
    }
    repeats = 2 if quick else 3
    cap = 1 << 16

    results: dict = {
        "meta": {
            "bench": "B2-pencil",
            "size": size,
            "quick": quick,
            "steps_per_round": cfg.steps_per_round,
            "detectors": len(dets),
            "record_capacity": cap,
            "backend": jax.default_backend(),
            "interpreted_pallas": interpreted,
            "jax": jax.__version__,
            "machine": platform.machine(),
        },
        "engines": {},
        "replay": {},
    }

    res_for_replay = None
    for engine in engines:
        n_photons, lanes = workload[engine]
        t_off, _ = _time_forward(vol, cfg, n_photons, lanes, dets, 0,
                                 engine, seed, src, repeats)
        t_on, res = _time_forward(vol, cfg, n_photons, lanes, dets, cap,
                                  engine, seed, src, repeats)
        n_rec = int(np.asarray(res.det_rec_n))
        row = {
            "n_photons": n_photons,
            "lanes": lanes,
            "photons_per_s_record_off": n_photons / t_off,
            "photons_per_s_record_on": n_photons / t_on,
            "recording_overhead_frac": (t_on - t_off) / t_off,
            "records": n_rec,
            "overflow": int(np.asarray(res.det_rec_overflow)),
        }
        results["engines"][engine] = row
        print(f"[{engine:6s}] {n_photons} photons: "
              f"{n_photons/t_off/1e3:8.2f} -> {n_photons/t_on/1e3:8.2f} "
              f"photons/ms (recording overhead "
              f"{100*row['recording_overhead_frac']:+.1f}%), "
              f"{n_rec} records", flush=True)
        # replay transports with the jnp engine; prefer its forward
        # records, but any engine's records are valid (same id set)
        if engine == "jnp" or res_for_replay is None:
            res_for_replay = res
            replay_lanes = lanes

    # -- replay throughput + physics cross-check (jnp transport) --------
    recs = detected_records(res_for_replay)
    lanes = replay_lanes
    t0 = time.perf_counter()
    rep = replay_jacobian(vol, cfg, recs, dets, source=src, seed=seed,
                          n_lanes=lanes)
    t_replay = time.perf_counter() - t0  # includes compile: one-shot cost
    t0 = time.perf_counter()
    rep = replay_jacobian(vol, cfg, recs, dets, source=src, seed=seed,
                          n_lanes=lanes)
    t_replay_warm = time.perf_counter() - t0
    det_exact = int((rep.replayed_det == rep.det).sum())
    M = An.jacobian_medium_sums(rep.jacobian, vol)
    ppath = np.asarray(res_for_replay.det_ppath, np.float64)
    ppath_err = float(np.abs(M - ppath).max() / max(ppath.max(), 1e-12))
    assert det_exact == rep.n_records, (
        f"replay must reproduce every recorded detector: "
        f"{det_exact}/{rep.n_records}")
    assert ppath_err < 1e-4, f"jacobian/ppath identity violated: {ppath_err}"
    results["replay"] = {
        "records": rep.n_records,
        "records_per_s_cold": rep.n_records / t_replay,
        "records_per_s": rep.n_records / t_replay_warm,
        "detector_exact": det_exact,
        "jacobian_ppath_rel_err": ppath_err,
    }
    print(f"[replay] {rep.n_records} records in {t_replay_warm:.2f}s "
          f"({rep.n_records/t_replay_warm/1e3:.3f} records/ms), "
          f"{det_exact}/{rep.n_records} detector-exact, "
          f"ppath identity rel err {ppath_err:.2e}", flush=True)

    out_path = Path(out_path)
    out_path.write_text(json.dumps(results, indent=2))
    print(f"wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engines", nargs="+", default=("jnp", "pallas"),
                    choices=("jnp", "pallas"))
    args = ap.parse_args()
    run(quick=args.quick, engines=tuple(args.engines))


if __name__ == "__main__":
    main()
