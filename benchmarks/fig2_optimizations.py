"""Paper Fig. 2: B1/B2/B2a throughput under the optimization ladder.

TPU/JAX mapping of the paper's optimizations (DESIGN.md §optimizations):
  Baseline — exact Beer-Lambert deposit, UNspecialized general kernel
             (traced physics flags), fixed N=2^14 lanes (paper baseline).
  Opt1     — native-math deposition (first-order Beer-Lambert).
  Opt1+2   — + autotuned lane count (pilot sweep = occupancy balance).
  Opt1+2+3 — + trace-time kernel specialization (control-flow simpl.).

B2a vs B2: on TPU the scatter-add is race-free, so the paper's
atomic-vs-nonatomic axis becomes deposition-on vs deposition-off, which
bounds the accumulation overhead from above.
"""

from __future__ import annotations

import json

from benchmarks.common import get_bench, photons_per_ms
from repro.core import simulator as S
from repro.core.volume import SimConfig


def run(n_photons=30_000, size=40, quick=False):
    if quick:
        n_photons, size = 15_000, 30
    base_lanes = 16384  # the paper's fixed baseline thread count (2^14)
    results = {}
    for bench in ("B1", "B2", "B2a"):
        vol, phys = get_bench(bench, size)
        rows = {}

        def cfg(deposit_mode, specialize):
            return SimConfig(do_reflect=phys["do_reflect"],
                             deposit_mode=deposit_mode, specialize=specialize)

        rows["baseline"] = photons_per_ms(
            vol, cfg("exact", False), n_photons, base_lanes)
        rows["opt1"] = photons_per_ms(
            vol, cfg("taylor", False), n_photons, base_lanes)
        lanes, timings = S.autotune_lanes(
            vol, cfg("taylor", False), n_pilot=max(n_photons // 10, 2000),
            candidates=(1024, 4096, 16384))
        rows["opt1_2"] = photons_per_ms(
            vol, cfg("taylor", False), n_photons, lanes)
        rows["opt1_2_3"] = photons_per_ms(
            vol, cfg("taylor", True), n_photons, lanes)
        rows["autotuned_lanes"] = lanes
        results[bench] = rows
        print(f"[fig2] {bench}: " + " ".join(
            f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in rows.items()), flush=True)
    # paper-claim check: Opt1 and Opt1+2 are consistent accelerations
    for bench, rows in results.items():
        speedup = rows["opt1_2_3"] / rows["baseline"]
        print(f"[fig2] {bench}: total speedup {speedup:.2f}x", flush=True)
    return results


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
