"""Perf-regression gate over the BENCH_*.json trajectories.

Compares freshly produced benchmark JSONs (``benchmarks/fused.py``,
``benchmarks/timegates.py``, ``benchmarks/replay.py``,
``benchmarks/resilience.py``, ``benchmarks/scenarios.py``) against the
committed baselines and **fails** (exit code 1) when

  * any throughput leaf (a key named ``photons_per_s*``,
    ``records_per_s*``, or ``scenarios_per_s*``, at any nesting depth)
    drops by more than ``--max-drop`` (default 30%), or
  * any overhead leaf (a key ending in ``_overhead_frac``) grows by
    more than ``--max-overhead-points`` (default 0.10, i.e. 10
    percentage points), or
  * any cache-efficiency leaf (a key ending in ``_hit_rate``) comes in
    below its baseline at all — the repeat-shape scenario workload is
    constructed to hit the compile cache on every timed batch, so the
    committed baseline is 1.0 and *any* fresh miss is a caching bug,
    not noise, or
  * a fresh file carries a **gated** leaf (throughput / overhead /
    hit-rate) that the committed baseline lacks: a new gated metric
    must land together with a baseline refresh, otherwise it would ride
    ungated until someone remembers to regenerate.

A ``meta.schema_version`` mismatch between baseline and fresh is a hard
**failure**, not a skip: intentional layout changes must come with a
baseline refresh (the bench-refresh workflow), never a silent
cross-version comparison.  Keys ending in ``_cold`` are ignored (cold
numbers include one-shot compile time — too noisy for a gate).
Non-gated keys present on only one side, and gated keys present only
in the *baseline*, stay notes (leaf-level evolution is not a
regression).  A file whose ``meta`` records a
different *workload* (``quick`` flag, ``size``, ``backend``) is skipped
with a warning: cross-workload throughput ratios are meaningless.  Machine-to-machine variance is what the 30% headroom is
for; tighten or loosen per lane with the CLI flags or the
``BENCH_MAX_DROP`` / ``BENCH_MAX_OVERHEAD_POINTS`` env vars.

  python -m benchmarks.check_regression --baseline <dir> [--fresh <dir>]

CI snapshots the committed baselines before the benchmark smoke runs
overwrite them at the repo root, then runs this gate (.github/
workflows/ci.yml).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

BENCH_FILES = ("BENCH_fused.json", "BENCH_timegates.json",
               "BENCH_replay.json", "BENCH_resilience.json",
               "BENCH_scenarios.json")
THROUGHPUT_MARKERS = ("photons_per_s", "records_per_s", "scenarios_per_s")
OVERHEAD_SUFFIX = "_overhead_frac"
HIT_RATE_SUFFIX = "_hit_rate"
# meta keys that define the workload: a mismatch means the two files
# measured different things and ratios are not comparable
WORKLOAD_KEYS = ("bench", "quick", "size", "backend", "interpreted_pallas")


def _leaves(node, prefix=""):
    """Flatten nested dicts to {dotted.path: numeric leaf}."""
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            out.update(_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)
    return out


def _is_throughput(path: str) -> bool:
    key = path.rsplit(".", 1)[-1]
    return any(m in key for m in THROUGHPUT_MARKERS) \
        and not key.endswith("_cold")


def _is_overhead(path: str) -> bool:
    return path.rsplit(".", 1)[-1].endswith(OVERHEAD_SUFFIX)


def _is_hit_rate(path: str) -> bool:
    return path.rsplit(".", 1)[-1].endswith(HIT_RATE_SUFFIX)


def _is_gated(path: str) -> bool:
    return _is_throughput(path) or _is_overhead(path) or _is_hit_rate(path)


def check_file(name: str, baseline: dict, fresh: dict, max_drop: float,
               max_overhead_points: float) -> tuple[list[str], list[str]]:
    """Returns (failures, notes) for one benchmark JSON pair."""
    failures, notes = [], []
    meta_b = baseline.get("meta", {})
    meta_f = fresh.get("meta", {})
    # schema version first, and LOUDLY: a layout change must never be
    # silently absorbed by the only-one-side key rule or demoted to a
    # workload-mismatch skip — either would let a regression through as
    # "schema evolution"
    sv_b = meta_b.get("schema_version")
    sv_f = meta_f.get("schema_version")
    if sv_b != sv_f:
        failures.append(
            f"{name}: schema_version mismatch — baseline {sv_b!r} vs "
            f"fresh {sv_f!r}; regenerate the committed baseline with the "
            f"current writers (bench-refresh workflow) instead of "
            f"comparing across layouts")
        return failures, notes
    mismatched = [k for k in WORKLOAD_KEYS
                  if k in meta_b and k in meta_f and meta_b[k] != meta_f[k]]
    if mismatched:
        notes.append(
            f"{name}: SKIPPED — workload mismatch on "
            f"{', '.join(f'{k} ({meta_b[k]!r} vs {meta_f[k]!r})' for k in mismatched)}")
        return failures, notes
    if meta_b.get("machine") != meta_f.get("machine"):
        # still compared — that is the gate's job — but cross-machine
        # ratios carry extra variance; the headroom (and the
        # BENCH_MAX_DROP escape hatch) is what absorbs it
        notes.append(
            f"{name}: note — baseline machine "
            f"{meta_b.get('machine')!r} != fresh "
            f"{meta_f.get('machine')!r}; expect extra variance")

    base_leaves = _leaves(baseline)
    fresh_leaves = _leaves(fresh)
    shared = sorted(set(base_leaves) & set(fresh_leaves))
    n_checked = 0
    for path in shared:
        b, f = base_leaves[path], fresh_leaves[path]
        if _is_throughput(path):
            n_checked += 1
            if b > 0 and f < (1.0 - max_drop) * b:
                failures.append(
                    f"{name}: {path} dropped {100 * (1 - f / b):.1f}% "
                    f"({b:.1f} -> {f:.1f}; limit {100 * max_drop:.0f}%)")
        elif _is_overhead(path):
            n_checked += 1
            # a negative baseline overhead is a timing-noise fluke
            # (record-on measured faster than record-off); gating growth
            # against it would demand impossible fresh numbers, so the
            # floor of a real overhead baseline is zero
            if f > max(b, 0.0) + max_overhead_points:
                failures.append(
                    f"{name}: {path} grew {f - max(b, 0.0):+.3f} "
                    f"({b:.3f} -> {f:.3f}; limit "
                    f"+{max_overhead_points:.2f})")
        elif _is_hit_rate(path):
            n_checked += 1
            # no headroom here: a hit rate is a deterministic ratio of
            # cache-ledger counters, not a timing — any drop below the
            # baseline means the repeat-shape workload re-compiled
            if f < b - 1e-9:
                failures.append(
                    f"{name}: {path} regressed {b:.3f} -> {f:.3f} — the "
                    f"repeat-shape workload missed the compile cache "
                    f"(shape key leaked a traced value?)")
    # a gated leaf only the FRESH side carries would silently ride
    # ungated forever; force the baseline refresh to land with it
    for path in sorted(set(fresh_leaves) - set(base_leaves)):
        if _is_gated(path):
            failures.append(
                f"{name}: fresh file adds gated leaf {path} absent from "
                f"the committed baseline — regenerate the baseline "
                f"(bench-refresh workflow) so the new metric is gated "
                f"from day one")
    notes.append(f"{name}: checked {n_checked} gated leaves "
                 f"({len(shared)} shared)")
    if n_checked == 0:
        notes.append(f"{name}: WARNING — no gated leaves found; schema "
                     f"drift?")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json "
                         "baselines (snapshot them before the benchmarks "
                         "overwrite the repo root)")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the freshly produced "
                         "BENCH_*.json files (default: repo root)")
    ap.add_argument("--max-drop", type=float,
                    default=float(os.environ.get("BENCH_MAX_DROP", 0.30)),
                    help="maximum tolerated fractional throughput drop "
                         "(default 0.30)")
    ap.add_argument("--max-overhead-points", type=float,
                    default=float(os.environ.get(
                        "BENCH_MAX_OVERHEAD_POINTS", 0.10)),
                    help="maximum tolerated absolute *_overhead_frac "
                         "growth (default 0.10 = 10 points)")
    args = ap.parse_args(argv)

    all_failures: list[str] = []
    for name in BENCH_FILES:
        base_path = Path(args.baseline) / name
        fresh_path = Path(args.fresh) / name
        if not base_path.exists():
            print(f"{name}: no committed baseline — skipping")
            continue
        if not fresh_path.exists():
            all_failures.append(
                f"{name}: baseline exists but no fresh file was produced "
                f"at {fresh_path}")
            continue
        failures, notes = check_file(
            name, json.loads(base_path.read_text()),
            json.loads(fresh_path.read_text()),
            args.max_drop, args.max_overhead_points)
        for note in notes:
            print(note)
        all_failures.extend(failures)

    if all_failures:
        print("\nPERF REGRESSION GATE FAILED:")
        for f in all_failures:
            print(f"  FAIL {f}")
        return 1
    print("\nperf-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
