"""Batched multi-scenario throughput: scenarios/s through simulate_many.

Measures the DESIGN.md §batching payoff on a *repeat-shape* workload —
the campaign pattern the compile cache exists for: one warm call pays
the single compile for the shared config shape, then every subsequent
batch of value-perturbed scenarios (new seeds, budgets, media tables,
source radii, detector coordinates) reuses the cached executable.  The
timed section must therefore run at compile-cache hit rate 1.0; the CI
gate fails any BENCH file where ``cache_hit_rate`` drops below the
committed baseline, alongside the usual >30% ``scenarios_per_s`` drop
rule.

  PYTHONPATH=src python -m benchmarks.scenarios [--quick] [--engines jnp]

Same Pallas caveat as benchmarks/fused.py: off-TPU the kernel runs
under the Pallas interpreter, so only the jnp rows are a meaningful
throughput trajectory there (``meta.interpreted_pallas`` records which
mode ran).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import SCHEMA_VERSION, get_bench
from repro.core import simulator as S
from repro.core.volume import SimConfig
from repro.kernels.photon_step.photon_step import default_interpret
from repro.scenarios import CompileCache, Scenario, simulate_many
from repro.sources import Disk

REPO_ROOT = Path(__file__).resolve().parent.parent


def _make_batch(vol0, cfg, n_scenarios, photons, round_idx):
    """One batch of same-shape, distinct-value scenarios.

    Every traced quantity varies across scenarios *and* across rounds
    (media tables, source radius, detector coordinates, seeds, budgets,
    id offsets) so a cache hit is only correct if per-scenario values
    really are traced, not baked into the executable.
    """
    scs = []
    for i in range(n_scenarios):
        media = np.asarray(vol0.media).copy()
        media[1:, 0] *= 1.0 + 0.05 * ((round_idx + i) % 7)
        vol = dataclasses.replace(vol0, media=media)
        cx = vol0.shape[0] / 2
        scs.append(Scenario(
            vol, cfg, photons + 16 * i,
            seed=1000 * round_idx + i,
            source=Disk(pos=(cx, cx, 0),
                        radius=1.0 + 0.25 * ((round_idx + i) % 4)),
            detectors=({"x": cx + 0.5 * (i % 3), "y": cx, "radius": 2.0},),
            id_offset=(round_idx * n_scenarios + i) << 20))
    return scs


def run(quick=False, engines=("jnp", "pallas"),
        out_path: Path | str = REPO_ROOT / "BENCH_scenarios.json"):
    size = 12 if quick else 24
    vol, phys = get_bench("B1", size)
    cfg = SimConfig(do_reflect=phys["do_reflect"], steps_per_round=4)
    interpreted = default_interpret()
    # (n_scenarios per batch, photons per scenario, lanes)
    jnp_load = (8, 400, 128) if quick else (16, 2_000, 512)
    workload = {
        "jnp": jnp_load,
        "pallas": ((4, 100, 64) if quick else (6, 300, 128))
        if interpreted else jnp_load,
    }
    repeats = 3 if quick else 5

    results: dict = {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "bench": "B1-disk-repeat-shape",
            "size": size,
            "quick": quick,
            "backend": jax.default_backend(),
            "interpreted_pallas": interpreted,
            "jax": jax.__version__,
            "machine": platform.machine(),
            "repeats": repeats,
        },
        "engines": {},
    }
    for engine in engines:
        n_sc, photons, lanes = workload[engine]
        block = 32 if engine == "pallas" else 256
        cache = CompileCache()
        kw = dict(n_lanes=lanes, engine=engine, block_lanes=block,
                  cache=cache)
        # warm: the one compile this shape ever pays
        jax.block_until_ready(
            simulate_many(_make_batch(vol, cfg, n_sc, photons, 0), **kw))
        warm_misses, warm_hits = cache.misses, cache.hits
        best = float("inf")
        for r in range(1, repeats + 1):
            batch = _make_batch(vol, cfg, n_sc, photons, r)
            t0 = time.perf_counter()
            jax.block_until_ready(simulate_many(batch, **kw))
            best = min(best, time.perf_counter() - t0)
        hits = cache.hits - warm_hits
        misses = cache.misses - warm_misses
        hit_rate = hits / max(hits + misses, 1)
        row = {
            "seconds": best,
            "scenarios_per_s": n_sc / best,
            "photons_per_s": n_sc * photons / best,
            "cache_hit_rate": hit_rate,
            "n_scenarios": n_sc,
            "photons_per_scenario": photons,
            "lanes": lanes,
            "warm_compiles": warm_misses,
        }
        print(f"[scenarios] {engine:6s}: {n_sc / best:7.2f} scenarios/s "
              f"({n_sc * photons / best / 1e3:.2f} photons/ms), "
              f"hit rate {hit_rate:.2f} "
              f"({hits} hits / {misses} misses over {repeats} batches)",
              flush=True)
        if hit_rate < 1.0:
            print(f"[scenarios] WARNING: {engine} repeat-shape workload "
                  f"re-compiled ({misses} misses) — the compile-cache "
                  f"key leaked a traced value into the shape", flush=True)
        results["engines"][engine] = row

    out_path = Path(out_path)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"[scenarios] wrote {out_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced scenario counts / domain (CI smoke)")
    ap.add_argument("--engines", default="jnp,pallas",
                    help="comma-separated subset of {jnp,pallas}")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_scenarios.json"))
    args = ap.parse_args(argv)
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip())
    for e in engines:
        if e not in S.ENGINES:
            ap.error(f"unknown engine {e!r}")
    run(quick=args.quick, engines=engines, out_path=args.out)


if __name__ == "__main__":
    main()
