"""Paper Fig. 3(b): device-level load partitioning S1/S2/S3.

Two parts:
  1. The paper's own device mix (1080Ti/980Ti/R9Nano/RX480 with their
     published T0 overheads): predicted makespans of S1/S2/S3 vs the
     ideal bound — reproduces the ~10-14% S2/S3-over-S1 claim.
  2. A *measured* pilot fit on this host: two pilot runs (the paper's
     n1/n2 protocol scaled down) fit (a, T0) of the real simulator, and
     a heterogeneity scenario derived from it (device classes at 1x/2x/4x
     the measured slope) is partitioned with all three strategies.
"""

from __future__ import annotations

import json

from benchmarks.common import get_bench
from repro.core import loadbalance as LB
from repro.core import simulator as S
from repro.core.volume import SimConfig


PAPER_DEVICES = [
    LB.DeviceModel("1080Ti", a=4.4e-8, t0=0.053, cores=3584),
    LB.DeviceModel("980Ti", a=8.0e-8, t0=0.063, cores=2816),
    LB.DeviceModel("R9Nano", a=6.0e-8, t0=0.631, cores=4096),
    LB.DeviceModel("RX480", a=1.1e-7, t0=0.652, cores=2304),
]


def run(quick=False):
    out = {}
    n = 10**8
    ms = {s: LB.makespan(LB.PARTITIONERS[s](n, PAPER_DEVICES), PAPER_DEVICES)
          for s in ("S1", "S2", "S3")}
    ms["ideal"] = LB.ideal_makespan(n, PAPER_DEVICES)
    out["paper_mix"] = ms
    print(f"[fig3b] paper mix makespans (s): " +
          " ".join(f"{k}={v:.3f}" for k, v in ms.items()), flush=True)
    print(f"[fig3b] S2 vs S1 speedup: {ms['S1']/ms['S2']:.3f}x "
          f"(paper: 1.10-1.14x); S3 vs S2: {ms['S2']/ms['S3']:.4f}x",
          flush=True)

    # measured pilot fit on this host (the paper's two-run protocol)
    vol, phys = get_bench("B1", 30 if quick else 40)
    cfg = SimConfig(do_reflect=phys["do_reflect"])
    fn = S.make_simulator(vol, cfg, 2048, "dynamic")
    import time as _t

    import jax

    def run_n(k):
        args = (vol.labels.reshape(-1), vol.media, k, 11)
        jax.block_until_ready(fn(*args))  # includes compile on first call
        t0 = _t.perf_counter()
        jax.block_until_ready(fn(*args))
        return _t.perf_counter() - t0

    n1, n2 = (2000, 10_000) if quick else (5000, 25_000)
    model = LB.run_pilot(run_n, n1, n2, name="cpu0")
    out["measured_model"] = {"a": model.a, "t0": model.t0,
                             "throughput_per_ms": model.throughput / 1e3}
    print(f"[fig3b] measured: a={model.a:.3e}s/photon t0={model.t0*1e3:.1f}ms",
          flush=True)

    # heterogeneous scenario built from the measured slope
    mix = [
        LB.DeviceModel("fast", a=model.a, t0=model.t0, cores=4),
        LB.DeviceModel("mid", a=model.a * 2, t0=model.t0, cores=2),
        LB.DeviceModel("slow", a=model.a * 4, t0=model.t0 * 2, cores=1),
    ]
    n_h = 10**6
    hm = {s: LB.makespan(LB.PARTITIONERS[s](n_h, mix), mix)
          for s in ("S1", "S2", "S3")}
    hm["ideal"] = LB.ideal_makespan(n_h, mix)
    out["measured_mix"] = hm
    print(f"[fig3b] measured-mix makespans: " +
          " ".join(f"{k}={v:.3f}" for k, v in hm.items()), flush=True)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
