"""Run the full benchmark suite:  python -m benchmarks.run [--full]

One benchmark per paper figure (Fig 2, Fig 3a/3b/3c) plus the
trajectory benches (fused / timegates / sources / replay / resilience /
scenarios).
"""

from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger photon counts (slower)")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (fig2_optimizations, fig3a_workgroup,
                            fig3b_devicelb, fig3c_scaling, fused, replay,
                            resilience, scenarios, sources, timegates)

    t0 = time.time()
    results = {}
    print("=" * 70)
    print("Fig 2 — optimization ladder (B1/B2/B2a x Baseline/Opt1/+2/+3)")
    print("=" * 70, flush=True)
    results["fig2"] = fig2_optimizations.run(quick=quick)

    print("=" * 70)
    print("Fig 3a — thread-level vs workgroup-level load balancing")
    print("=" * 70, flush=True)
    results["fig3a"] = fig3a_workgroup.run(quick=quick)

    print("=" * 70)
    print("Fig 3b — device-level partitioning S1/S2/S3")
    print("=" * 70, flush=True)
    results["fig3b"] = fig3b_devicelb.run(quick=quick)

    print("=" * 70)
    print("Fig 3c — multi-device scaling 1x..8x")
    print("=" * 70, flush=True)
    results["fig3c"] = fig3c_scaling.run(quick=quick)

    print("=" * 70)
    print("Fused rounds — photons/s vs K = steps_per_round, per engine")
    print("=" * 70, flush=True)
    results["fused"] = fused.run(quick=quick)

    print("=" * 70)
    print("Time gates — photons/s vs n_time_gates, per engine")
    print("=" * 70, flush=True)
    results["timegates"] = timegates.run(quick=quick)

    print("=" * 70)
    print("Sources — per-source-type launch/regeneration cost")
    print("=" * 70, flush=True)
    results["sources"] = sources.run(quick=quick)

    print("=" * 70)
    print("Replay — detected-photon recording overhead + Jacobian replay")
    print("=" * 70, flush=True)
    results["replay"] = replay.run(quick=quick)

    print("=" * 70)
    print("Resilience — fault-free DevicePool overhead vs pre-PR scheduler")
    print("=" * 70, flush=True)
    results["resilience"] = resilience.run(quick=quick)

    print("=" * 70)
    print("Scenarios — batched multi-scenario scenarios/s + cache hit rate")
    print("=" * 70, flush=True)
    results["scenarios"] = scenarios.run(quick=quick)

    print(f"\nbenchmark suite done in {time.time()-t0:.1f}s")
    with open("bench_results.json", "w") as f:
        json.dump(results, f, indent=2, default=str)
    print("wrote bench_results.json")


if __name__ == "__main__":
    main()
