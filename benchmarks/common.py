"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax

from repro.core import simulator as S
from repro.core import volume as V

# Version of the BENCH_*.json layout.  Bump whenever a writer changes
# the meaning or structure of recorded values; check_regression.py
# refuses to compare files across versions (a silent cross-version
# comparison is how a perf regression sneaks through as a "workload
# mismatch" skip).  v2: added schema_version itself + the
# collect_stats_overhead_frac leaf in BENCH_fused.json.
SCHEMA_VERSION = 2


def get_bench(name: str, size: int = 40):
    shape = (size, size, size)
    if name == "B1":
        return V.benchmark_b1(shape), dict(do_reflect=False)
    if name in ("B2", "B2a"):
        return V.benchmark_b2(shape), dict(do_reflect=True)
    raise ValueError(name)


def time_sim(vol, cfg, n_photons, lanes, seed=11, mode="dynamic",
             repeats=2, source=None, engine="jnp") -> float:
    """Best-of-N wall seconds for one simulation (compile excluded)."""
    fn = S.make_simulator(vol, cfg, lanes, mode, source, engine)
    args = (vol.labels.reshape(-1), vol.media, n_photons, seed)
    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def photons_per_ms(vol, cfg, n_photons, lanes, **kw) -> float:
    return n_photons / time_sim(vol, cfg, n_photons, lanes, **kw) / 1e3
