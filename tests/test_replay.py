"""Detected-photon replay (DESIGN.md §replay).

Contracts under test:

  * The forward engines (jnp at any K, Pallas, and the ref oracle)
    record the *same* detected-photon id set — trajectories are
    id-keyed, so the records are engine-independent.
  * The fixed-capacity id buffer fills in capture order, never corrupts
    the aggregate detector outputs, and counts overflowing captures.
  * Replayed photons reproduce their forward trajectories bit-for-bit:
    recorded detector index and exit gate are reproduced exactly, and
    the per-detector replayed exit-weight sums match the forward TPSF
    totals to fp-accumulation tolerance.
  * The absorption Jacobian's per-medium row sums equal the forward
    run's weight-weighted partial pathlengths (``det_ppath``) — the
    identity that ties the replay to ``analysis.rescale_detected`` —
    and a finite-difference perturbed forward run on B2 confirms the
    first-order prediction.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import photon as ph
from repro.core import simulator as S
from repro.core import volume as V
from repro.detectors import Detector, det_geometry
from repro.replay import ReplayResult, detected_records, replay_jacobian
from repro.sources import Pencil

SHAPE = (16, 16, 16)
SRC = {"type": "pencil", "pos": (8.0, 8.0, 0.0)}
DETS = (Detector(11.0, 8.0, 3.0), Detector(5.0, 5.0, 2.5))
SEED = 7
N_PHOTONS = 2000
LANES = 256


def _forward(record=4096, engine="jnp", k=1, cfg=None, vol=None,
             n_photons=N_PHOTONS, **kw):
    vol = vol if vol is not None else V.benchmark_b1(SHAPE)
    cfg = cfg or V.SimConfig(do_reflect=False, steps_per_round=k)
    return S.simulate(vol, cfg, n_photons, LANES, SEED, source=SRC,
                      engine=engine, detectors=DETS, record_detected=record,
                      **kw), vol, cfg


def _sorted(rec):
    return np.asarray(sorted(map(tuple, np.asarray(rec))), np.uint32)


# ---------------------------------------------------------------------------
# record buffer semantics
# ---------------------------------------------------------------------------

def test_records_are_unique_and_consistent_with_det_w():
    res, _, _ = _forward()
    rec = detected_records(res)
    assert rec.shape[0] == int(res.det_rec_n) > 0
    assert int(res.det_rec_overflow) == 0
    # each capture is recorded once: ids unique
    ids = {(int(r[0]), int(r[1])) for r in rec}
    assert len(ids) == rec.shape[0]
    # detector/gate indices in range
    assert rec[:, 2].max() < len(DETS)
    assert rec[:, 3].max() < 1  # CW run: single gate
    # every detector with recorded captures has detected weight and
    # vice versa
    w = np.asarray(res.det_w).sum(axis=1)
    for d in range(len(DETS)):
        assert (w[d] > 0) == ((rec[:, 2] == d).any())


def test_record_overflow_keeps_aggregates_intact():
    full, _, _ = _forward(record=4096)
    n_cap = int(full.det_rec_n)
    assert n_cap > 8
    cap = 5
    small, _, _ = _forward(record=cap)
    assert int(small.det_rec_n) == cap
    assert int(small.det_rec_overflow) == n_cap - cap
    # the first `cap` records agree (captures append in engine order)
    np.testing.assert_array_equal(detected_records(small),
                                  detected_records(full)[:cap])
    # aggregate detector outputs are unaffected by the buffer size
    np.testing.assert_array_equal(np.asarray(small.det_w),
                                  np.asarray(full.det_w))
    np.testing.assert_array_equal(np.asarray(small.det_ppath),
                                  np.asarray(full.det_ppath))


def test_recording_does_not_perturb_physics():
    plain, _, _ = _forward(record=0)
    recd, _, _ = _forward(record=4096)
    np.testing.assert_array_equal(np.asarray(plain.energy),
                                  np.asarray(recd.energy))
    np.testing.assert_array_equal(np.asarray(plain.det_w),
                                  np.asarray(recd.det_w))
    assert int(plain.n_launched) == int(recd.n_launched)


def test_record_requires_detectors():
    vol = V.benchmark_b1(SHAPE)
    with pytest.raises(ValueError, match="requires detectors"):
        S.build_sim_fn(vol.shape, vol.unitinmm, V.SimConfig(), 128,
                       record_detected=16)


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,k", [("jnp", 4), ("pallas", 1),
                                      ("pallas", 4)])
def test_records_engine_invariant(engine, k):
    """The recorded id set is identical across round executors and
    fused-round depths (capture *order* may differ between K values, so
    compare as sorted sets)."""
    ref, _, _ = _forward(engine="jnp", k=1)
    other, _, _ = _forward(engine=engine, k=k, block_lanes=64)
    np.testing.assert_array_equal(_sorted(detected_records(ref)),
                                  _sorted(detected_records(other)))


def test_kernel_capture_records_match_oracle():
    """Per-lane (cap_det, cap_gate) outputs: Pallas kernel vs the
    pure-jnp ref oracle, bit-for-bit."""
    from repro.kernels.photon_step.photon_step import photon_step_pallas
    from repro.kernels.photon_step.ref import photon_steps_ref

    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False, n_time_gates=4)
    n = 256
    src = Pencil(pos=(8.0, 8.0, 0.0))
    ids = jnp.arange(n, dtype=jnp.uint32)
    pos, direc, w0, rng = src.sample(ids, jnp.uint32(SEED))
    state = ph.launch(pos, direc, w0, rng, jnp.ones((n,), bool), vol.shape)
    dg = det_geometry(DETS)
    pp0 = jnp.zeros((n, vol.media.shape[0]), jnp.float32)
    args = (vol.labels.reshape(-1), vol.media, state, vol.shape,
            vol.unitinmm, cfg, 60)

    outs_k = photon_step_pallas(*args, block_lanes=64, interpret=True,
                                ppath=pp0, det_geom=dg, record=True)
    outs_r = photon_steps_ref(*args, ppath=pp0, det_geom=dg, record=True)
    capd_k, capg_k = outs_k[8:]
    capd_r, capg_r = outs_r[8:]
    np.testing.assert_array_equal(np.asarray(capd_k), np.asarray(capd_r))
    np.testing.assert_array_equal(np.asarray(capg_k), np.asarray(capg_r))
    # captures happened, and only captured lanes carry a gate
    assert int(jnp.sum(capd_k >= 0)) > 0


# ---------------------------------------------------------------------------
# replay: bit-exact trajectories + Jacobian validation
# ---------------------------------------------------------------------------

def _b2_forward():
    vol = V.benchmark_b2((20, 20, 20))
    cfg = V.SimConfig(do_reflect=True, steps_per_round=4)
    src = {"type": "pencil", "pos": (10.0, 10.0, 0.0)}
    dets = (Detector(14.0, 10.0, 3.0), Detector(6.0, 6.0, 2.0))
    res = S.simulate(vol, cfg, 3000, 512, SEED, source=src, detectors=dets,
                     record_detected=4096)
    return res, vol, cfg, src, dets


def test_replay_reproduces_forward_bit_for_bit():
    res, vol, cfg, src, dets = _b2_forward()
    rec = detected_records(res)
    assert rec.shape[0] > 100 and int(res.det_rec_overflow) == 0
    rep = replay_jacobian(vol, cfg, rec, dets, source=src, seed=SEED,
                          n_lanes=512)
    assert isinstance(rep, ReplayResult) and rep.n_records == rec.shape[0]
    # trajectory determinism: every replayed photon exits into the same
    # detector at the same time gate as the forward run recorded
    np.testing.assert_array_equal(rep.replayed_det, rep.det)
    np.testing.assert_array_equal(rep.gate, rec[:, 3].astype(np.int32))
    # per-detector replayed exit weight == forward TPSF totals
    per_det = np.zeros(len(dets))
    np.add.at(per_det, rep.det, rep.w_exit.astype(np.float64))
    fw = np.asarray(res.det_w, np.float64).sum(axis=1)
    np.testing.assert_allclose(per_det, fw, rtol=1e-5)


def test_jacobian_matches_ppath_rescale_and_finite_difference():
    res, vol, cfg, src, dets = _b2_forward()
    rep = replay_jacobian(vol, cfg, detected_records(res), dets, source=src,
                          seed=SEED, n_lanes=512)
    # 1) medium row sums == forward weight-weighted partial pathlengths
    M = A.jacobian_medium_sums(rep.jacobian, vol)
    np.testing.assert_allclose(M, np.asarray(res.det_ppath, np.float64),
                               rtol=1e-4, atol=1e-4)
    # 2) first-order consistency with the white-MC rescaling: for a
    #    small per-medium absorption change both predict
    #    dW_d = -sum_m det_ppath[d, m] * dmua_m
    d_mua = 0.005 * 0.05  # +5% of the background mua
    W0 = np.asarray(res.det_w, np.float64).sum(axis=1)
    new_mua = np.asarray(vol.media)[:, 0].copy()
    new_mua[1] += d_mua
    dw_rescale = A.rescale_detected(res, vol, new_mua) - W0
    dw_jac = -M[:, 1] * d_mua
    np.testing.assert_allclose(dw_jac, dw_rescale, rtol=5e-2)
    # 3) finite difference: a perturbed forward run (same seed — the
    #    trajectories only drift through roulette, second order here)
    media2 = np.asarray(vol.media).copy()
    media2[1, 0] += d_mua
    vol2 = dataclasses.replace(vol, media=jnp.asarray(media2))
    res2 = S.simulate(vol2, cfg, 3000, 512, SEED, source=src,
                      detectors=dets)
    dw_fd = np.asarray(res2.det_w, np.float64).sum(axis=1) - W0
    np.testing.assert_allclose(dw_jac, dw_fd, rtol=5e-2)
    # sanity: the Jacobian is nonnegative and concentrated where the
    # detected light actually travelled (source-detector plane)
    assert rep.jacobian.min() >= 0.0
    assert rep.jacobian.sum() > 0.0


def test_replay_input_validation():
    res, vol, cfg, src, dets = _b2_forward()
    with pytest.raises(ValueError, match="detectors"):
        replay_jacobian(vol, cfg, detected_records(res), ())
    bad = np.array([[1, 0, 99, 0]], np.uint32)  # detector 99 of 2
    with pytest.raises(ValueError, match="detector 99"):
        replay_jacobian(vol, cfg, bad, dets, source=src, seed=SEED)


# ---------------------------------------------------------------------------
# 64-bit photon ids through the engine
# ---------------------------------------------------------------------------

def test_photon_ids_straddle_2_32_through_the_engine():
    """Regression for the uint32 id-counter wraparound: a campaign
    window straddling 2**32 must (a) count its launches correctly and
    (b) simulate photons with *distinct* RNG streams from the sub-2**32
    ids sharing the same low word — under the old uint32 counter the
    post-wrap photons re-ran ids 0, 1, 2, ... bit-identically."""
    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    fn = S.make_simulator(vol, cfg, LANES, source=SRC)
    labels, media = vol.labels.reshape(-1), vol.media
    n = 500
    # NB: offsets >= 2**31 must cross the jit boundary as np.uint32 —
    # rng.split_id64 does this for host-side 64-bit ids
    off_lo, off_hi = S.xrng.split_id64(2**32 - n // 2)
    straddle = fn(labels, media, n, SEED, off_lo, off_hi)
    assert int(straddle.n_launched) == n
    low = fn(labels, media, n, SEED, off_lo, off_hi + 1)  # same lo, hi+1
    assert int(low.n_launched) == n
    # distinct id windows -> distinct photon sets -> different grids
    assert not np.array_equal(np.asarray(straddle.energy),
                              np.asarray(low.energy))
    # the old wraparound made the post-wrap half replay ids 0..249: the
    # straddling window must differ from simulating ids 0..n-1 too
    zero = fn(labels, media, n, SEED, 0, 0)
    assert not np.array_equal(np.asarray(straddle.energy),
                              np.asarray(zero.energy))


def test_sub_2_32_ids_unchanged_by_id_offset_hi_plumbing():
    """id_offset_hi=0 (the default) is the historical engine: calling
    with and without the new argument is bit-identical."""
    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    fn = S.make_simulator(vol, cfg, LANES, source=SRC)
    labels, media = vol.labels.reshape(-1), vol.media
    a = fn(labels, media, 800, SEED, 123)
    b = fn(labels, media, 800, SEED, 123, 0)
    np.testing.assert_array_equal(np.asarray(a.energy), np.asarray(b.energy))
    np.testing.assert_array_equal(np.asarray(a.exitance),
                                  np.asarray(b.exitance))
    assert int(a.n_launched) == int(b.n_launched) == 800


def test_detected_records_reassembles_sharded_buffers():
    """simulate_sharded concatenates per-shard fixed-capacity buffers
    with a rank-1 det_rec_n; detected_records must slice each shard's
    valid prefix (exercised host-side — the live 8-device path is
    covered by test_multidevice)."""
    cap = 4
    shard0 = [[1, 0, 0, 0], [2, 0, 1, 0], [0, 0, 0, 0], [0, 0, 0, 0]]
    shard1 = [[7, 1, 0, 2], [0, 0, 0, 0], [0, 0, 0, 0], [0, 0, 0, 0]]
    res = S.SimResult(
        energy=np.zeros((2, 2, 2), np.float32),
        exitance=np.zeros((2, 2), np.float32),
        escaped_w=np.float32(0), n_launched=np.int32(0),
        launched_w=np.float32(0), steps=np.zeros((2,), np.int32),
        det_rec=np.asarray(shard0 + shard1, np.uint32),
        det_rec_n=np.asarray([2, 1], np.int32),
        det_rec_overflow=np.int32(0),
    )
    rec = detected_records(res)
    np.testing.assert_array_equal(
        rec, np.asarray([[1, 0, 0, 0], [2, 0, 1, 0], [7, 1, 0, 2]],
                        np.uint32))
    assert rec.shape[0] == 3 and cap == 4


# ---------------------------------------------------------------------------
# engine-pluggable, batched/sharded replay (DESIGN.md §replay)
# ---------------------------------------------------------------------------

def _b1_forward(seed=5, n_photons=400, cfg=None, lanes=128):
    vol = V.benchmark_b1(SHAPE)
    cfg = cfg or V.SimConfig(do_reflect=False)
    res = S.simulate(vol, cfg, n_photons, lanes, seed, source=SRC,
                     detectors=DETS, record_detected=2048)
    return res, vol, cfg


def test_replay_pallas_engine_matches_jnp():
    """The Pallas round executor replays bit-identical trajectories:
    per-record outputs equal the jnp engine exactly for any blocking,
    and the Jacobian is bit-equal when the grid is a single block (the
    in-kernel scatter then runs in the same order as the jnp rounds)."""
    res, vol, cfg, src, dets = _b2_forward()
    rec = detected_records(res)
    rj = replay_jacobian(vol, cfg, rec, dets, source=src, seed=SEED,
                         n_lanes=256, engine="jnp")
    rp = replay_jacobian(vol, cfg, rec, dets, source=src, seed=SEED,
                         n_lanes=256, engine="pallas", block_lanes=256)
    np.testing.assert_array_equal(rp.w_exit, rj.w_exit)
    np.testing.assert_array_equal(rp.gate, rj.gate)
    np.testing.assert_array_equal(rp.replayed_det, rj.replayed_det)
    np.testing.assert_array_equal(rp.jacobian, rj.jacobian)
    # multi-block grids reorder the in-kernel scatter across lane
    # blocks: per-record outputs stay bit-equal, the Jacobian agrees to
    # fp-accumulation order
    rp4 = replay_jacobian(vol, cfg, rec, dets, source=src, seed=SEED,
                          n_lanes=256, engine="pallas", block_lanes=64)
    np.testing.assert_array_equal(rp4.w_exit, rj.w_exit)
    np.testing.assert_array_equal(rp4.replayed_det, rj.replayed_det)
    np.testing.assert_allclose(rp4.jacobian, rj.jacobian,
                               rtol=1e-5, atol=1e-9)


def test_replay_gate_resolved_partitions_ungated():
    """gate_resolved=True widens the scatter to (nvox, n_det, ntg)
    keyed by each record's exit gate; the gates *partition* the
    scatter, so the gate-sum recovers the ungated Jacobian and the
    5-D medium sums keep the det_ppath identity."""
    cfg = V.SimConfig(do_reflect=False, steps_per_round=2, tmax_ns=0.5,
                      n_time_gates=4)
    res, vol, cfg = _b1_forward(seed=7, n_photons=1500, cfg=cfg, lanes=256)
    rec = detected_records(res)
    gates = np.unique(rec[:, 3])
    assert gates.size >= 2, "fixture must spread records over gates"
    rj = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=7,
                         n_lanes=256)
    rg = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=7,
                         n_lanes=256, gate_resolved=True)
    assert rg.jacobian.shape == SHAPE + (len(DETS), 4)
    np.testing.assert_array_equal(rg.w_exit, rj.w_exit)
    np.testing.assert_array_equal(rg.gate, rec[:, 3].astype(np.int32))
    np.testing.assert_allclose(rg.jacobian.sum(axis=-1), rj.jacobian,
                               rtol=2e-5, atol=1e-9)
    # gates with no records contribute empty slices
    for g in range(4):
        if g not in gates:
            assert np.abs(rg.jacobian[..., g]).max() == 0.0
    # 5-D medium sums: gate-summed identity vs the forward det_ppath,
    # and the per-gate variant partitions it
    M = A.jacobian_medium_sums(rg.jacobian, vol)
    np.testing.assert_allclose(M, np.asarray(res.det_ppath, np.float64),
                               rtol=1e-4, atol=1e-4)
    Mg = A.jacobian_medium_sums(rg.jacobian, vol, per_gate=True)
    assert Mg.shape == (len(DETS), 4, vol.media.shape[0])
    np.testing.assert_allclose(Mg.sum(axis=1), M)
    with pytest.raises(ValueError, match="per_gate"):
        A.jacobian_medium_sums(rj.jacobian, vol, per_gate=True)


def test_replay_gate_resolved_cw_is_bit_equal_ungated():
    """ntg=1 (CW): the gate-resolved scatter is the ungated scatter
    with a singleton gate axis — bit-for-bit."""
    res, vol, cfg = _b1_forward()
    rec = detected_records(res)
    rj = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=5,
                         n_lanes=128)
    rg = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=5,
                         n_lanes=128, gate_resolved=True)
    assert rg.jacobian.shape == SHAPE + (len(DETS), 1)
    np.testing.assert_array_equal(rg.jacobian[..., 0], rj.jacobian)


def test_replay_batch_padding_contributes_exactly_zero():
    """Regression for the batch-padding contract: padding lanes carry
    id (0, 0) with active=False and must contribute *exactly* zero —
    even when a real detected photon has id 0 (the padding id is not a
    sentinel; only the active mask separates them)."""
    res, vol, cfg = _b1_forward()  # seed 5: photon id 0 IS detected
    rec = detected_records(res)
    is_id0 = (rec[:, 0] == 0) & (rec[:, 1] == 0)
    assert is_id0.any(), \
        "fixture must detect photon id (0,0) — pick another seed"
    id0 = rec[is_id0]
    # 1 real lane + 7 padding lanes with the SAME id as the real one:
    # padding adds exact zeros, so the result is bit-equal to the
    # pad-free single-lane replay
    padded = replay_jacobian(vol, cfg, id0, DETS, source=SRC, seed=5,
                             n_lanes=8)
    alone = replay_jacobian(vol, cfg, id0, DETS, source=SRC, seed=5,
                            n_lanes=1)
    np.testing.assert_array_equal(padded.jacobian, alone.jacobian)
    np.testing.assert_array_equal(padded.w_exit, alone.w_exit)
    # a 5-record subset including id 0, padded to 8 lanes vs exact fit
    subset = rec[:5] if is_id0[:5].any() else np.concatenate(
        [id0[:1], rec[~is_id0][:4]])
    pad8 = replay_jacobian(vol, cfg, subset, DETS, source=SRC, seed=5,
                           n_lanes=8)
    fit5 = replay_jacobian(vol, cfg, subset, DETS, source=SRC, seed=5,
                           n_lanes=5)
    np.testing.assert_array_equal(pad8.jacobian, fit5.jacobian)


def test_replay_batch_size_invariance():
    """Replay is batched over fixed-size lane blocks; the per-record
    outputs are bit-invariant across batch sizes (trajectories depend
    only on the photon id) and the Jacobian agrees to fp-accumulation
    order."""
    res, vol, cfg = _b1_forward()
    rec = detected_records(res)
    assert rec.shape[0] > 64  # several batches at n_lanes=8
    r8 = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=5,
                         n_lanes=8)
    r64 = replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=5,
                          n_lanes=64)
    np.testing.assert_array_equal(r8.w_exit, r64.w_exit)
    np.testing.assert_array_equal(r8.gate, r64.gate)
    np.testing.assert_array_equal(r8.replayed_det, r64.replayed_det)
    np.testing.assert_allclose(r8.jacobian, r64.jacobian,
                               rtol=1e-5, atol=1e-9)


def test_truncated_records_replay_matches_ppath_subset():
    """det_rec_overflow semantics under replay: a deliberately tiny id
    buffer truncates the record list but not the aggregates; replaying
    the truncated records yields exactly the det_ppath share of those
    records (truncated + dropped = the full forward det_ppath)."""
    full, vol, cfg = _b1_forward()
    n_cap = int(full.det_rec_n)
    assert n_cap > 12
    cap = 8
    small = S.simulate(vol, cfg, 400, 128, 5, source=SRC, detectors=DETS,
                       record_detected=cap)
    assert int(small.det_rec_n) == cap
    assert int(small.det_rec_overflow) == n_cap - cap
    # aggregates are untouched by the truncation
    np.testing.assert_array_equal(np.asarray(small.det_w),
                                  np.asarray(full.det_w))
    np.testing.assert_array_equal(np.asarray(small.det_ppath),
                                  np.asarray(full.det_ppath))
    rec_full = detected_records(full)
    rec_small = detected_records(small)
    np.testing.assert_array_equal(rec_small, rec_full[:cap])
    # the truncated replay covers exactly its records' det_ppath share
    M_trunc = A.jacobian_medium_sums(
        replay_jacobian(vol, cfg, rec_small, DETS, source=SRC,
                        seed=5, n_lanes=64).jacobian, vol)
    M_rest = A.jacobian_medium_sums(
        replay_jacobian(vol, cfg, rec_full[cap:], DETS, source=SRC,
                        seed=5, n_lanes=64).jacobian, vol)
    ppath = np.asarray(full.det_ppath, np.float64)
    np.testing.assert_allclose(M_trunc + M_rest, ppath,
                               rtol=1e-4, atol=1e-4)
    assert (M_trunc <= ppath + 1e-6).all()


def test_replay_engine_and_gate_validation():
    res, vol, cfg = _b1_forward()
    rec = detected_records(res)
    with pytest.raises(ValueError, match="unknown engine"):
        replay_jacobian(vol, cfg, rec, DETS, source=SRC, seed=5,
                        engine="bogus")
    # gate-resolved replay refuses records whose gates exceed the cfg's
    # gate count (records from a different forward gate layout)
    bad = rec.copy()
    bad[0, 3] = 7
    with pytest.raises(ValueError, match="time gate 7"):
        replay_jacobian(vol, cfg, bad, DETS, source=SRC, seed=5,
                        gate_resolved=True)
