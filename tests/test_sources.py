"""Source subsystem tests: registry, determinism contract, physics.

Covers the DESIGN.md §sources guarantees: pure counter-seeded sampling
(photon id, not lane/device, determines the launch state), pencil-beam
bit-compatibility with the historical hard-coded launch, per-type weight
conservation through a full simulation, and id_offset-sharded launches
reproducing the single-device photon set for a non-pencil source.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sources as SRC
from repro.core import rng as xrng
from repro.core import simulator as S
from repro.core import volume as V

SHAPE = (16, 16, 16)
CENTER_FACE = (8.0, 8.0, 0.0)
CENTER = (8.0, 8.0, 8.0)

ALL_SOURCES = {
    "pencil": SRC.Pencil(pos=CENTER_FACE),
    "isotropic": SRC.IsotropicPoint(pos=CENTER),
    "cone": SRC.Cone(pos=CENTER_FACE, half_angle_deg=20.0),
    "gaussian": SRC.GaussianBeam(pos=CENTER_FACE, waist=2.0),
    "disk": SRC.Disk(pos=CENTER_FACE, radius=3.0),
    "planar": SRC.Planar(pos=(4.0, 4.0, 0.0), v1=(8.0, 0.0, 0.0),
                         v2=(0.0, 8.0, 0.0),
                         pattern=((1.0, 0.5), (0.25, 1.0))),
    "line_slit": SRC.Line(start=(4.0, 8.0, 0.0), end=(12.0, 8.0, 0.0)),
    "line_iso": SRC.Line(start=(4.0, 8.0, 8.0), end=(12.0, 8.0, 8.0),
                         dir=None),
}


# ---------------------------------------------------------------------------
# registry + serialization
# ---------------------------------------------------------------------------

def test_registry_lists_all_types():
    assert set(SRC.available_sources()) == {
        "pencil", "isotropic", "cone", "gaussian", "disk", "planar", "line",
    }


@pytest.mark.parametrize("name", sorted(ALL_SOURCES))
def test_config_roundtrip(name):
    src = ALL_SOURCES[name]
    d = SRC.to_dict(src)
    assert d["type"] == src.type_name
    # JSON-friendly: only lists/scalars/None in the payload
    import json
    json.dumps(d)
    assert SRC.from_dict(d) == src


def test_as_source_coercions():
    assert SRC.as_source(None) == SRC.Pencil()
    legacy = V.Source(pos=(5.0, 6.0, 0.0), dir=(0.0, 0.0, 1.0))
    assert SRC.as_source(legacy) == SRC.Pencil(pos=(5.0, 6.0, 0.0))
    disk = ALL_SOURCES["disk"]
    assert SRC.as_source(disk) is disk
    assert SRC.as_source(SRC.to_dict(disk)) == disk
    with pytest.raises(KeyError):
        SRC.from_dict({"type": "warp-drive"})
    with pytest.raises(TypeError):
        SRC.as_source(42)
    # list-typed fields are normalized to tuples so sources stay hashable
    # (jit caches in ChunkScheduler key on the source instance)
    listy = SRC.Planar(pos=[4.0, 4.0, 0.0], v1=[8.0, 0.0, 0.0],
                       v2=[0.0, 8.0, 0.0], pattern=[[1.0, 0.5], [0.5, 1.0]])
    norm = SRC.as_source(listy)
    hash(norm)
    assert norm == SRC.Planar(pos=(4.0, 4.0, 0.0), v1=(8.0, 0.0, 0.0),
                              v2=(0.0, 8.0, 0.0),
                              pattern=((1.0, 0.5), (0.5, 1.0)))


# ---------------------------------------------------------------------------
# determinism contract
# ---------------------------------------------------------------------------

def test_pencil_matches_historical_launch():
    """Pencil sampling is bit-identical to the pre-subsystem hard-coded
    launch: broadcast pos/dir, unit weights, unsalted counter RNG."""
    src = ALL_SOURCES["pencil"]
    ids = jnp.arange(64, dtype=jnp.uint32)
    pos, direc, w0, rng = src.sample(ids, jnp.uint32(99))
    np.testing.assert_array_equal(
        np.asarray(pos), np.full((64, 3), CENTER_FACE, np.float32))
    np.testing.assert_array_equal(
        np.asarray(direc), np.broadcast_to([0.0, 0.0, 1.0], (64, 3)))
    np.testing.assert_array_equal(np.asarray(w0), np.ones(64, np.float32))
    np.testing.assert_array_equal(
        np.asarray(rng), np.asarray(xrng.seed_state(jnp.uint32(99), ids)))


@pytest.mark.parametrize("name", sorted(ALL_SOURCES))
def test_sample_is_pure_in_photon_id(name):
    """Row k of sample(ids) depends only on ids[k] — lane order, batch
    size, and shard boundaries cannot change any photon's launch state."""
    src = ALL_SOURCES[name]
    seed = jnp.uint32(7)
    ids = jnp.arange(40, dtype=jnp.uint32)
    perm = np.random.default_rng(0).permutation(40)
    ref = src.sample(ids, seed)
    shuffled = src.sample(ids[perm], seed)
    for a, b in zip(ref, shuffled):
        np.testing.assert_array_equal(np.asarray(a)[perm], np.asarray(b))
    # and a disjoint id window sampled separately matches the full window
    tail = src.sample(ids[25:], seed)
    for a, b in zip(ref, tail):
        np.testing.assert_array_equal(np.asarray(a)[25:], np.asarray(b))


@pytest.mark.parametrize("name", sorted(ALL_SOURCES))
def test_sample_geometry(name):
    src = ALL_SOURCES[name]
    pos, direc, w0, _ = src.sample(jnp.arange(500, dtype=jnp.uint32),
                                   jnp.uint32(3))
    norms = np.linalg.norm(np.asarray(direc), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
    w = np.asarray(w0)
    assert np.all(w >= 0.0) and np.all(w <= 1.0)
    p = np.asarray(pos)
    if name == "cone":
        cost = np.asarray(direc)[:, 2]  # axis is +z
        assert np.all(cost >= np.cos(np.radians(20.0)) - 1e-5)
    if name == "disk":
        r = np.linalg.norm(p - np.asarray(CENTER_FACE), axis=-1)
        assert np.all(r <= 3.0 + 1e-5)
    if name == "planar":
        assert np.all(p[:, 0] >= 4.0 - 1e-5) and np.all(p[:, 0] <= 12.0 + 1e-5)
        assert np.all(p[:, 1] >= 4.0 - 1e-5) and np.all(p[:, 1] <= 12.0 + 1e-5)
        assert len(np.unique(w)) > 1  # pattern actually modulates weights


# ---------------------------------------------------------------------------
# full-simulation physics
# ---------------------------------------------------------------------------

def _launched_weight(src, n, seed):
    ids = jnp.arange(n, dtype=jnp.uint32)
    return float(jnp.sum(src.sample(ids, jnp.uint32(seed))[2]))


@pytest.mark.parametrize("name", sorted(ALL_SOURCES))
def test_weight_conservation(name):
    """deposited + escaped ≈ launched weight once every photon terminates
    (roulette is unbiased; residue is statistical only)."""
    src = ALL_SOURCES[name]
    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    n = 1500
    res = S.simulate(vol, cfg, n, 512, 11, source=src)
    jax.block_until_ready(res)
    assert int(res.n_launched) == n
    launched = _launched_weight(src, n, 11)
    # the engine's launched-weight accumulator matches the analytic sum
    np.testing.assert_allclose(float(res.launched_w), launched, rtol=1e-6)
    total = float(jnp.sum(res.energy)) + float(res.escaped_w)
    assert abs(total - launched) / launched < 5e-3, (total, launched)


def test_sharded_id_offset_reproduces_single_run():
    """Two id_offset-sharded launches of a non-pencil source reproduce the
    single-device photon set (DESIGN.md §determinism + §sources)."""
    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    src = ALL_SOURCES["disk"]
    labels, media = vol.labels.reshape(-1), vol.media
    n = 2000
    fn = jax.jit(S.build_sim_fn(SHAPE, vol.unitinmm, cfg, 512,
                                source=src))
    full = fn(labels, media, n, 5)
    half_a = fn(labels, media, n // 2, 5, 0)
    half_b = fn(labels, media, n // 2, 5, n // 2)
    jax.block_until_ready((full, half_a, half_b))
    assert int(half_a.n_launched) + int(half_b.n_launched) == n
    merged = np.asarray(half_a.energy) + np.asarray(half_b.energy)
    ref = np.asarray(full.energy)
    rel = np.abs(merged - ref).max() / ref.max()
    assert rel < 1e-3, rel
    esc = float(half_a.escaped_w) + float(half_b.escaped_w)
    np.testing.assert_allclose(esc, float(full.escaped_w), rtol=1e-4)


def test_out_of_domain_launches_are_clamped():
    """Launch positions sampled outside the volume are clamped onto the
    boundary (photon.launch): the run still terminates and conserves
    weight instead of mis-depositing from inconsistent pos/ivox lanes."""
    from repro.core import photon as ph

    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    # disk overhanging the x=0 face + wide Gaussian tails
    for src in (SRC.Disk(pos=(1.0, 8.0, 0.0), radius=5.0),
                SRC.GaussianBeam(pos=(8.0, 8.0, 0.0), waist=10.0)):
        ids = jnp.arange(400, dtype=jnp.uint32)
        pos, direc, w0, rng = src.sample(ids, jnp.uint32(2))
        state = ph.launch(pos, direc, w0, rng, jnp.ones((400,), bool), SHAPE)
        p = np.asarray(state.pos)
        assert p.min() >= 0.0 and np.all(p <= np.asarray(SHAPE, np.float32))
        np.testing.assert_array_equal(
            np.asarray(state.ivox),
            np.clip(np.floor(p).astype(np.int32), 0,
                    np.asarray(SHAPE, np.int32) - 1))
        res = S.simulate(vol, cfg, 800, 256, 2, source=src)
        jax.block_until_ready(res)
        launched = _launched_weight(src, 800, 2)
        total = float(jnp.sum(res.energy)) + float(res.escaped_w)
        assert abs(total - launched) / launched < 5e-3


def test_energy_balance_uses_launched_weight():
    """energy_balance must balance against launched *weight*, not photon
    count — a Planar pattern source launches well below 1.0 per photon."""
    from repro.core import analysis as A

    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    src = ALL_SOURCES["planar"]
    res = S.simulate(vol, cfg, 1500, 512, 11, source=src)
    jax.block_until_ready(res)
    bal = A.energy_balance(res)
    assert bal["launched"] < 1500 * 0.95  # pattern weights pull it down
    assert abs(bal["residue_frac"]) < 5e-3, bal


def test_elastic_checkpoint_rejects_source_mismatch():
    from repro.core.multidevice import ElasticSimulator

    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    es = ElasticSimulator(vol, cfg, 800, 400, n_lanes=256, seed=3,
                          source=ALL_SOURCES["disk"])
    es.run_round(max_chunks=1)
    state = es.state_dict()
    es2 = ElasticSimulator(vol, cfg, 800, 400, n_lanes=256, seed=3)  # pencil
    with pytest.raises(AssertionError, match="source mismatch"):
        es2.load_state_dict(state)
    es3 = ElasticSimulator(vol, cfg, 800, 400, n_lanes=256, seed=3,
                           source=ALL_SOURCES["disk"])
    es3.load_state_dict(state)
    res = es3.run_to_completion()
    assert int(res.n_launched) == 800


def test_elastic_checkpoint_roundtrips_through_checkpointer(tmp_path):
    """Every state_dict leaf must stay a numeric array the project
    Checkpointer can write to npz — including the encoded source key."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core.multidevice import ElasticSimulator

    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    es = ElasticSimulator(vol, cfg, 800, 400, n_lanes=256, seed=3,
                          source=ALL_SOURCES["disk"])
    es.run_round(max_chunks=1)
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, es.state_dict())
    step, restored = ckpt.restore(es.state_dict())
    assert step == 1
    es2 = ElasticSimulator(vol, cfg, 800, 400, n_lanes=256, seed=3,
                           source=ALL_SOURCES["disk"])
    es2.load_state_dict(restored)
    res = es2.run_to_completion()
    assert int(res.n_launched) == 800
    np.testing.assert_allclose(
        float(res.launched_w) - float(jnp.sum(res.energy))
        - float(res.escaped_w), 0.0, atol=5.0)


def test_non_pencil_source_changes_fluence():
    """Different sources must actually produce different light fields."""
    vol = V.benchmark_b1(SHAPE)
    cfg = V.SimConfig(do_reflect=False)
    a = S.simulate(vol, cfg, 800, 256, 3, source=ALL_SOURCES["pencil"])
    b = S.simulate(vol, cfg, 800, 256, 3, source=ALL_SOURCES["isotropic"])
    assert not np.allclose(np.asarray(a.energy), np.asarray(b.energy))
