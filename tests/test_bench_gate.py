"""Perf-regression CI gate (benchmarks/check_regression.py).

Host-side only — no jax.  Pins the gate's decision rules: throughput
leaves (photons_per_s / records_per_s / scenarios_per_s at any depth)
fail on a >30% drop, overhead leaves (*_overhead_frac) fail on a
>10-point growth, cache-efficiency leaves (*_hit_rate) fail on ANY drop
below baseline, fresh-only *gated* leaves fail loudly (a new gated
metric must land with a baseline refresh), cold-start keys and other
one-sided keys are ignored, and workload-mismatched files are skipped
rather than compared.
"""

import copy
import json

import pytest

from benchmarks.check_regression import check_file, main

BASE = {
    "meta": {"bench": "B2", "quick": True, "size": 20, "backend": "cpu"},
    "engines": {
        "jnp": {
            "photons_per_s_record_on": 1000.0,
            "recording_overhead_frac": 0.10,
            "records": 377,
        },
    },
    "replay": {
        "engines": {
            "jnp": {"records_per_s": 200.0, "records_per_s_cold": 50.0},
        },
    },
}


def test_identical_files_pass():
    failures, notes = check_file("BENCH_x.json", BASE, copy.deepcopy(BASE),
                                 0.30, 0.10)
    assert failures == []
    assert any("checked" in n for n in notes)


def test_throughput_drop_fails_and_small_drop_passes():
    fresh = copy.deepcopy(BASE)
    fresh["engines"]["jnp"]["photons_per_s_record_on"] = 650.0  # -35%
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1 and "photons_per_s_record_on" in failures[0]
    fresh["engines"]["jnp"]["photons_per_s_record_on"] = 750.0  # -25%
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert failures == []


def test_nested_records_per_s_is_gated_but_cold_is_not():
    fresh = copy.deepcopy(BASE)
    fresh["replay"]["engines"]["jnp"]["records_per_s"] = 100.0   # -50%
    fresh["replay"]["engines"]["jnp"]["records_per_s_cold"] = 1.0
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1
    assert "records_per_s " in failures[0] + " "
    assert all("cold" not in f for f in failures)


def test_overhead_growth_fails_in_points_not_ratio():
    fresh = copy.deepcopy(BASE)
    fresh["engines"]["jnp"]["recording_overhead_frac"] = 0.19  # +9 points
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert failures == []
    fresh["engines"]["jnp"]["recording_overhead_frac"] = 0.21  # +11 points
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1 and "recording_overhead_frac" in failures[0]


def test_workload_mismatch_skips_instead_of_comparing():
    fresh = copy.deepcopy(BASE)
    fresh["meta"]["quick"] = False
    fresh["engines"]["jnp"]["photons_per_s_record_on"] = 1.0  # huge "drop"
    failures, notes = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert failures == []
    assert any("SKIPPED" in n and "quick" in n for n in notes)


def test_one_sided_keys_are_ignored_unless_gated():
    # baseline-only gated key + fresh-only NON-gated key: both ignored
    fresh = copy.deepcopy(BASE)
    del fresh["replay"]["engines"]["jnp"]["records_per_s"]
    fresh["engines"]["jnp"]["new_records_count"] = 42
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert failures == []


def test_fresh_only_gated_key_fails_loudly():
    """A fresh file adding a gated metric the baseline lacks must fail
    and demand a baseline refresh — otherwise the new metric rides
    ungated until someone remembers to regenerate."""
    fresh = copy.deepcopy(BASE)
    fresh["engines"]["pallas"] = {"photons_per_s_record_on": 1.0}
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1
    assert "engines.pallas.photons_per_s_record_on" in failures[0]
    assert "regenerate the baseline" in failures[0]
    # same for a fresh-only hit-rate leaf
    fresh = copy.deepcopy(BASE)
    fresh["engines"]["jnp"]["cache_hit_rate"] = 1.0
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1 and "cache_hit_rate" in failures[0]


def test_hit_rate_fails_on_any_drop():
    """*_hit_rate is a deterministic cache-ledger ratio, not a timing:
    no 30% headroom — any value below baseline is a caching bug."""
    base = copy.deepcopy(BASE)
    base["engines"]["jnp"]["cache_hit_rate"] = 1.0
    fresh = copy.deepcopy(base)
    fresh["engines"]["jnp"]["cache_hit_rate"] = 0.95  # tiny drop: FAIL
    failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert len(failures) == 1
    assert "cache_hit_rate" in failures[0]
    assert "compile cache" in failures[0]
    # equal or better passes
    for ok in (1.0, 1.0 + 1e-12):
        fresh["engines"]["jnp"]["cache_hit_rate"] = ok
        failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
        assert failures == []


@pytest.mark.parametrize("regress", [False, True])
def test_main_exit_codes(tmp_path, regress):
    base_dir = tmp_path / "base"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()
    fresh = copy.deepcopy(BASE)
    if regress:
        fresh["engines"]["jnp"]["photons_per_s_record_on"] = 1.0
    (base_dir / "BENCH_replay.json").write_text(json.dumps(BASE))
    (fresh_dir / "BENCH_replay.json").write_text(json.dumps(fresh))
    rc = main(["--baseline", str(base_dir), "--fresh", str(fresh_dir)])
    assert rc == (1 if regress else 0)


def test_main_fails_when_fresh_file_missing(tmp_path):
    base_dir = tmp_path / "base"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()
    (base_dir / "BENCH_fused.json").write_text(json.dumps(BASE))
    rc = main(["--baseline", str(base_dir), "--fresh", str(fresh_dir)])
    assert rc == 1


def test_negative_overhead_baseline_is_floored_at_zero():
    """A negative baseline overhead is a timing-noise fluke; growth is
    gated against max(baseline, 0) so a representative fresh value
    (e.g. +0.09) still passes."""
    base = copy.deepcopy(BASE)
    base["engines"]["jnp"]["recording_overhead_frac"] = -0.09
    fresh = copy.deepcopy(BASE)
    fresh["engines"]["jnp"]["recording_overhead_frac"] = 0.09
    failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert failures == []
    fresh["engines"]["jnp"]["recording_overhead_frac"] = 0.11
    failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert len(failures) == 1


def test_machine_mismatch_notes_but_still_compares():
    fresh = copy.deepcopy(BASE)
    base = copy.deepcopy(BASE)
    base["meta"]["machine"] = "x86_64"
    fresh["meta"]["machine"] = "aarch64"
    fresh["engines"]["jnp"]["photons_per_s_record_on"] = 100.0
    failures, notes = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert len(failures) == 1  # compared despite the machine change
    assert any("machine" in n for n in notes)


def test_schema_version_mismatch_fails_loudly():
    """A layout change must surface as a gate FAILURE demanding a
    baseline refresh — never as a silent skip or a one-sided-key
    ignore."""
    base = copy.deepcopy(BASE)
    base["meta"]["schema_version"] = 2
    fresh = copy.deepcopy(base)
    fresh["meta"]["schema_version"] = 3
    # make the workload mismatch too: version must win over the skip
    fresh["meta"]["quick"] = False
    failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert len(failures) == 1
    assert "schema_version mismatch" in failures[0]
    assert "refresh" in failures[0]
    # a baseline written before versioning vs a versioned fresh file is
    # itself a version mismatch (None vs 2)
    failures, _ = check_file("BENCH_x.json", BASE, fresh, 0.30, 0.10)
    assert len(failures) == 1 and "schema_version mismatch" in failures[0]
    # matching versions compare as before
    fresh = copy.deepcopy(base)
    failures, _ = check_file("BENCH_x.json", base, fresh, 0.30, 0.10)
    assert failures == []
