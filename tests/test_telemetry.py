"""Simulation telemetry (DESIGN.md §observability).

Contracts under test:

  * ``collect_stats=True`` changes NO physics bit: energy, exitance,
    escaped_w, timed_out_w, n_launched, launched_w and steps are
    bit-identical to the stats-off run — for both round executors and
    for K in {1, 4}.
  * ``SimResult.stats`` reconciles with the energy-balance identity:
    relaunched == n_launched, escaped_w / timed_out_w are bit-equal to
    the SimResult fields, deposited_w matches sum(energy) to fp
    accumulation order, detected_w matches sum(det_w), and
    lane_segments == steps * n_lanes.
  * The Tracer's span timeline round-trips through Chrome trace JSON
    and feeds ``loadbalance.fit_pilot`` as measured-throughput samples
    (the dispatch -> measure -> refit -> re-partition loop).
  * The CLI surfaces the silent-loss warnings (timed-out weight,
    detector id-buffer overflow) and writes trace/metrics files that
    parse back into device models.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import loadbalance as LB
from repro.core import simulator as S
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler, ElasticSimulator
from repro.detectors import Detector
from repro.launch import simulate as CLI
from repro.telemetry import (InMemorySink, JsonlSink, RoundStats, SpanEvent,
                             Tracer, chrome_trace, device_label,
                             fit_device_models, load_chrome_trace)

SHAPE = (16, 16, 16)
N_PHOTONS = 2000
LANES = 256
SEED = 9


def _bench(reflect=False):
    vol = V.benchmark_b2(SHAPE) if reflect else V.benchmark_b1(SHAPE)
    return vol, V.SimConfig(do_reflect=reflect)


def _run(vol, cfg, engine="jnp", **kw):
    return S.simulate(vol, cfg, N_PHOTONS, LANES, SEED, engine=engine, **kw)


# ---------------------------------------------------------------------------
# RoundStats: bit-identical physics + counter reconciliation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [1, 4])
def test_collect_stats_changes_no_physics_bit(engine, k):
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, steps_per_round=k)
    off = _run(vol, cfg, engine)
    on = _run(vol, dataclasses.replace(cfg, collect_stats=True), engine)
    assert off.stats is None and on.stats is not None
    np.testing.assert_array_equal(np.asarray(off.energy),
                                  np.asarray(on.energy))
    np.testing.assert_array_equal(np.asarray(off.exitance),
                                  np.asarray(on.exitance))
    for field in ("escaped_w", "timed_out_w", "launched_w"):
        assert float(getattr(off, field)) == float(getattr(on, field)), field
    assert int(off.n_launched) == int(on.n_launched)
    assert int(off.steps) == int(on.steps)


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_round_stats_reconcile_with_energy_balance(engine):
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, steps_per_round=4, collect_stats=True)
    res = _run(vol, cfg, engine)
    st = res.stats
    bal = A.energy_balance(res)
    # exact photon accounting: every launch goes through regeneration
    assert int(st.relaunched) == int(res.n_launched) == N_PHOTONS
    assert int(st.rounds) == int(res.steps) // 4
    assert 0 < int(st.regen_rounds) <= int(st.rounds)
    # retired-weight counters mirror the physics accumulators bit-exactly
    assert float(st.escaped_w) == float(res.escaped_w) == bal["escaped"]
    assert float(st.timed_out_w) == float(res.timed_out_w)
    # deposited weight re-sums the same per-segment deposits the energy
    # grid scatters, so it agrees to fp accumulation order
    np.testing.assert_allclose(float(st.deposited_w), bal["absorbed"],
                               rtol=1e-5)
    # the counters close the balance like the grids do, up to the
    # statistical Russian-roulette residue
    total = (float(st.deposited_w) + float(st.escaped_w)
             + float(st.timed_out_w))
    np.testing.assert_allclose(total, bal["launched"], rtol=5e-4)
    # occupancy bookkeeping: denominator is exactly steps * n_lanes
    assert float(st.lane_segments) == float(int(res.steps) * LANES)
    assert 0.0 < st.lane_occupancy() <= 1.0


def test_round_stats_engine_and_k_invariant_live_segments():
    """live_segments counts id-keyed trajectory segments, so it is
    invariant across engines and K (trajectories are identical)."""
    vol, cfg0 = _bench()
    vals = set()
    for engine in ("jnp", "pallas"):
        for k in (1, 4):
            cfg = dataclasses.replace(cfg0, steps_per_round=k,
                                      collect_stats=True)
            vals.add(float(_run(vol, cfg, engine).stats.live_segments))
    assert len(vals) == 1, vals


def test_round_stats_detected_w_reconciles():
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, collect_stats=True)
    dets = (Detector(SHAPE[0] / 2.0, SHAPE[1] / 2.0, SHAPE[0] / 2.0),)
    res = _run(vol, cfg, detectors=dets)
    assert float(np.asarray(res.det_w).sum()) > 0
    np.testing.assert_allclose(float(res.stats.detected_w),
                               float(np.asarray(res.det_w).sum()), rtol=1e-5)


def test_round_stats_host_merge_helpers():
    a = RoundStats.zeros()
    assert a.lane_occupancy() == 0.0
    b = RoundStats.from_vector([2, 1, 100, 50.0, 200.0, 1.5, 2.5, 0.5, 0.25])
    m = a.add(b).add(b)
    assert int(m.rounds) == 4 and int(m.relaunched) == 200
    assert float(m.live_segments) == 100.0
    assert m.lane_occupancy() == pytest.approx(0.25)
    d = m.to_dict()
    assert isinstance(d["rounds"], int)
    assert d["lane_occupancy"] == pytest.approx(0.25)
    # round-trips through the checkpoint vector form
    rt = RoundStats.from_vector([float(v) for v in m])
    assert rt == m


# ---------------------------------------------------------------------------
# Tracer, sinks, Chrome trace round-trip, device-model fitting
# ---------------------------------------------------------------------------

def test_tracer_spans_and_sinks(tmp_path):
    mem = InMemorySink()
    jsonl = JsonlSink(tmp_path / "m.jsonl")
    tr = Tracer(sinks=[mem, jsonl])
    with tr.span("chunk", device="cpu:0", engine="jnp", photons=100):
        pass
    sp = tr.span("chunk", device="cpu:1", engine="jnp", photons=50)
    sp.end(overflow=3)
    tr.counter("photons_per_s", 123.0, bench="B1")
    jsonl.close()
    assert len(tr.events) == 2
    assert tr.events[1].args["overflow"] == 3
    assert [e["type"] for e in mem.events] == ["span", "span", "counter"]
    lines = [json.loads(line)
             for line in (tmp_path / "m.jsonl").read_text().splitlines()]
    assert len(lines) == 3 and lines[2]["value"] == 123.0
    # throughput is derived from the photons arg and the measured span
    assert tr.events[0].photons_per_s > 0


def test_chrome_trace_round_trip(tmp_path):
    events = [
        SpanEvent("chunk", "cpu:0", t0=1.0, dur=0.5, engine="jnp",
                  args={"photons": 1000}),
        SpanEvent("chunk", "cpu:1", t0=1.2, dur=0.25, engine="jnp",
                  args={"photons": 500}),
    ]
    obj = chrome_trace(events)
    # one viewer thread per device, named via metadata rows
    names = {r["args"]["name"] for r in obj["traceEvents"]
             if r.get("ph") == "M" and r["name"] == "thread_name"}
    assert names == {"cpu:0", "cpu:1"}
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(obj))
    back = load_chrome_trace(path)
    assert {(e.name, e.device, e.engine) for e in back} == \
        {("chunk", "cpu:0", "jnp"), ("chunk", "cpu:1", "jnp")}
    by_dev = {e.device: e for e in back}
    assert by_dev["cpu:0"].args["photons"] == 1000
    assert by_dev["cpu:0"].dur == pytest.approx(0.5, rel=1e-6)


def test_fit_device_models_pilot_and_throughput_fallback():
    # two distinct chunk sizes -> the full T = a*n + T0 pilot fit
    ev = [SpanEvent("chunk", "tpu:0", 0.0, 0.1 + 1e-4 * n,
                    args={"photons": n}) for n in (1000, 4000, 8000)]
    # equal chunk sizes -> aggregate-throughput fallback (t0 = 0)
    ev += [SpanEvent("chunk", "tpu:1", 0.0, 0.5, args={"photons": 1000}),
           SpanEvent("chunk", "tpu:1", 1.0, 0.5, args={"photons": 1000})]
    models = fit_device_models(ev, name="chunk")
    assert set(models) == {"tpu:0", "tpu:1"}
    assert models["tpu:0"].a == pytest.approx(1e-4, rel=1e-3)
    assert models["tpu:0"].t0 == pytest.approx(0.1, rel=1e-3)
    assert models["tpu:1"].t0 == 0.0
    assert models["tpu:1"].a == pytest.approx(1.0 / 1000 * 0.5 * 2 / 2)
    # the fits plug straight into the paper's partitioners
    part = LB.partition_s2(10_000, list(models.values()))
    assert sum(part) == 10_000 and all(p >= 0 for p in part)


def test_device_label():
    assert device_label(None) == "host"
    assert device_label("mesh") == "mesh"
    d = jax.devices()[0]
    assert device_label(d) == f"{d.platform}:{d.id}"


# ---------------------------------------------------------------------------
# Schedulers: chunk spans + merged stats
# ---------------------------------------------------------------------------

def test_chunk_scheduler_trace_and_stats_merge():
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, collect_stats=True)
    tr = Tracer(sinks=[InMemorySink()])
    sched = ChunkScheduler(vol, cfg, n_lanes=LANES, tracer=tr)
    res, per_dev = sched.run(N_PHOTONS, chunk_size=500, seed=SEED)
    spans = [e for e in tr.events if e.name == "chunk"]
    assert len(spans) == 4
    assert {e.device for e in spans} <= \
        {device_label(d) for d in jax.devices()}
    assert sum(e.args["photons"] for e in spans) == N_PHOTONS
    # merged counters keep exact photon accounting across chunks
    assert int(res.stats.relaunched) == int(res.n_launched) == N_PHOTONS
    # chunked run matches the single-shot physics (id-keyed photons)
    ref = _run(vol, dataclasses.replace(cfg, collect_stats=False))
    np.testing.assert_allclose(np.asarray(res.energy), np.asarray(ref.energy),
                               rtol=5e-5, atol=1e-5)
    # ...and its spans fit device models the partitioners accept
    models = fit_device_models(tr.events, name="chunk")
    assert models
    part = LB.partition_s2(N_PHOTONS, list(models.values()))
    assert sum(part) == N_PHOTONS


def test_elastic_simulator_stats_checkpoint_roundtrip():
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, collect_stats=True)
    tr = Tracer()
    sim = ElasticSimulator(vol, cfg, N_PHOTONS, chunk_size=500,
                           n_lanes=LANES, seed=SEED, tracer=tr)
    sim.run_round()
    state = sim.state_dict()
    assert "stats" in state
    res = sim.run_to_completion()
    assert int(res.stats.relaunched) == N_PHOTONS
    assert len([e for e in tr.events if e.name == "chunk"]) == 4
    # restart from the checkpoint: stats resume mid-campaign
    sim2 = ElasticSimulator(vol, cfg, N_PHOTONS, chunk_size=500,
                            n_lanes=LANES, seed=SEED)
    sim2.load_state_dict(state)
    res2 = sim2.run_to_completion()
    assert int(res2.stats.relaunched) == N_PHOTONS
    np.testing.assert_array_equal(np.asarray(res.energy),
                                  np.asarray(res2.energy))
    for a, b in zip(res.stats, res2.stats):
        assert float(a) == float(b)


# ---------------------------------------------------------------------------
# CLI: loss warnings + trace/metrics files (the end-to-end loop)
# ---------------------------------------------------------------------------

_CLI_BASE = ["--bench", "B1", "--size", "16", "--photons", "800",
             "--lanes", "128", "--seed", "3"]


def test_cli_warns_on_timed_out_weight(capsys):
    CLI.main(_CLI_BASE + ["--tmax-ns", "0.02"])
    out = capsys.readouterr().out
    assert "WARNING" in out and "tmax" in out
    assert "retired" in out


def test_cli_no_timeout_warning_by_default(capsys):
    CLI.main(_CLI_BASE)
    out = capsys.readouterr().out
    assert "WARNING" not in out


def test_cli_warns_on_detector_record_overflow(capsys):
    # B2 (mismatched boundary, reflection on) backscatters enough weight
    # into the z=0 disk to overrun a tiny id buffer
    det = json.dumps([{"x": 8, "y": 8, "radius": 8}])
    CLI.main(["--bench", "B2", "--size", "16", "--photons", "800",
              "--lanes", "128", "--seed", "3",
              "--detectors", det, "--save-detected", "8"])
    out = capsys.readouterr().out
    assert "WARNING" in out and "overflow" in out
    assert "raise --save-detected" in out


def test_cli_trace_metrics_feed_load_balancer(tmp_path, capsys):
    """The acceptance loop: a chunked CLI run's --trace-out spans
    round-trip into loadbalance device models."""
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.jsonl"
    CLI.main(_CLI_BASE + ["--chunk", "200", "--collect-stats",
                          "--trace-out", str(trace),
                          "--metrics-out", str(metrics)])
    out = capsys.readouterr().out
    assert "round stats:" in out and "lane occupancy" in out
    events = load_chrome_trace(trace)
    spans = [e for e in events if e.name == "chunk"]
    assert len(spans) == 4
    for d in jax.devices():
        assert any(e.device == device_label(d) for e in spans)
    models = fit_device_models(events, name="chunk")
    assert models
    part = LB.partition_s2(4000, list(models.values()))
    assert sum(part) == 4000
    recs = [json.loads(line)
            for line in metrics.read_text().splitlines()]
    assert any(r["type"] == "span" for r in recs)
    names = {r["name"] for r in recs if r["type"] == "counter"}
    assert "photons_per_s" in names
    assert "round_stats.lane_occupancy" in names
