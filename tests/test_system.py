"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V
from repro.models.config import SHAPES


def test_full_b2a_pipeline():
    """The paper's B2a benchmark end to end on a reduced domain:
    simulate, normalize, validate energy + fluence structure."""
    vol = V.benchmark_b2((30, 30, 30))
    cfg = V.b2_config()
    src_beam = V.Source(pos=(15.0, 15.0, 0.0))  # face center of the 30^3 cube
    res = S.simulate(vol, cfg, n_photons=10_000, n_lanes=1024, seed=21,
                     source=src_beam)
    jax.block_until_ready(res)
    bal = A.energy_balance(res)
    assert abs(bal["residue_frac"]) < 1e-4
    phi = np.asarray(A.fluence_cw(res, vol))
    assert np.all(np.isfinite(phi)) and phi.max() > 0
    # fluence peaks near the source and decays into the depth
    src = phi[13:18, 13:18, 0:3].sum()
    deep = phi[13:18, 13:18, 25:28].sum()
    assert src > deep > 0


def test_config_registry_complete():
    assert len(C.ARCH_IDS) == 10
    cells = C.cells()
    assert len(cells) == 33  # 40 - 7 documented long_500k skips
    assert len(C.cells(include_skipped=True)) == 40
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)
        smoke = C.get_smoke_config(arch)
        assert cfg.kind == smoke.kind  # same family, reduced size
        assert smoke.n_layers <= 4 and smoke.d_model <= 256


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_assigned_arch_dimensions():
    cfg = C.get_config("deepseek-v3-671b")
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads) == (61, 7168, 128)
    assert (cfg.n_experts, cfg.top_k, cfg.n_shared_experts) == (256, 8, 1)
    cfg = C.get_config("mixtral-8x7b")
    assert (cfg.n_experts, cfg.top_k, cfg.sliding_window) == (8, 2, 4096)
    cfg = C.get_config("granite-20b")
    assert cfg.n_kv_heads == 1  # MQA
    cfg = C.get_config("mamba2-1.3b")
    assert (cfg.n_layers, cfg.d_model, cfg.ssm_state) == (48, 2048, 128)
    cfg = C.get_config("hymba-1.5b")
    assert (cfg.n_heads, cfg.n_kv_heads, cfg.meta_tokens) == (25, 5, 128)
