"""End-to-end behaviour tests for the paper's system."""

import jax
import numpy as np

from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V


def test_full_b2a_pipeline():
    """The paper's B2a benchmark end to end on a reduced domain:
    simulate, normalize, validate energy + fluence structure."""
    vol = V.benchmark_b2((30, 30, 30))
    cfg = V.b2_config()
    src_beam = V.Source(pos=(15.0, 15.0, 0.0))  # face center of the 30^3 cube
    res = S.simulate(vol, cfg, n_photons=10_000, n_lanes=1024, seed=21,
                     source=src_beam)
    jax.block_until_ready(res)
    bal = A.energy_balance(res)
    assert abs(bal["residue_frac"]) < 1e-4
    phi = np.asarray(A.fluence_cw(res, vol))
    assert np.all(np.isfinite(phi)) and phi.max() > 0
    # fluence peaks near the source and decays into the depth
    src = phi[13:18, 13:18, 0:3].sum()
    deep = phi[13:18, 13:18, 25:28].sum()
    assert src > deep > 0
