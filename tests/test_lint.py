"""reprolint rule tests: per-rule positive/negative fixtures, pragma
suppression, baseline round-trip, and the live-repo-clean meta-test.

Fixture trees are written under tmp_path with the real repo layout
(src/repro/..., benchmarks/, tests/) and linted with ``rule_ids``
isolation so one rule's fixture never trips another rule.
"""

import json
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

try:  # hypothesis is optional locally (pinned in CI); only the property
    # test needs it — the deterministic mutation tests always run
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

from repro.lint import normalize_line, run_lint
from repro.lint.baseline import (baseline_path, load_baseline,
                                 save_baseline)

REPO = Path(__file__).resolve().parents[1]
SPEC = REPO / "src" / "repro" / "kernels" / "photon_step" / "spec.py"


def _write(root: Path, rel: str, text: str) -> Path:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))
    return p


def _lint(root, *rule_ids, baseline=None):
    return run_lint(root, rule_ids=rule_ids or None, baseline=baseline)


# ---------------------------------------------------------------- REP101

_OPS_SRC = """\
    import functools

    import jax


    @functools.partial(jax.jit, static_argnames=(
        "shape", "unitinmm", "cfg", "n_steps", "block_lanes",
        "interpret", "record", "jac_cols", "stats"))
    def _photon_steps_jit(labels_flat, media, state, shape, unitinmm,
                          cfg, n_steps, block_lanes, interpret,
                          ppath=None, det_geom=None, record=False,
                          jac_w=None, jac_col=None, jac_cols=0,
                          stats=False):
        return None


    def photon_steps(labels_flat, media, state, shape, unitinmm, cfg,
                     n_steps, block_lanes=256, interpret=None,
                     ppath=None, det_geom=None, record=False,
                     jac_w=None, jac_col=None, jac_cols=0, stats=False):
        return _photon_steps_jit(labels_flat, media, state, shape,
                                 unitinmm, cfg, n_steps, block_lanes,
                                 interpret)
    """

_PALLAS_SRC = """\
    def photon_step_pallas(labels_flat, media, state, shape, unitinmm,
                           cfg, n_steps, block_lanes=256,
                           interpret=False, ppath=None, det_geom=None,
                           record=False, jac_w=None, jac_col=None,
                           jac_cols=0, stats=False):
        n_det = 0 if det_geom is None else 1
        out_shapes = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
        if n_det:
            out_shapes += [1, 2, 3]
        if record:
            out_shapes += [1, 2]
        if jac_cols:
            out_shapes += [1]
        if stats:
            out_shapes += [1]
        return out_shapes
    """

_REF_SRC = """\
    def photon_steps_ref(labels_flat, media, state, shape, unitinmm,
                         cfg, n_steps, ppath=None, det_geom=None,
                         record=False, jac_w=None, jac_col=None,
                         jac_cols=0, stats=False):
        n_det = 0 if det_geom is None else 1
        init = (state, 1, 2, 3, 4)
        if n_det:
            init = init + (1, 2, 3)
        if record:
            init = init + (1, 2)
        if jac_cols:
            init = init + (1,)
        if stats:
            init = init + (1,)
        return init
    """

_SIM_SRC = """\
    def build_sim_fn(engine, n_det, record, collect):
        def run(outs):
            state, flu, exi, esc, timed = outs[:5]
            cur = 5
            if n_det:
                ppath, dw, dp = outs[cur:cur + 3]
                cur += 3
            if record:
                capd, capg = outs[cur:cur + 2]
                cur += 2
            if collect:
                st_block = outs[cur]
            return state
        return run
    """


def _mirror_tree(root: Path) -> None:
    (root / "src/repro/kernels/photon_step").mkdir(parents=True,
                                                   exist_ok=True)
    shutil.copy(SPEC, root / "src/repro/kernels/photon_step/spec.py")
    _write(root, "src/repro/kernels/photon_step/ops.py", _OPS_SRC)
    _write(root, "src/repro/kernels/photon_step/photon_step.py",
           _PALLAS_SRC)
    _write(root, "src/repro/kernels/photon_step/ref.py", _REF_SRC)
    _write(root, "src/repro/core/simulator.py", _SIM_SRC)


def test_mirror_clean_tree(tmp_path):
    _mirror_tree(tmp_path)
    rep = _lint(tmp_path, "REP101")
    assert rep.clean, [f.format() for f in rep.findings]


def test_mirror_catches_demirrored_ref(tmp_path):
    _mirror_tree(tmp_path)
    _write(tmp_path, "src/repro/kernels/photon_step/ref.py",
           _REF_SRC.replace("init = init + (1, 2, 3)",
                            "init = init + (1, 2)"))
    rep = _lint(tmp_path, "REP101")
    assert len(rep.findings) == 1
    msg = rep.findings[0].message
    assert "ref.py init appends" in msg and "n_det" in msg


def test_mirror_catches_base_arity_drift(tmp_path):
    _mirror_tree(tmp_path)
    _write(tmp_path, "src/repro/kernels/photon_step/photon_step.py",
           _PALLAS_SRC.replace(
               "out_shapes = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]",
               "out_shapes = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]"))
    rep = _lint(tmp_path, "REP101")
    assert any("out_shapes` has 11 entries" in f.message
               for f in rep.findings)


def test_mirror_catches_reordered_simulator_groups(tmp_path):
    _mirror_tree(tmp_path)
    _write(tmp_path, "src/repro/core/simulator.py", """\
        def build_sim_fn(engine, n_det, record, collect):
            def run(outs):
                state, flu, exi, esc, timed = outs[:5]
                cur = 5
                if record:
                    capd, capg = outs[cur:cur + 2]
                    cur += 2
                if n_det:
                    ppath, dw, dp = outs[cur:cur + 3]
                return state
            return run
        """)
    rep = _lint(tmp_path, "REP101")
    assert any("out of order" in f.message for f in rep.findings)


def test_mirror_catches_missing_static_flag(tmp_path):
    _mirror_tree(tmp_path)
    _write(tmp_path, "src/repro/kernels/photon_step/ops.py",
           _OPS_SRC.replace('"record", "jac_cols", "stats"',
                            '"record", "jac_cols"'))
    rep = _lint(tmp_path, "REP101")
    assert any("static_argnames is missing" in f.message and
               "stats" in f.message for f in rep.findings)


def test_mirror_silent_without_spec(tmp_path):
    # rule-isolated fixture trees for other rules must not trip REP101
    _write(tmp_path, "src/repro/core/simulator.py", "X = 1\n")
    assert _lint(tmp_path, "REP101").clean


# ---------------------------------------------------------------- REP201

def test_determinism_flags_host_rng_in_traced_module(tmp_path):
    _write(tmp_path, "src/repro/core/simulator.py", """\
        import numpy as np


        def sample(n):
            return np.random.rand(n)
        """)
    rep = _lint(tmp_path, "REP201")
    assert len(rep.findings) == 1  # outermost chain only, no dup
    assert "numpy.random" in rep.findings[0].message


def test_determinism_flags_set_iteration(tmp_path):
    _write(tmp_path, "src/repro/core/simulator.py", """\
        def order():
            return [x for x in {3, 1, 2}]
        """)
    rep = _lint(tmp_path, "REP201")
    assert len(rep.findings) == 1
    assert "hash order" in rep.findings[0].message


def test_determinism_ignores_untraced_modules(tmp_path):
    # helpers is not imported (at module level) from any traced
    # entrypoint, so host RNG there is fine
    _write(tmp_path, "src/repro/core/simulator.py", """\
        def run():
            from repro import helpers  # lazy: stays off the trace path
            return helpers.jitter()
        """)
    _write(tmp_path, "src/repro/helpers.py", """\
        import random


        def jitter():
            return random.random()
        """)
    assert _lint(tmp_path, "REP201").clean


def test_determinism_follows_module_level_imports(tmp_path):
    _write(tmp_path, "src/repro/core/simulator.py",
           "from repro import helpers\n")
    _write(tmp_path, "src/repro/helpers.py", """\
        import random


        def jitter():
            return random.random()
        """)
    rep = _lint(tmp_path, "REP201")
    assert len(rep.findings) == 1
    assert rep.findings[0].path.endswith("helpers.py")


# ---------------------------------------------------------------- REP301

def test_dtype_flags_float64_and_bare_float(tmp_path):
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def bad(y):
            a = np.asarray(y, np.float64)
            b = np.zeros(3, dtype=float)
            c = np.asarray(y, float)
            return a, b, c
        """)
    rep = _lint(tmp_path, "REP301")
    assert len(rep.findings) == 3


def test_dtype_accepts_float32(tmp_path):
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def good(y):
            return np.asarray(y, np.float32)
        """)
    assert _lint(tmp_path, "REP301").clean


def test_pragma_suppresses_finding(tmp_path):
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def ok(y):
            return np.asarray(y, np.float64)  # reprolint: disable=REP301 - host-side test
        """)
    rep = _lint(tmp_path, "REP301")
    assert rep.clean
    assert rep.suppressed_pragma == 1


def test_pragma_disable_all(tmp_path):
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def ok(y):
            return np.asarray(y, np.float64)  # reprolint: disable=all
        """)
    assert _lint(tmp_path, "REP301").clean


# ---------------------------------------------------------------- REP401

def test_jit_flags_host_sync_in_lax_body(tmp_path):
    _write(tmp_path, "src/repro/core/loop.py", """\
        import jax


        def step(c):
            return float(c) + 1


        def run(x):
            return jax.lax.while_loop(lambda c: c < 3, step, x)
        """)
    rep = _lint(tmp_path, "REP401")
    assert len(rep.findings) == 1
    assert "float" in rep.findings[0].message


def test_jit_flags_item_in_traced_body(tmp_path):
    _write(tmp_path, "src/repro/core/loop.py", """\
        import jax


        def body(i, c):
            return c + c.item()


        def run(x):
            return jax.lax.fori_loop(0, 3, body, x)
        """)
    rep = _lint(tmp_path, "REP401")
    assert any(".item()" in f.message for f in rep.findings)


def test_jit_ignores_host_calls_outside_traced_bodies(tmp_path):
    _write(tmp_path, "src/repro/core/loop.py", """\
        def host(x):
            return float(x)
        """)
    assert _lint(tmp_path, "REP401").clean


def test_jit_flags_bogus_static_argname(tmp_path):
    _write(tmp_path, "src/repro/core/wrap.py", """\
        import functools

        import jax


        @functools.partial(jax.jit, static_argnames=("n", "nope"))
        def f(x, n):
            return x
        """)
    rep = _lint(tmp_path, "REP401")
    assert len(rep.findings) == 1
    assert "`nope`" in rep.findings[0].message


def test_jit_accepts_valid_static_argnames(tmp_path):
    _write(tmp_path, "src/repro/core/wrap.py", """\
        import functools

        import jax


        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            return x
        """)
    assert _lint(tmp_path, "REP401").clean


# ---------------------------------------------------------------- REP501

_VMEM_CALL = """\
    from repro.core.volume import SimConfig
    from repro.kernels.photon_step.photon_step import photon_step_pallas


    def run(labels, media, state):
        shape = {shape}
        cfg = SimConfig(n_time_gates={ntg})
        return photon_step_pallas(labels, media, state, shape, 1.0,
                                  cfg, 10, block_lanes=256,
                                  interpret={interpret})
    """


def test_vmem_rejects_over_budget_config(tmp_path):
    # 60^3 x 32 gates: the gate-major fluence block alone (~27 MB)
    # blows the 16 MiB core budget — exactly the config the runtime's
    # spec.check_vmem refuses
    _write(tmp_path, "src/repro/core/driver.py", _VMEM_CALL.format(
        shape="(60, 60, 60)", ntg=32, interpret=False))
    rep = _lint(tmp_path, "REP501")
    assert len(rep.findings) == 1
    assert "VMEM budget" in rep.findings[0].message


def test_vmem_skips_interpret_mode(tmp_path):
    # the interpreter has no VMEM: the CPU benches legitimately sweep
    # this exact config
    _write(tmp_path, "src/repro/core/driver.py", _VMEM_CALL.format(
        shape="(60, 60, 60)", ntg=32, interpret=True))
    assert _lint(tmp_path, "REP501").clean


def test_vmem_accepts_in_budget_config(tmp_path):
    _write(tmp_path, "src/repro/core/driver.py", _VMEM_CALL.format(
        shape="(32, 32, 32)", ntg=4, interpret=False))
    assert _lint(tmp_path, "REP501").clean


def test_vmem_chases_local_alias(tmp_path):
    # regression: grid/cfg args flowing through a simple local alias
    # (cfg2 = cfg) used to defeat resolution entirely
    _write(tmp_path, "src/repro/core/driver.py", """\
        from repro.core.volume import SimConfig
        from repro.kernels.photon_step.photon_step import photon_step_pallas


        def run(labels, media, state):
            shape = (60, 60, 60)
            shp = shape
            cfg = SimConfig(n_time_gates=32)
            cfg2 = cfg
            return photon_step_pallas(labels, media, state, shp, 1.0,
                                      cfg2, 10, block_lanes=256,
                                      interpret=False)
        """)
    rep = _lint(tmp_path, "REP501")
    assert len(rep.findings) == 1
    assert "VMEM budget" in rep.findings[0].message


def test_vmem_resolves_module_level_constants(tmp_path):
    # regression: SHAPE/NTG living at module scope were invisible to
    # the function-local literal env
    _write(tmp_path, "src/repro/core/driver.py", """\
        from repro.core.volume import SimConfig
        from repro.kernels.photon_step.photon_step import photon_step_pallas

        SHAPE = (60, 60, 60)
        NTG = 32


        def run(labels, media, state):
            cfg = SimConfig(n_time_gates=NTG)
            return photon_step_pallas(labels, media, state, SHAPE, 1.0,
                                      cfg, 10, block_lanes=256,
                                      interpret=False)
        """)
    rep = _lint(tmp_path, "REP501")
    assert len(rep.findings) == 1
    assert "VMEM budget" in rep.findings[0].message


def test_vmem_alias_of_in_budget_config_stays_clean(tmp_path):
    _write(tmp_path, "src/repro/core/driver.py", """\
        from repro.core.volume import SimConfig
        from repro.kernels.photon_step.photon_step import photon_step_pallas

        SHAPE = (32, 32, 32)


        def run(labels, media, state):
            cfg = SimConfig(n_time_gates=4)
            cfg2 = cfg
            return photon_step_pallas(labels, media, state, SHAPE, 1.0,
                                      cfg2, 10, block_lanes=256,
                                      interpret=False)
        """)
    assert _lint(tmp_path, "REP501").clean


def test_vmem_drops_ambiguously_rebound_alias(tmp_path):
    # a name rebound twice is ambiguous at the call site: the rule
    # must skip (runtime check covers it), never guess
    _write(tmp_path, "src/repro/core/driver.py", """\
        from repro.core.volume import SimConfig
        from repro.kernels.photon_step.photon_step import photon_step_pallas


        def run(labels, media, state, flag):
            shape = (60, 60, 60)
            if flag:
                shape = (8, 8, 8)
            cfg = SimConfig(n_time_gates=32)
            return photon_step_pallas(labels, media, state, shape, 1.0,
                                      cfg, 10, block_lanes=256,
                                      interpret=False)
        """)
    assert _lint(tmp_path, "REP501").clean


def test_vmem_skips_unresolvable_shape(tmp_path):
    _write(tmp_path, "src/repro/core/driver.py", """\
        from repro.kernels.photon_step.ops import photon_steps


        def run(labels, media, state, shape, cfg):
            return photon_steps(labels, media, state, shape, 1.0, cfg,
                                10)
        """)
    assert _lint(tmp_path, "REP501").clean


def test_vmem_threshold_matches_runtime():
    """The lint threshold IS the runtime threshold: same function."""
    from repro.kernels.photon_step import spec
    try:
        spec.check_vmem(60 * 60 * 60, 60 * 60, ntg=32, block_lanes=256)
    except ValueError as e:
        assert "MiB" in str(e)
    else:
        raise AssertionError("60^3 x 32 gates must exceed the budget")
    # and the boundary the benches document as safe stays accepted
    spec.check_vmem(32 * 32 * 32, 32 * 32, ntg=4, block_lanes=256)


# ---------------------------------------------------------------- REP601

def test_reach_flags_orphan_module(tmp_path):
    _write(tmp_path, "src/repro/launch/run.py",
           "from repro.core import engine\n")
    _write(tmp_path, "src/repro/core/engine.py", "X = 1\n")
    _write(tmp_path, "src/repro/orphan.py", "Y = 2\n")
    rep = _lint(tmp_path, "REP601")
    assert len(rep.findings) == 1
    assert "`repro.orphan`" in rep.findings[0].message


def test_reach_counts_test_imports_as_roots(tmp_path):
    _write(tmp_path, "src/repro/launch/run.py", "X = 1\n")
    _write(tmp_path, "src/repro/oracle.py", "Y = 2\n")
    _write(tmp_path, "tests/test_oracle.py",
           "from repro import oracle\n")
    assert _lint(tmp_path, "REP601").clean


def test_reach_follows_lazy_imports(tmp_path):
    # reachability (unlike the traced closure) follows function-level
    # imports: lazy importing is the repo's idiom, not a sign of death
    _write(tmp_path, "src/repro/launch/run.py", """\
        def main():
            from repro import heavy
            return heavy.go()
        """)
    _write(tmp_path, "src/repro/heavy.py", "def go(): return 1\n")
    assert _lint(tmp_path, "REP601").clean


# ---------------------------------------------------------------- REP701

_BENCH_WRITER = """\
    import json

    {extra_import}

    def run():
        out = {{"meta": {meta}, "result": 1}}
        with open("BENCH_figx.json", "w") as f:
            json.dump(out, f)
    """


def test_bench_flags_missing_schema_stamp(tmp_path):
    _write(tmp_path, "benchmarks/figx.py", _BENCH_WRITER.format(
        extra_import="", meta="{}"))
    rep = _lint(tmp_path, "REP701")
    assert len(rep.findings) == 1
    assert "never stamps" in rep.findings[0].message


def test_bench_flags_hardcoded_schema_version(tmp_path):
    _write(tmp_path, "benchmarks/figx.py", _BENCH_WRITER.format(
        extra_import="", meta='{"schema_version": 3}'))
    rep = _lint(tmp_path, "REP701")
    assert len(rep.findings) == 1
    assert "hardcoded" in rep.findings[0].message


def test_bench_accepts_shared_constant(tmp_path):
    _write(tmp_path, "benchmarks/figx.py", _BENCH_WRITER.format(
        extra_import="from benchmarks.common import SCHEMA_VERSION",
        meta='{"schema_version": SCHEMA_VERSION}'))
    assert _lint(tmp_path, "REP701").clean


def test_bench_ignores_non_writers(tmp_path):
    _write(tmp_path, "benchmarks/plot.py", """\
        def load(path):
            return open(path).read()  # reads BENCH_ files, writes none
        """)
    assert _lint(tmp_path, "REP701").clean


# ------------------------------------------------------------ baseline

def test_baseline_round_trip(tmp_path):
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def bad(y):
            return np.asarray(y, np.float64)
        """)
    rep = _lint(tmp_path, "REP301")
    assert len(rep.findings) == 1

    bp = baseline_path(tmp_path)
    save_baseline(bp, rep)
    data = json.loads(bp.read_text())
    assert data["version"] == 1 and len(data["findings"]) == 1

    rep2 = _lint(tmp_path, "REP301", baseline=load_baseline(bp))
    assert rep2.clean
    assert rep2.suppressed_baseline == 1

    # a *new* finding on top of the grandfathered one still fails
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def bad(y):
            return np.asarray(y, np.float64)


        def worse(y):
            return np.zeros(3, dtype=float)
        """)
    rep3 = _lint(tmp_path, "REP301", baseline=load_baseline(bp))
    assert len(rep3.findings) == 1
    assert rep3.suppressed_baseline == 1


def test_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == {}


# ------------------------------------- fingerprint stability (baseline)

_FPRINT_TEMPLATE = """\
import numpy as np


def bad(y):
    {indent}a{s1}={s2}np.asarray(y,{s3}np.float64){comment}
    return a
"""


def _fingerprint_of(tmp_path, body: str) -> str:
    _write(tmp_path, "src/repro/core/util.py", body)
    rep = _lint(tmp_path, "REP301")
    assert len(rep.findings) == 1, body
    return rep.findings[0].fingerprint


if _HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        s1=st.text(alphabet=" ", max_size=3),
        s2=st.text(alphabet=" ", max_size=3),
        s3=st.text(alphabet=" ", max_size=3),
        comment=st.one_of(
            st.just(""),
            st.builds(lambda t: "  # " + t,
                      st.text(alphabet="abcdefghij xyz", max_size=20))),
    )
    def test_fingerprint_survives_whitespace_and_comment_edits(
            tmp_path_factory, s1, s2, s3, comment):
        """Whitespace/comment-only edits must not invalidate committed
        .reprolint.json fingerprints (the baseline would silently stop
        matching)."""
        canonical = _FPRINT_TEMPLATE.format(indent="", s1=" ", s2=" ",
                                            s3=" ", comment="")
        mutated = _FPRINT_TEMPLATE.format(indent="", s1=s1, s2=s2,
                                          s3=s3, comment=comment)
        tmp = tmp_path_factory.mktemp("fp")
        ref = _fingerprint_of(tmp, canonical)
        assert _fingerprint_of(tmp, mutated) == ref


def test_fingerprint_survives_canonical_mutations(tmp_path_factory):
    # deterministic subset of the property above: always runs, even
    # without hypothesis installed
    canonical = _FPRINT_TEMPLATE.format(indent="", s1=" ", s2=" ",
                                        s3=" ", comment="")
    ref = _fingerprint_of(tmp_path_factory.mktemp("fp"), canonical)
    for s1, s2, s3, comment in [
            ("", "", "", ""),
            ("   ", "  ", " ", ""),
            (" ", " ", " ", "  # host-side conversion"),
            ("", " ", "", "  # xyz"),
    ]:
        mutated = _FPRINT_TEMPLATE.format(indent="", s1=s1, s2=s2,
                                          s3=s3, comment=comment)
        assert _fingerprint_of(tmp_path_factory.mktemp("fp"),
                               mutated) == ref


def test_fingerprint_changes_on_content_edit(tmp_path):
    canonical = _FPRINT_TEMPLATE.format(indent="", s1=" ", s2=" ",
                                        s3=" ", comment="")
    edited = canonical.replace("np.float64", "np.float64.type")
    assert _fingerprint_of(tmp_path, canonical) != \
        _fingerprint_of(tmp_path, edited)


def test_normalize_line_is_quote_aware():
    # '#' inside a string literal is content, not a comment
    assert normalize_line('x = "a#b"  # note') == 'x="a#b"'
    assert normalize_line("y  =  1   # c") == "y=1"


# ------------------------------------------------------ live-repo meta

def test_live_repo_is_lint_clean():
    """The committed tree must stay clean modulo the committed
    baseline — the same gate CI runs."""
    rep = run_lint(REPO, baseline=load_baseline(baseline_path(REPO)))
    assert rep.clean, "\n".join(f.format() for f in rep.findings)
    assert rep.n_modules > 30  # sanity: the real tree was discovered
    assert set(rep.rules_run) >= {"REP101", "REP201", "REP301",
                                  "REP401", "REP501", "REP601",
                                  "REP701"}


def test_cli_github_format_emits_annotations(tmp_path, capsys):
    from repro.lint.__main__ import main
    _write(tmp_path, "src/repro/core/util.py", """\
        import numpy as np


        def bad(y):
            return np.asarray(y, np.float64)
        """)
    rc = main(["--root", str(tmp_path), "--format", "github",
               "--rules", "REP301"])
    out = capsys.readouterr().out
    assert rc == 1
    line = next(ln for ln in out.splitlines() if ln.startswith("::error"))
    assert "file=src/repro/core/util.py" in line
    assert "line=5" in line and "title=REP301[dtype]" in line
    assert "::" in line.rpartition("title=")[2]  # message after the ::


def test_cli_github_format_clean_tree(tmp_path, capsys):
    from repro.lint.__main__ import main
    _write(tmp_path, "src/repro/core/util.py", "X = 1\n")
    rc = main(["--root", str(tmp_path), "--format", "github",
               "--rules", "REP301"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "::error" not in out and "clean" in out


def test_cli_json_output():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--format", "json"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(proc.stdout)
    assert data["clean"] is True
