"""Resilient heterogeneous execution (DESIGN.md §resilience).

Contracts under test:

  * **Chaos anchor** — under any seeded fault schedule (dispatch
    failures, NaN corruption, injected delays, device dropout) the
    final ``SimResult`` of a chunked run is *bit-identical* to the
    fault-free run, no chunk is merged twice, and the retry/quarantine
    accounting in ``PoolReport`` adds up.  Holds for single-spec pools
    and for mixed ``engine="jnp"``/``"pallas"`` fleets (engine binding
    keeps every retry on a bit-identical worker class).
  * **FaultInjector determinism** — every decision is a pure function
    of ``(seed, kind, chunk, attempt)``: schedule- and replay-stable.
  * **RetryPolicy** — exponential backoff with cap, attempt budgets,
    and the healthy -> suspect -> quarantined worker ladder.
  * **validate_chunk** — accepts real healthy chunks and rejects NaN,
    negative-weight, short-launch, and energy-balance corruption.
  * **Deadlines + speculation** — a straggler (throttled fake device)
    is speculatively re-dispatched, the first valid result wins, and
    the late duplicate is discarded by chunk id.
  * **Quarantine paths** — poison chunks exhaust their budget and are
    quarantined (raise or record), a real dispatch error is surfaced
    as the ``__cause__``, an empty pool raises ``PoolExhaustedError``,
    and a hung run is bounded by ``deadline_s``.
  * **Checkpoint/restart** — both the ``DevicePool`` (frontier
    checkpoints, ``resume=True``) and the ``ElasticSimulator``
    (satellite: injected host crash after k merges, restore from the
    atomic Checkpointer, finish) end bit-identical to an uninterrupted
    campaign — including ``det_rec``, ``stats`` and detector/gate
    accumulators.
  * **Fig. 8 analogue** — an unequal two-worker fleet (throttled fake
    devices) sustains >= 0.9x the sum of its solo throughputs.
"""

import dataclasses

import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import simulator as S
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler, ElasticSimulator
from repro.detectors import Detector
from repro.resilience import (ChunkQuarantinedError, DevicePool, DeviceSpec,
                              FaultInjector, InjectedCrash, InjectedFault,
                              PoolExhaustedError, RetryPolicy, corrupt_harvest,
                              harvest_result, validate_chunk)
from repro.resilience.policy import HEALTHY, QUARANTINED, SUSPECT

SHAPE = (16, 16, 16)
LANES = 128
SEED = 7


def _bench():
    return V.benchmark_b1(SHAPE), V.SimConfig(do_reflect=False)


_RESULT_FIELDS = ("energy", "exitance", "escaped_w", "timed_out_w", "det_w",
                  "det_ppath", "det_rec", "launched_w", "n_launched")


def _assert_bit_identical(a, b):
    for f in _RESULT_FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        np.testing.assert_array_equal(x, y, err_msg=f)


def _assert_stats_equal(a, b):
    for name in a._fields:  # RoundStats NamedTuple
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# FaultInjector: seeded, counter-based, schedule-independent
# ---------------------------------------------------------------------------

def test_fault_injector_is_deterministic_and_schedule_independent():
    a = FaultInjector(seed=3, p_fail=0.4, p_nan=0.4, p_delay=0.4)
    b = FaultInjector(seed=3, p_fail=0.4, p_nan=0.4, p_delay=0.4)

    def fate(inj, chunk, attempt):
        try:
            inj.check_dispatch(chunk, attempt)
            failed = False
        except InjectedFault:
            failed = True
        return (failed, inj.corrupts(chunk, attempt),
                inj.delay_for(chunk, attempt))

    keys = [(c, k) for c in (0, 500, 1000, 1500) for k in range(4)]
    fwd = [fate(a, c, k) for c, k in keys]
    # replaying the same (chunk, attempt) pairs in any order — or on a
    # fresh injector — gives the same fates: no hidden call-order state
    rev = [fate(b, c, k) for c, k in reversed(keys)]
    assert fwd == list(reversed(rev))
    assert fwd == [fate(a, c, k) for c, k in keys]
    # the coin actually has both sides at p=0.4 over 16 draws
    assert any(f for f, _, _ in fwd) and not all(f for f, _, _ in fwd)
    # a different seed is a different schedule
    other = FaultInjector(seed=4, p_fail=0.4, p_nan=0.4, p_delay=0.4)
    assert fwd != [fate(other, c, k) for c, k in keys]


def test_fault_injector_schedules_and_json_config():
    # JSON configs (--chaos) hand lists/dicts; the injector normalizes
    inj = FaultInjector(seed=1, poison_chunks=[100], dropout={"w0": 2},
                        kill_after_merges=3)
    assert inj.poison_chunks == (100,)
    assert inj.active
    with pytest.raises(InjectedFault, match="poison"):
        inj.check_dispatch(100, attempt=5)
    inj.check_dispatch(200, attempt=0)  # only the poison chunk fails
    assert not inj.dropped("w0", 1) and inj.dropped("w0", 2)
    assert not inj.dropped("w1", 99)   # unscheduled workers never drop
    inj.maybe_kill(2)
    with pytest.raises(InjectedCrash):
        inj.maybe_kill(3)
    assert not FaultInjector().active


# ---------------------------------------------------------------------------
# RetryPolicy: backoff, budgets, health ladder
# ---------------------------------------------------------------------------

def test_retry_policy_backoff_budget_and_health():
    p = RetryPolicy(max_attempts=3, backoff_s=0.1, backoff_factor=2.0,
                    max_backoff_s=0.3, suspect_after=2, quarantine_after=4)
    assert [p.backoff(k) for k in (1, 2, 3, 4)] == [0.1, 0.2, 0.3, 0.3]
    assert RetryPolicy(backoff_s=0.0).backoff(5) == 0.0
    assert not p.exhausted(2) and p.exhausted(3)
    assert [p.health_for(n) for n in (0, 1, 2, 3, 4)] == \
        [HEALTHY, HEALTHY, SUSPECT, SUSPECT, QUARANTINED]
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(suspect_after=3, quarantine_after=2)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=-1.0)


# ---------------------------------------------------------------------------
# validate_chunk: the merge guard
# ---------------------------------------------------------------------------

def test_validate_chunk_accepts_real_results_and_rejects_corruption():
    vol, cfg = _bench()
    res = S.simulate(vol, cfg, 400, LANES, SEED)
    h = harvest_result(res)
    assert validate_chunk(h, 400) == []

    bad = corrupt_harvest(h)
    assert any("non-finite" in e for e in validate_chunk(bad, 400))
    # the original host copy is untouched (corruption is copy-on-write)
    assert validate_chunk(h, 400) == []

    assert any("assigned" in e for e in validate_chunk(h, 401))

    neg = dict(h, exitance=h["exitance"] - 1.0)
    assert any("negative" in e for e in validate_chunk(neg, 400))

    # breaking the energy balance (launched weight inflated) is caught
    # even though every array stays finite and non-negative
    skew = dict(h, launched_w=h["launched_w"] * 1.5)
    assert any("residue" in e for e in validate_chunk(skew, 400))


# ---------------------------------------------------------------------------
# Chaos anchors: bit-identity under seeded fault schedules
# ---------------------------------------------------------------------------

def test_pool_chaos_bit_identity_single_spec():
    """Faults (dispatch failures, NaN corruption, delays) change no
    output bit, and the fault-free pool matches the plain scheduler."""
    vol, cfg = _bench()
    N, CHUNK = 800, 200
    ref = ChunkScheduler(vol, cfg, n_lanes=LANES)
    res_ref, _ = ref.run(N, CHUNK, seed=SEED)

    inj = FaultInjector(seed=2, p_fail=0.35, p_nan=0.25, p_delay=0.3,
                        delay_s=0.01)
    pool = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)],
                      fault_injector=inj,
                      retry_policy=RetryPolicy(max_attempts=12,
                                               quarantine_after=50))
    res, rep = pool.run(N, CHUNK, seed=SEED, deadline_s=300)
    _assert_bit_identical(res_ref, res)
    # the drill actually exercised the machinery...
    assert rep.injected_faults > 0 and rep.retries > 0
    assert rep.validation_failures + rep.dispatch_failures == rep.retries
    # ...and nothing was merged twice or lost
    assert rep.merged == rep.n_chunks == N // CHUNK
    assert not rep.quarantined_chunks
    assert int(res.n_launched) == N


def test_pool_chaos_bit_identity_mixed_engines():
    """The acceptance anchor: a mixed jnp/pallas fleet under a seeded
    fault schedule is bit-identical to the fault-free run of the same
    fleet — engine binding keeps every retry on the bit-class the chunk
    was bound to (rebound == 0: no class went extinct)."""
    vol, cfg = _bench()
    N, CHUNK = 800, 200
    specs = [DeviceSpec(engine="jnp", n_lanes=LANES, label="jnp0"),
             DeviceSpec(engine="pallas", n_lanes=LANES, label="pal0")]

    clean = DevicePool(vol, cfg, specs)
    res_ref, rep_ref = clean.run(N, CHUNK, seed=SEED)
    assert rep_ref.retries == 0 and rep_ref.rebound == 0

    inj = FaultInjector(seed=5, p_fail=0.35, p_nan=0.25, p_delay=0.3,
                        delay_s=0.01)
    chaos = DevicePool(vol, cfg, specs, fault_injector=inj,
                       retry_policy=RetryPolicy(max_attempts=12,
                                                quarantine_after=50))
    res, rep = chaos.run(N, CHUNK, seed=SEED, deadline_s=300)
    _assert_bit_identical(res_ref, res)
    assert rep.injected_faults > 0 and rep.retries > 0
    assert rep.rebound == 0
    assert rep.merged == rep.n_chunks and not rep.quarantined_chunks
    assert int(res.n_launched) == N
    # both bit classes did real work
    merged_by = {w["engine"]: w["chunks_merged"] for w in rep.workers}
    assert merged_by.get("jnp", 0) > 0 and merged_by.get("pallas", 0) > 0


def test_pool_dropout_rebinds_chunks_to_surviving_class():
    """When a bit class loses its last worker its chunks are re-bound
    (graceful degradation down to one device) and the run completes."""
    vol, cfg = _bench()
    N, CHUNK = 600, 150
    specs = [DeviceSpec(engine="jnp", n_lanes=LANES, label="a"),
             DeviceSpec(engine="jnp", n_lanes=2 * LANES, label="b")]
    inj = FaultInjector(seed=1, dropout={"b": 1})
    pool = DevicePool(vol, cfg, specs, fault_injector=inj)
    res, rep = pool.run(N, CHUNK, seed=SEED, deadline_s=300)
    assert int(res.n_launched) == N
    assert rep.merged == rep.n_chunks
    assert rep.workers_quarantined == 1 and rep.quarantine_events >= 1
    # class ('jnp', 256, ...) went extinct -> its chunks moved to 'a'
    assert rep.rebound >= 1


# ---------------------------------------------------------------------------
# Deadlines, speculation, duplicates
# ---------------------------------------------------------------------------

def test_pool_straggler_speculation_first_valid_wins():
    vol, cfg = _bench()
    N, CHUNK = 600, 150  # 4 chunks
    # one genuinely slow fake device + one fast one, same bit class, so
    # the speculative twin is bit-identical by construction
    specs = [DeviceSpec(n_lanes=LANES, label="slow", throttle_s=0.2),
             DeviceSpec(n_lanes=LANES, label="fast", throttle_s=0.1)]
    pool = DevicePool(vol, cfg, specs, chunk_timeout_s=0.08)
    res, rep = pool.run(N, CHUNK, seed=SEED, deadline_s=120)

    fast = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)])
    res_ref, _ = fast.run(N, CHUNK, seed=SEED)
    _assert_bit_identical(res_ref, res)
    assert rep.speculative >= 1
    # the loser of at least one race landed late and was discarded by
    # chunk id instead of double-merging
    assert rep.duplicates_discarded >= 1
    assert rep.merged == rep.n_chunks
    assert int(res.n_launched) == N


# ---------------------------------------------------------------------------
# Quarantine and failure surfacing
# ---------------------------------------------------------------------------

def test_pool_poison_chunk_quarantine():
    vol, cfg = _bench()
    N, CHUNK = 600, 150
    inj = FaultInjector(poison_chunks=(150,))
    policy = RetryPolicy(max_attempts=3, quarantine_after=50)

    with pytest.raises(ChunkQuarantinedError, match="chunk 150") as ei:
        DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)],
                   fault_injector=inj, retry_policy=policy
                   ).run(N, CHUNK, seed=SEED, deadline_s=120)
    assert isinstance(ei.value.__cause__, InjectedFault)

    pool = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)],
                      fault_injector=inj, retry_policy=policy,
                      raise_on_quarantine=False)
    res, rep = pool.run(N, CHUNK, seed=SEED, deadline_s=120)
    assert [(c.start_id, c.count) for c in rep.quarantined_chunks] == \
        [(150, 150)]
    assert len(rep.chunk_failures[150]) == 3  # the whole attempt budget
    assert rep.merged == 3
    # the quarantined chunk is recorded, never merged: its photons are
    # missing from the accounting instead of silently wrong
    assert int(res.n_launched) == N - 150


def test_pool_real_dispatch_error_is_retried_and_surfaced():
    """Satellite: a dispatch that raises no longer loses the chunk —
    it is requeued, retried, and the real error surfaces as the cause
    of the quarantine instead of vanishing."""
    vol, cfg = _bench()
    pool = DevicePool(vol, cfg, [DeviceSpec(engine="definitely-not-real")],
                      retry_policy=RetryPolicy(max_attempts=2))
    with pytest.raises(ChunkQuarantinedError) as ei:
        pool.run(100, 100, seed=SEED, deadline_s=60)
    assert isinstance(ei.value.__cause__, ValueError)  # unknown engine
    assert "definitely-not-real" in str(ei.value.__cause__)


def test_pool_exhausted_when_every_worker_drops():
    vol, cfg = _bench()
    inj = FaultInjector(dropout={"only": 0})
    pool = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES, label="only")],
                      fault_injector=inj)
    with pytest.raises(PoolExhaustedError, match="worker history"):
        pool.run(300, 100, seed=SEED, deadline_s=60)


def test_pool_overall_deadline_bounds_hung_runs():
    """Satellite: a never-ready device can no longer spin the dispatch
    loop forever — deadline_s turns the hang into a TimeoutError."""
    vol, cfg = _bench()
    pool = DevicePool(vol, cfg,
                      [DeviceSpec(n_lanes=LANES, throttle_s=30.0)])
    with pytest.raises(TimeoutError, match="deadline_s"):
        pool.run(300, 100, seed=SEED, deadline_s=0.3)


# ---------------------------------------------------------------------------
# DevicePool checkpoint / resume
# ---------------------------------------------------------------------------

def test_pool_crash_resume_bit_identity(tmp_path):
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, collect_stats=True)
    N, CHUNK = 600, 150
    dets = (Detector(SHAPE[0] / 2.0, SHAPE[1] / 2.0, SHAPE[0] / 2.0),)
    kw = dict(detectors=dets, record_detected=64)

    ref_pool = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)], **kw)
    res_ref, _ = ref_pool.run(N, CHUNK, seed=SEED)

    ckpt = Checkpointer(str(tmp_path / "ckpt"), keep=3)
    crash = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)], **kw,
                       fault_injector=FaultInjector(kill_after_merges=2),
                       checkpointer=ckpt, checkpoint_every=1)
    with pytest.raises(InjectedCrash):
        crash.run(N, CHUNK, seed=SEED, deadline_s=120)
    assert ckpt.latest_step() == 2
    assert ckpt.manifest()["extra"]["kind"] == "device_pool"
    assert ckpt.manifest()["extra"]["merged"] == 2

    # a fresh pool (fresh process in real life) resumes past the crash
    resumed = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)], **kw,
                         checkpointer=ckpt, checkpoint_every=1)
    res, rep = resumed.run(N, CHUNK, seed=SEED, resume=True,
                           deadline_s=120)
    assert rep.merged == N // CHUNK  # restored chunks count as merged
    _assert_bit_identical(res_ref, res)
    _assert_stats_equal(res_ref.stats, res.stats)

    # a checkpoint from a different campaign is refused, not merged
    other = DevicePool(vol, cfg, [DeviceSpec(n_lanes=LANES)], **kw,
                       checkpointer=ckpt)
    with pytest.raises(ValueError, match="different campaign"):
        other.run(N, CHUNK, seed=SEED + 1, resume=True)


# ---------------------------------------------------------------------------
# ElasticSimulator: retry caps, ordering, crash/restore (satellites)
# ---------------------------------------------------------------------------

def test_elastic_requeue_goes_to_the_back_and_caps_attempts():
    vol, cfg = _bench()
    sim = ElasticSimulator(vol, cfg, 600, 150, n_lanes=LANES, seed=SEED,
                           fault_injector=FaultInjector(poison_chunks=(0,)),
                           retry_policy=RetryPolicy(max_attempts=2))
    sim.run_round(max_chunks=1)
    # the poison chunk re-queues at the BACK: the campaign advances
    # instead of starving behind it (pre-PR it went to the front)
    assert [c.start_id for c in sim.pending][-1] == 0
    assert sim.n_retries == 1
    res = sim.run_to_completion()
    assert [c.start_id for c in sim.skipped] == [0]
    assert sim.failures[0] == 2          # full attempt budget spent
    assert int(res.n_launched) == 600 - 150
    assert len(sim.completed) == 3 and not sim.pending


def test_elastic_kill_restore_bit_identity(tmp_path):
    """Satellite: kill after k merges via FaultInjector, restore from
    the atomic keep-k Checkpointer, finish — bit-identical to the
    uninterrupted campaign, including det_rec, stats and the
    detector/gate accumulators."""
    vol, cfg = _bench()
    cfg = dataclasses.replace(cfg, collect_stats=True, n_time_gates=4)
    N, CHUNK = 600, 150
    dets = (Detector(SHAPE[0] / 2.0, SHAPE[1] / 2.0, SHAPE[0] / 2.0),
            Detector(5.0, 5.0, 2.5))
    kw = dict(n_lanes=LANES, seed=SEED, detectors=dets, record_detected=64)

    ref = ElasticSimulator(vol, cfg, N, CHUNK, **kw)
    res_ref = ref.run_to_completion()
    assert np.asarray(res_ref.det_rec).size > 0  # the assertion has teeth
    assert np.asarray(res_ref.det_w).shape == (2, 4)

    ckpt = Checkpointer(str(tmp_path / "ckpt"), keep=3)
    crash = ElasticSimulator(vol, cfg, N, CHUNK, **kw,
                             fault_injector=FaultInjector(
                                 kill_after_merges=2),
                             checkpointer=ckpt, checkpoint_every=1)
    with pytest.raises(InjectedCrash):
        crash.run_to_completion()
    assert ckpt.latest_step() == 2
    assert ckpt.manifest()["extra"]["kind"] == "elastic"

    restored = ElasticSimulator(vol, cfg, N, CHUNK, **kw)
    _, state = ckpt.restore(restored.state_dict())
    restored.load_state_dict(state)
    assert len(restored.completed) == 2 and len(restored.pending) == 2
    res = restored.run_to_completion()

    _assert_bit_identical(res_ref, res)
    np.testing.assert_array_equal(np.asarray(res_ref.det_rec),
                                  np.asarray(res.det_rec))
    _assert_stats_equal(res_ref.stats, res.stats)


# ---------------------------------------------------------------------------
# Fig. 8 analogue: unequal fleet throughput (fake-device approximation)
# ---------------------------------------------------------------------------

def test_heterogeneous_fleet_sustains_sum_of_solo_throughputs():
    """Two unequal fake devices (throttled latency floors) together
    reach >= 0.9x the sum of their solo throughputs: the pool's greedy
    pull leaves no worker idle while chunks remain."""
    vol, cfg = _bench()
    N, CHUNK = 1200, 100  # 12 chunks
    fast = DeviceSpec(n_lanes=LANES, label="fast", throttle_s=0.08)
    slow = DeviceSpec(n_lanes=LANES, label="slow", throttle_s=0.16)

    def rate(specs):
        pool = DevicePool(vol, cfg, specs)
        pool.run(N, CHUNK, seed=SEED)          # warm compile + caches
        _, rep = pool.run(N, CHUNK, seed=SEED)
        return N / rep.wall_s

    r_fast, r_slow = rate([fast]), rate([slow])
    r_both = rate([fast, slow])
    assert r_both >= 0.9 * (r_fast + r_slow), \
        (r_both, r_fast, r_slow)
