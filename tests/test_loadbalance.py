"""Tests for the device-level load-balancing strategies (paper Fig. 3b)."""

import numpy as np
import pytest

from repro.core import loadbalance as LB

try:  # hypothesis is optional locally (pinned in CI); only the property
    # tests need it — the deterministic tests always run
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False


def _paper_devices():
    """Device models with the overheads reported in the paper:
    1080Ti/980Ti/R9 Nano/RX480 with T0 = 53/63/631/652 ms and throughput
    ratios chosen to match their reported relative speeds."""
    return [
        LB.DeviceModel("1080Ti", a=4.4e-8, t0=0.053, cores=3584),
        LB.DeviceModel("980Ti", a=8.0e-8, t0=0.063, cores=2816),
        LB.DeviceModel("R9Nano", a=6.0e-8, t0=0.631, cores=4096),
        LB.DeviceModel("RX480", a=1.1e-7, t0=0.652, cores=2304),
    ]


def test_fit_pilot_two_points_exact():
    m = LB.fit_pilot([1e6, 5e6], [0.097, 0.273], name="x")
    np.testing.assert_allclose(m.a, (0.273 - 0.097) / 4e6)
    np.testing.assert_allclose(m.t0, 0.097 - m.a * 1e6)
    assert m.predict(0) == 0.0
    np.testing.assert_allclose(m.predict(1e6), 0.097)


def test_fit_pilot_equal_sizes_raises():
    """Regression: equal pilot sizes used to divide by n2 - n1 == 0 and
    hand partition_s3 an inf/NaN device model; now a clear error."""
    with pytest.raises(ValueError, match="distinct photon counts"):
        LB.fit_pilot([1e6, 1e6], [0.1, 0.2])
    # the degenerate design is rejected on the lstsq path too
    with pytest.raises(ValueError, match="distinct photon counts"):
        LB.fit_pilot([5e5, 5e5, 5e5], [0.1, 0.11, 0.09])
    # and a healthy fit still goes through partition_s3 cleanly
    m = LB.fit_pilot([1e6, 5e6], [0.097, 0.273])
    part = LB.partition_s3(10_000, [m, m])
    assert sum(part) == 10_000 and all(np.isfinite(p) for p in part)


def test_fit_pilot_lstsq():
    a_true, t0_true = 5e-8, 0.1
    ns = [1e6, 2e6, 5e6, 8e6]
    ts = [a_true * n + t0_true for n in ns]
    m = LB.fit_pilot(ns, ts)
    np.testing.assert_allclose(m.a, a_true, rtol=1e-6)
    np.testing.assert_allclose(m.t0, t0_true, rtol=1e-5)


def test_partitions_sum_and_sign():
    devs = _paper_devices()
    for strat in ("S1", "S2", "S3"):
        part = LB.PARTITIONERS[strat](10**8, devs)
        assert sum(part) == 10**8
        assert all(p >= 0 for p in part)


def test_s2_matches_throughput_ratios():
    devs = _paper_devices()
    part = LB.partition_s2(10**8, devs)
    tps = np.asarray([d.throughput for d in devs])
    expect = tps / tps.sum()
    got = np.asarray(part) / 1e8
    np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_s3_beats_or_ties_s1_s2_makespan():
    """The paper's core claim: S2/S3 beat S1 by ~10-14%; S3 is optimal."""
    devs = _paper_devices()
    n = 10**8
    ms = {s: LB.makespan(LB.PARTITIONERS[s](n, devs), devs)
          for s in ("S1", "S2", "S3")}
    assert ms["S3"] <= ms["S1"] * (1 + 1e-9)
    assert ms["S3"] <= ms["S2"] * (1 + 1e-9)
    # S1 (core-count) should be measurably worse on this device mix
    assert ms["S3"] < ms["S1"] * 0.95


def test_s3_accounts_for_overhead_small_budget():
    """With a tiny budget, S3 should starve high-overhead devices."""
    devs = [
        LB.DeviceModel("fast_low_t0", a=1e-6, t0=0.0),
        LB.DeviceModel("fast_high_t0", a=1e-6, t0=10.0),
    ]
    part = LB.partition_s3(1000, devs)
    assert part[0] == 1000 and part[1] == 0
    # S2 ignores overhead and splits evenly — S3 must be better here
    s2 = LB.partition_s2(1000, devs)
    assert LB.makespan(part, devs) < LB.makespan(s2, devs)


def test_ideal_makespan_lower_bound():
    devs = _paper_devices()
    n = 10**8
    ideal = LB.ideal_makespan(n, devs)
    for s in ("S1", "S2", "S3"):
        assert LB.makespan(LB.PARTITIONERS[s](n, devs), devs) >= ideal


if _HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(0, 10**7),
        seed=st.integers(0, 2**31),
        k=st.integers(2, 6),
    )
    def test_property_partitions_valid(n, seed, k):
        rng = np.random.default_rng(seed)
        devs = [
            LB.DeviceModel(
                f"d{i}",
                a=float(10 ** rng.uniform(-8, -5)),
                t0=float(rng.uniform(0, 2.0)),
                cores=int(rng.integers(1, 8192)),
            )
            for i in range(k)
        ]
        for strat in ("S1", "S2", "S3"):
            part = LB.PARTITIONERS[strat](n, devs)
            assert sum(part) == n
            assert all(p >= 0 for p in part)
        # minimax optimality within integer rounding slack
        s3 = LB.PARTITIONERS["S3"](n, devs)
        for other in ("S1", "S2"):
            po = LB.PARTITIONERS[other](n, devs)
            slack = max(d.a for d in devs) * k  # rounding slack
            assert LB.makespan(s3, devs) <= LB.makespan(po, devs) + slack


def test_run_pilot_with_synthetic_clock():
    calls = []

    def fake_run(n):
        calls.append(n)
        return 3e-8 * n + 0.4

    m = LB.run_pilot(fake_run, 10**6, 5 * 10**6, name="sim")
    np.testing.assert_allclose(m.a, 3e-8, rtol=1e-9)
    np.testing.assert_allclose(m.t0, 0.4, rtol=1e-9)
    assert calls == [10**6, 5 * 10**6]


# ---------------------------------------------------------------------------
# degenerate pilot fits / device models (PR 4 hardening)
# ---------------------------------------------------------------------------

def test_fit_pilot_nonpositive_slope_raises():
    """Regression: a noisy pilot where the larger run timed *faster*
    used to fit a negative slope that the silent 1e-12 clamp turned
    into a ~infinitely fast device; now a clear error."""
    with pytest.raises(ValueError, match="non-positive photon cost slope"):
        LB.fit_pilot([1e6, 5e6], [0.30, 0.25])  # bigger run was faster
    with pytest.raises(ValueError, match="non-positive photon cost slope"):
        LB.fit_pilot([1e6, 5e6], [0.25, 0.25])  # zero slope
    # the lstsq path is guarded too
    with pytest.raises(ValueError, match="non-positive photon cost slope"):
        LB.fit_pilot([1e6, 2e6, 5e6], [0.5, 0.4, 0.2])


def test_device_model_rejects_degenerate_slopes():
    """partition_s2/s3 divide by the slope; a hand-built degenerate
    model must fail at construction, not as NaN shares downstream."""
    for bad_a in (0.0, -1e-8, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="positive finite"):
            LB.DeviceModel("bad", a=bad_a, t0=0.1)
    with pytest.raises(ValueError, match="nonnegative finite"):
        LB.DeviceModel("bad", a=1e-8, t0=float("nan"))
    # healthy models still construct and partition cleanly
    devs = [LB.DeviceModel("a", a=1e-8, t0=0.1),
            LB.DeviceModel("b", a=4e-8, t0=0.2)]
    for strat in ("S1", "S2", "S3"):
        part = LB.PARTITIONERS[strat](10_000, devs)
        assert sum(part) == 10_000 and all(p >= 0 for p in part)


# ---------------------------------------------------------------------------
# property tests: _largest_remainder_round invariants
# ---------------------------------------------------------------------------

if _HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(
        total=st.integers(0, 10**7),
        seed=st.integers(0, 2**31),
        k=st.integers(1, 12),
    )
    def test_property_largest_remainder_round(total, seed, k):
        """Sum/bounds invariants of the share-rounding helper: the
        rounded partition must sum exactly to the total, stay
        nonnegative, and never move any share by a full photon or
        more."""
        rng = np.random.default_rng(seed)
        weights = rng.uniform(1e-6, 1.0, size=k)
        shares = (total * weights / weights.sum()).tolist()
        out = LB._largest_remainder_round(shares, total)
        assert sum(out) == total
        assert all(p >= 0 for p in out)
        assert all(abs(p - s) < 1.0 + 1e-9 for p, s in zip(out, shares))

    @settings(max_examples=50, deadline=None)
    @given(total=st.integers(0, 10**6), k=st.integers(1, 8))
    def test_property_largest_remainder_round_exact_integers(total, k):
        """Integer shares must pass through unchanged."""
        base = [total // k] * k
        for i in range(total % k):
            base[i] += 1
        out = LB._largest_remainder_round([float(b) for b in base], total)
        assert out == base
