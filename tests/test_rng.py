"""Unit + property tests for the counter-seeded xorshift128 RNG."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rng as xrng

try:  # hypothesis is optional locally (pinned in CI); only the property
    # tests need it — the deterministic regression tests always run
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False


def test_seed_state_shape_and_nonzero():
    ids = jnp.arange(1000, dtype=jnp.uint32)
    st_ = xrng.seed_state(42, ids)
    assert st_.shape == (1000, 4)
    assert st_.dtype == jnp.uint32
    assert not bool(jnp.any(jnp.all(st_ == 0, axis=-1)))


def test_seed_state_deterministic_and_distinct():
    ids = jnp.arange(256, dtype=jnp.uint32)
    a = xrng.seed_state(7, ids)
    b = xrng.seed_state(7, ids)
    assert bool(jnp.all(a == b))
    c = xrng.seed_state(8, ids)
    assert not bool(jnp.all(a == c))
    # states distinct across photon ids
    flat = np.asarray(a).view(np.uint64).reshape(256, 2)
    assert len({tuple(r) for r in flat}) == 256


def test_uniform_in_open_unit_interval():
    state = xrng.seed_state(3, jnp.arange(4096, dtype=jnp.uint32))
    for _ in range(8):
        state, u = xrng.next_uniform(state)
        u = np.asarray(u)
        assert np.all(u > 0.0) and np.all(u < 1.0)


def test_uniform_moments():
    state = xrng.seed_state(11, jnp.arange(8192, dtype=jnp.uint32))
    total = []
    for _ in range(16):
        state, u = xrng.next_uniform(state)
        total.append(np.asarray(u))
    u = np.concatenate(total)
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1.0 / 12.0) < 5e-3
    # lag-1 serial correlation across draws of one lane should vanish
    lane = np.stack(total)[:, 0]
    assert abs(np.corrcoef(lane[:-1], lane[1:])[0, 1]) < 0.7  # tiny sample


def test_streams_uncorrelated_across_ids():
    state = xrng.seed_state(5, jnp.arange(2, dtype=jnp.uint32))
    xs, ys = [], []
    for _ in range(512):
        state, u = xrng.next_uniform(state)
        u = np.asarray(u)
        xs.append(u[0])
        ys.append(u[1])
    r = np.corrcoef(xs, ys)[0, 1]
    assert abs(r) < 0.15


if _HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), pid=st.integers(0, 2**32 - 1))
    def test_property_uniform_bounds(seed, pid):
        state = xrng.seed_state(jnp.uint32(seed),
                                jnp.asarray([pid], jnp.uint32))
        for _ in range(4):
            state, u = xrng.next_uniform(state)
            val = float(u[0])
            assert 0.0 < val < 1.0

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_property_seeding_is_injective_in_id(seed):
        ids = jnp.arange(128, dtype=jnp.uint32)
        s = xrng.seed_state(jnp.uint32(seed), ids)
        flat = np.asarray(s).view(np.uint64).reshape(128, 2)
        assert len({tuple(r) for r in flat}) == 128


# ---------------------------------------------------------------------------
# 64-bit (two-word) photon ids
# ---------------------------------------------------------------------------

def test_photon_id_hi_zero_is_bit_identical_to_legacy():
    """Ids below 2**32 must keep their historical streams: a PhotonId
    with hi=0 seeds bit-identically to the plain uint32 id."""
    ids = jnp.arange(512, dtype=jnp.uint32)
    legacy = xrng.seed_state(7, ids)
    paired = xrng.seed_state(7, xrng.as_photon_id(ids))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(paired))


def test_photon_ids_straddling_2_32_are_distinct():
    """Regression: a uint32 id counter wraps at 2**32 and silently
    reuses streams; the two-word id must keep every photon distinct."""
    n = 256
    lo = (jnp.uint32(2**32 - n // 2) + jnp.arange(n, dtype=jnp.uint32))
    hi = (lo < jnp.uint32(2**32 - n // 2)).astype(jnp.uint32)
    s = xrng.seed_state(7, xrng.PhotonId(lo=lo, hi=hi))
    flat = np.asarray(s).view(np.uint64).reshape(n, 2)
    assert len({tuple(r) for r in flat}) == n
    # and the post-wrap ids differ from the hi=0 ids with the same lo
    # word — exactly the collision the uint32 counter used to produce
    s0 = xrng.seed_state(7, lo)
    wrapped = np.asarray(hi) == 1
    assert wrapped.any()
    assert not np.any(np.all(np.asarray(s)[wrapped] == np.asarray(s0)[wrapped],
                             axis=-1))


if _HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), hi=st.integers(1, 2**32 - 1))
    def test_property_hi_word_always_perturbs(seed, hi):
        ids = jnp.arange(64, dtype=jnp.uint32)
        base = xrng.seed_state(jnp.uint32(seed), ids)
        lifted = xrng.seed_state(
            jnp.uint32(seed),
            xrng.PhotonId(lo=ids, hi=jnp.full((64,), hi, jnp.uint32)))
        assert not np.any(np.all(np.asarray(base) == np.asarray(lifted),
                                 axis=-1))


def test_split_id64():
    assert xrng.split_id64(0) == (0, 0)
    assert xrng.split_id64(2**32 - 1) == (2**32 - 1, 0)
    assert xrng.split_id64(2**32) == (0, 1)
    assert xrng.split_id64(3 * 2**32 + 17) == (17, 3)
    with pytest.raises(ValueError):
        xrng.split_id64(-1)
