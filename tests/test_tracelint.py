"""tracelint (REP8xx) tests: per-rule positive/negative jaxpr fixtures,
allowlist semantics, traced-baseline round-trip, and the live-tree
meta-test (the same gate the CI lint-traced job runs).

Fixture targets trace tiny throwaway jnp/pallas functions so each rule
is exercised in milliseconds; the live meta-test traces the real
entrypoint registry and is marked slow.
"""

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.lint.baseline import load_baseline, save_baseline
from repro.lint.traced import (TraceTarget, allowlist_path, iter_eqns,
                               jaxpr_fingerprint, load_allowlist,
                               run_traced_lint, traced_baseline_path)

REPO = Path(__file__).resolve().parents[1]
ENTRY = "src/repro/fixture.py"


def _target(fn, *args, name="fx", group=None, variants=None, make=None):
    mk = make if make is not None else \
        (lambda ov, f=fn, a=args: jax.make_jaxpr(f)(*a))
    return TraceTarget(name=name, entry=ENTRY, make=mk, group=group,
                       variants=dict(variants or {}))


def _run(targets, *rule_ids, **kw):
    return run_traced_lint(REPO, targets=targets,
                           rule_ids=rule_ids or None, **kw)


# ---------------------------------------------------------------- REP801

def test_dtype_flags_f64_in_trace():
    def make(ov):
        from jax.experimental import enable_x64
        with enable_x64():
            return jax.make_jaxpr(
                lambda x: x.astype(jnp.float64).sum())(
                    jnp.ones(4, jnp.float32))
    rep = _run([_target(None, make=make)], "REP801")
    assert any("wide dtype float64" in f.message for f in rep.findings)


def test_dtype_flags_weak_float_output():
    # a bare Python scalar returned from the entrypoint: its dtype is
    # decided by whoever consumes it (weak f32 here, f64 under x64)
    t = _target(lambda x: (x.sum(), jnp.asarray(2.0)),
                jnp.ones(3, jnp.float32))
    rep = _run([t], "REP801")
    assert any("weak-typed" in f.message and "output 1" in f.message
               for f in rep.findings)


def test_dtype_flags_weak_float_eqn():
    t = _target(lambda: jnp.sin(2.0))
    rep = _run([t], "REP801")
    assert any("weak-typed float32" in f.message for f in rep.findings)


def test_dtype_quiet_on_f32_loop():
    # fori_loop lowers its bounds as weak int32 — jax-internal loop
    # counters must NOT be flagged
    def clean(x):
        return jax.lax.fori_loop(0, 3, lambda i, c: c + x, x)
    rep = _run([_target(clean, jnp.float32(0.0))], "REP801")
    assert rep.clean, [f.message for f in rep.findings]


# ---------------------------------------------------------------- REP802

def test_scatter_flags_alias_capable_indices():
    # indices arrive as a traced argument: nothing constrains them to
    # be lane-disjoint
    t = _target(lambda x, idx: x.at[idx].add(1.0),
                jnp.zeros(8, jnp.float32), jnp.zeros(4, jnp.int32))
    rep = _run([t], "REP802")
    assert len(rep.findings) == 1
    assert "alias-capable indices" in rep.findings[0].message


def test_scatter_flags_aliased_pallas_kernel():
    # a deliberately aliased in-kernel scatter: every lane hits the
    # same accumulator slots the traced indices choose
    from jax.experimental import pallas as pl

    def kernel(idx_ref, o_ref):
        o_ref[...] = jnp.zeros_like(o_ref[...]).at[idx_ref[...]].add(1.0)

    def racy(idx):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True)(idx)

    rep = _run([_target(racy, jnp.zeros(4, jnp.int32))], "REP802")
    assert rep.findings, "in-kernel pallas scatter must be analyzed"
    assert all("alias-capable" in f.message for f in rep.findings)


def test_scatter_accepts_provably_disjoint_arange():
    # .at[arange].add — lane-disjoint by construction; the prover must
    # see through jax's negative-index wrap (iota -> select_n -> ...)
    t = _target(lambda x, v: x.at[jnp.arange(4)].add(v),
                jnp.zeros(8, jnp.float32), jnp.ones(4, jnp.float32))
    rep = _run([t], "REP802")
    assert rep.clean, [f.message for f in rep.findings]


def test_scatter_accepts_unique_indices_assertion():
    t = _target(lambda x, idx, v: x.at[idx].add(v, unique_indices=True),
                jnp.zeros(8, jnp.float32), jnp.zeros(4, jnp.int32),
                jnp.ones(4, jnp.float32))
    rep = _run([t], "REP802")
    assert rep.clean


# ---------------------------------------------------------------- REP803

def test_hostsync_flags_callback_in_loop():
    def loopy(x):
        def body(i, c):
            jax.debug.print("i = {i}", i=i)
            return c + 1.0
        return jax.lax.fori_loop(0, 3, body, x)
    rep = _run([_target(loopy, jnp.float32(0.0))], "REP803")
    assert len(rep.findings) == 1
    assert "inside the round loop" in rep.findings[0].message


def test_hostsync_accepts_callback_outside_loop():
    def flat(x):
        jax.debug.print("x = {x}", x=x)
        return x + 1.0
    rep = _run([_target(flat, jnp.float32(0.0))], "REP803")
    assert rep.clean


# ---------------------------------------------------------------- REP804

def test_parity_flags_dtype_mismatch():
    a = _target(lambda x: (x, x.sum()), jnp.ones(4, jnp.float32),
                name="eng-a", group="g")
    b = _target(lambda x: (x, x.sum().astype(jnp.int32)),
                jnp.ones(4, jnp.float32), name="eng-b", group="g")
    rep = _run([a, b], "REP804")
    assert len(rep.findings) == 1
    f = rep.findings[0]
    assert "parity group `g`" in f.message and "output 1" in f.message
    assert "[eng-b]" in f.message  # anchored to the diverging member


def test_parity_flags_output_count_drift():
    a = _target(lambda x: (x, x.sum()), jnp.ones(4, jnp.float32),
                name="eng-a", group="g")
    b = _target(lambda x: (x,), jnp.ones(4, jnp.float32),
                name="eng-b", group="g")
    rep = _run([a, b], "REP804")
    assert any("1 outputs vs 2" in f.message for f in rep.findings)


def test_parity_quiet_on_matching_groups():
    a = _target(lambda x: (x, x.sum()), jnp.ones(4, jnp.float32),
                name="eng-a", group="g")
    b = _target(lambda x: (x * 2.0, x.max()), jnp.ones(4, jnp.float32),
                name="eng-b", group="g")
    ungrouped = _target(lambda x: x.astype(jnp.int32),
                        jnp.ones(4, jnp.float32), name="other")
    rep = _run([a, b, ungrouped], "REP804")
    assert rep.clean


# ---------------------------------------------------------------- REP805

def test_churn_flags_value_baked_into_trace():
    # the fixture bakes a config field (w_threshold analogue) into the
    # traced program as a literal: every new value forces a retrace
    def make(ov):
        thresh = (ov or {}).get("w_threshold", 1e-4)
        return jax.make_jaxpr(lambda x: x * float(thresh))(
            jnp.ones(3, jnp.float32))
    t = _target(None, make=make,
                variants={"w_threshold": {"w_threshold": 1e-3}})
    rep = _run([t], "REP805")
    assert len(rep.findings) == 1
    assert "changed the jaxpr" in rep.findings[0].message
    assert "w_threshold" in rep.findings[0].message


def test_churn_flags_variant_trace_failure():
    def make(ov):
        n = (ov or {}).get("n", 4)
        if n > 10:
            raise ValueError("n indexes a static table of size 10")
        return jax.make_jaxpr(lambda x: x + 1.0)(jnp.ones(3, jnp.float32))
    t = _target(None, make=make, variants={"n": {"n": 100}})
    rep = _run([t], "REP805")
    assert len(rep.findings) == 1
    assert "failed to trace" in rep.findings[0].message


def test_churn_quiet_when_values_stay_traced():
    def make(ov):
        seed = (ov or {}).get("seed", 1)
        return jax.make_jaxpr(
            lambda x, s: x * s.astype(jnp.float32))(
                jnp.ones(3, jnp.float32), jnp.uint32(seed))
    t = _target(None, make=make, variants={"seed": {"seed": 99}})
    rep = _run([t], "REP805")
    assert rep.clean


def test_jaxpr_fingerprint_tracks_weak_type():
    strong = jax.make_jaxpr(lambda x: x)(jnp.float32(1.0))
    weak = jax.make_jaxpr(lambda x: x)(1.0)
    assert jaxpr_fingerprint(strong) != jaxpr_fingerprint(weak)


# ------------------------------------------------------------ engine

def test_trace_failure_becomes_rep800_finding():
    def boom(ov):
        raise RuntimeError("no such entrypoint")
    bad = _target(None, make=boom, name="broken")
    good = _target(lambda x: x + 1.0, jnp.ones(3, jnp.float32))
    rep = _run([bad, good])
    assert any(f.rule == "REP800" and "broken" in f.message
               for f in rep.findings)
    # the healthy target was still traced and linted
    assert rep.n_modules == 2


def test_iter_eqns_reaches_nested_loop_bodies():
    def nested(x):
        def outer(i, c):
            return jax.lax.fori_loop(0, 2, lambda j, d: d + 1.0, c)
        return jax.lax.fori_loop(0, 3, outer, x)
    closed = jax.make_jaxpr(nested)(jnp.float32(0.0))
    depths = [d for _, _, d in iter_eqns(closed)]
    assert max(depths) >= 2  # inner loop body sits two loops deep


# ---------------------------------------------------------- allowlist

def _racy_target():
    return _target(lambda x, idx: x.at[idx].add(1.0),
                   jnp.zeros(8, jnp.float32), jnp.zeros(4, jnp.int32))


def test_allowlist_suppresses_with_why():
    allow = [{"rule": "REP802", "target": "fx",
              "match": "alias-capable", "why": "fixture"}]
    rep = _run([_racy_target()], "REP802", allowlist=allow)
    assert rep.clean
    assert rep.suppressed_pragma == 1


def test_allowlist_max_caps_absorption():
    def two_scatters(x, idx):
        return x.at[idx].add(1.0), x.at[idx].add(2.0)
    t = _target(two_scatters, jnp.zeros(8, jnp.float32),
                jnp.zeros(4, jnp.int32))
    allow = [{"rule": "REP802", "target": "fx", "max": 1,
              "why": "only one grandfathered scatter"}]
    rep = _run([t], "REP802", allowlist=allow)
    assert len(rep.findings) == 1  # the second scatter still surfaces
    assert rep.suppressed_pragma == 1


def test_allowlist_requires_why(tmp_path):
    p = tmp_path / "allow.json"
    p.write_text(json.dumps(
        {"version": 1, "allow": [{"rule": "REP802", "why": "  "}]}))
    with pytest.raises(ValueError, match="why"):
        load_allowlist(p)


def test_allowlist_rejects_bad_version(tmp_path):
    p = tmp_path / "allow.json"
    p.write_text(json.dumps({"version": 99, "allow": []}))
    with pytest.raises(ValueError, match="version"):
        load_allowlist(p)


def test_allowlist_missing_file_is_empty(tmp_path):
    assert load_allowlist(tmp_path / "nope.json") == []


# ------------------------------------------------------------ baseline

def test_traced_baseline_round_trip(tmp_path):
    rep = _run([_racy_target()], "REP802")
    assert len(rep.findings) == 1
    bp = tmp_path / ".tracelint.json"
    save_baseline(bp, rep)
    rep2 = _run([_racy_target()], "REP802", baseline=load_baseline(bp))
    assert rep2.clean
    assert rep2.suppressed_baseline == 1


# ------------------------------------------------------ live-tree meta

@pytest.mark.slow
def test_live_tree_is_tracelint_clean():
    """The committed tree must stay tracelint-clean modulo the
    committed allowlist, with an EMPTY traced baseline — the gate the
    CI lint-traced job runs."""
    baseline = load_baseline(traced_baseline_path(REPO))
    assert baseline == {}, "policy: the traced baseline stays empty"
    allow = load_allowlist(allowlist_path(REPO))
    assert allow, "the live tree's scatter allowlist must be committed"
    rep = run_traced_lint(REPO, baseline=baseline, allowlist=allow)
    assert rep.clean, "\n".join(f.format() for f in rep.findings)
    assert rep.n_modules >= 6  # both engines x sim/replay/pool at least
    assert set(rep.rules_run) == {"REP801", "REP802", "REP803",
                                  "REP804", "REP805"}
    # the allowlist absorbed the documented scatter accumulators and
    # nothing else was needed
    assert rep.suppressed_pragma > 0
    assert rep.suppressed_baseline == 0


@pytest.mark.slow
def test_cli_tier_traced_json():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--tier", "traced",
         "--format", "json"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(proc.stdout)
    assert data["clean"] is True
    assert data["tier"] == "traced"
    assert set(data["rules"]) == {"REP801", "REP802", "REP803",
                                  "REP804", "REP805"}


def test_cli_list_rules_all_tiers():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--tier", "all",
         "--list-rules"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "REP101" in proc.stdout and "REP805" in proc.stdout
