"""Time-resolved recording (DESIGN.md §time-resolved).

Contracts under test:

  * ``n_time_gates=1`` (the CW default) is **bit-identical** to the
    pre-PR (PR-2 fused) engine at K=1 and K=4 — the ungated round
    executor is embedded verbatim below as the reference.  The gated
    scatter index ``voxel * ntg + gate`` degenerates to ``voxel`` at
    ntg=1, so this holds exactly, not just to tolerance.
  * Summing ``fluence_td`` over gates reproduces ``fluence_cw``
    bit-for-bit on the same result (jnp engine, any K, any gate count)
    — the gate axis partitions deposition, it never rescales it — and
    the gate-summed energy of an ntg>1 run matches the CW run of the
    same photon set to fp-accumulation tolerance (for both engines).
  * Detector TPSF capture: detected weight is a subset of the z=0-face
    exitance, is identical across schedulers (chunked vs one-shot), and
    the analysis helpers (tpsf / detector_mean_ppath / rescale_detected)
    are consistent with the raw histograms.
"""

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import photon as ph
from repro.core import simulator as S
from repro.core import volume as V
from repro.detectors import Detector, as_detectors, det_geometry
from repro.sources import as_source


# ---------------------------------------------------------------------------
# Verbatim copy of the PR-2 fused engine: K fused segments per round, ONE
# ungated (nvox,) energy scatter per round — the "current engine" the
# ntg=1 path must reproduce bit-for-bit at any K.
# ---------------------------------------------------------------------------

class _Pr2Carry(NamedTuple):
    state: ph.PhotonState
    energy: jnp.ndarray
    exitance: jnp.ndarray
    escaped_w: jnp.ndarray
    remaining: jnp.ndarray
    launched_per_lane: jnp.ndarray
    next_id: jnp.ndarray
    launched_w: jnp.ndarray
    steps: jnp.ndarray


def _pr2_fused_sim_fn(shape, unitinmm, cfg, n_lanes, mode="dynamic",
                      source=None):
    source = as_source(source)
    nx, ny, nz = shape
    nvox = nx * ny * nz
    nxy = nx * ny
    K = int(cfg.steps_per_round)

    def sim_fn(labels_flat, media, n_photons, seed, id_offset=0):
        n_photons = jnp.asarray(n_photons, jnp.int32)
        seed = jnp.asarray(seed, jnp.uint32)
        id_offset = jnp.asarray(id_offset, jnp.int32)
        lane_idx = jnp.arange(n_lanes, dtype=jnp.int32)
        quota = n_photons // n_lanes + (lane_idx < n_photons % n_lanes)
        state0 = ph.PhotonState(
            pos=jnp.zeros((n_lanes, 3), jnp.float32),
            dir=jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32),
                         (n_lanes, 1)),
            ivox=jnp.zeros((n_lanes, 3), jnp.int32),
            w=jnp.zeros((n_lanes,), jnp.float32),
            s_left=jnp.zeros((n_lanes,), jnp.float32),
            t=jnp.zeros((n_lanes,), jnp.float32),
            rng=jnp.zeros((n_lanes, 4), jnp.uint32),
            alive=jnp.zeros((n_lanes,), bool),
        )
        # _maybe_regenerate now carries the id counter as a 64-bit
        # (lo, hi) uint32 pair; hi=0 is bit-identical to the PR-2 int32
        # counter, so the verbatim copy keeps its contract
        carry0 = _Pr2Carry(
            state0, jnp.zeros((nvox,), jnp.float32),
            jnp.zeros((nxy,), jnp.float32), jnp.float32(0.0), n_photons,
            jnp.zeros((n_lanes,), jnp.int32),
            (id_offset.astype(jnp.uint32), jnp.uint32(0)),
            jnp.float32(0.0), jnp.int32(0),
        )

        def cond(c):
            has_work = jnp.any(c.state.alive)
            if mode == "dynamic":
                has_work = has_work | (c.remaining > 0)
            else:
                has_work = has_work | jnp.any(c.launched_per_lane < quota)
            return has_work & (c.steps < cfg.max_steps)

        def round_jnp(state):
            def seg(k, rc):
                st, dep_i, dep_w, ex_i, ex_w, esc = rc
                res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
                dep_i = dep_i.at[k].set(res.dep_idx)
                dep_w = dep_w.at[k].set(res.dep_w)
                xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
                ex_i = ex_i.at[k].set(xy)
                ex_w = ex_w.at[k].set(xw)
                esc = esc + jnp.sum(res.esc_w)
                return (res.state, dep_i, dep_w, ex_i, ex_w, esc)

            init = (
                state,
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.float32(0.0),
            )
            return jax.lax.fori_loop(0, K, seg, init)

        def body(c):
            state, remaining, launched, next_id, w_new = S._maybe_regenerate(
                c.state, c.remaining, c.launched_per_lane, c.next_id,
                quota, source, seed, mode, shape,
            )
            state, dep_i, dep_w, ex_i, ex_w, esc = round_jnp(state)
            energy = c.energy.at[dep_i.reshape(-1)].add(dep_w.reshape(-1))
            exitance = c.exitance.at[ex_i.reshape(-1)].add(ex_w.reshape(-1))
            return _Pr2Carry(state, energy, exitance, c.escaped_w + esc,
                             remaining, launched, next_id,
                             c.launched_w + w_new, c.steps + K)

        final = jax.lax.while_loop(cond, body, carry0)
        return S.SimResult(
            energy=final.energy.reshape(shape),
            exitance=final.exitance.reshape((nx, ny)),
            escaped_w=final.escaped_w,
            n_launched=(final.next_id[0]
                        - id_offset.astype(jnp.uint32)).astype(jnp.int32),
            launched_w=final.launched_w,
            steps=final.steps,
        )

    return sim_fn


SHAPE = (16, 16, 16)
N_PHOTONS = 2500
LANES = 512
SEED = 17


def _bench(reflect=False):
    vol = V.benchmark_b2(SHAPE) if reflect else V.benchmark_b1(SHAPE)
    return vol, V.SimConfig(do_reflect=reflect)


def _run(vol, cfg, engine="jnp", lanes=LANES, detectors=None,
         n_photons=N_PHOTONS):
    fn = jax.jit(S.build_sim_fn(vol.shape, vol.unitinmm, cfg, lanes,
                                engine=engine, detectors=detectors))
    return fn(vol.labels.reshape(-1), vol.media, n_photons, SEED, 0)


# ---------------------------------------------------------------------------
# ntg=1 — bit-identical to the pre-PR engine at K=1 and K=4
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 4])
@pytest.mark.parametrize("reflect", [False, True])
def test_ntg1_bit_identical_to_ungated_engine(k, reflect):
    vol, cfg = _bench(reflect)
    cfg = dataclasses.replace(cfg, steps_per_round=k)
    assert cfg.n_time_gates == 1
    ref_fn = jax.jit(_pr2_fused_sim_fn(vol.shape, vol.unitinmm, cfg, LANES))
    ref = ref_fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED)
    res = _run(vol, cfg)
    np.testing.assert_array_equal(np.asarray(ref.energy),
                                  np.asarray(res.energy))
    np.testing.assert_array_equal(np.asarray(ref.exitance),
                                  np.asarray(res.exitance))
    assert float(ref.escaped_w) == float(res.escaped_w)
    assert int(ref.n_launched) == int(res.n_launched)
    assert float(ref.launched_w) == float(res.launched_w)
    assert int(ref.steps) == int(res.steps)


# ---------------------------------------------------------------------------
# gate-sum properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ntg", [1, 3, 8])
@pytest.mark.parametrize("k", [1, 4])
def test_fluence_td_gate_sum_is_fluence_cw_bitwise(ntg, k):
    """Summing fluence_td over gates IS fluence_cw, bit for bit, for any
    (K, gate count) on the jnp engine — the normalization is shared."""
    vol, cfg = _bench(False)
    cfg = dataclasses.replace(cfg, steps_per_round=k, n_time_gates=ntg)
    res = _run(vol, cfg)
    td = np.asarray(A.fluence_td(res, vol))
    assert td.shape == vol.shape + (ntg,)
    cw = np.asarray(A.fluence_cw(res, vol))
    np.testing.assert_array_equal(td.sum(axis=-1), cw)


@pytest.mark.parametrize("ntg", [2, 5, 16])
def test_gated_energy_sums_to_cw_run(ntg):
    """An ntg>1 run simulates the identical photon set as the CW run;
    its gate-summed energy matches to fp-accumulation tolerance and the
    overall accounting is exact."""
    vol, cfg = _bench(False)
    res_cw = _run(vol, cfg)
    res_td = _run(vol, dataclasses.replace(cfg, n_time_gates=ntg))
    assert res_td.energy.shape == vol.shape + (ntg,)
    assert int(res_cw.n_launched) == int(res_td.n_launched)
    assert float(res_cw.launched_w) == float(res_td.launched_w)
    assert int(res_cw.steps) == int(res_td.steps)
    np.testing.assert_allclose(np.asarray(res_td.energy).sum(axis=-1),
                               np.asarray(res_cw.energy),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res_td.exitance),
                                  np.asarray(res_cw.exitance))
    # early gates fill first for a source on the z=0 face
    per_gate = np.asarray(res_td.energy).sum(axis=(0, 1, 2))
    assert per_gate[0] > 0


@pytest.mark.parametrize("k", [4])
def test_pallas_engine_gated_matches_jnp(k):
    vol, cfg = _bench(False)
    cfg = dataclasses.replace(cfg, steps_per_round=k, n_time_gates=6)
    res_j = _run(vol, cfg, engine="jnp", lanes=256)
    res_p = _run(vol, cfg, engine="pallas", lanes=256)
    assert int(res_j.n_launched) == int(res_p.n_launched)
    np.testing.assert_allclose(np.asarray(res_j.energy),
                               np.asarray(res_p.energy),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_j.energy).sum(-1),
                               np.asarray(_run(vol, dataclasses.replace(
                                   cfg, n_time_gates=1), lanes=256).energy),
                               rtol=5e-5, atol=1e-5)


def test_timed_out_accounting_short_gate():
    """With a tight tmax the balance closes through timed_out, for both
    engines and any gate count."""
    vol, cfg = _bench(False)
    cfg = dataclasses.replace(cfg, tmax_ns=0.08, n_time_gates=4,
                              steps_per_round=4)
    for engine in ("jnp", "pallas"):
        res = _run(vol, cfg, engine=engine, lanes=256)
        bal = A.energy_balance(res)
        assert bal["timed_out"] > 0
        assert abs(bal["residue_frac"]) < 1e-5, (engine, bal)


# ---------------------------------------------------------------------------
# detector TPSF capture
# ---------------------------------------------------------------------------

_DETS = (Detector(8.0, 8.0, 5.0), Detector(2.0, 2.0, 2.0))


def _pencil_center():
    from repro import sources as SRC

    return SRC.Pencil(pos=(8.0, 8.0, 0.0))


def _run_det(cfg, engine="jnp", lanes=256):
    vol, _ = _bench(False)
    fn = jax.jit(S.build_sim_fn(vol.shape, vol.unitinmm, cfg, lanes,
                                source=_pencil_center(), engine=engine,
                                detectors=_DETS))
    return vol, fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED, 0)


def test_detected_weight_subset_of_exitance():
    cfg = dataclasses.replace(V.SimConfig(), n_time_gates=8,
                              steps_per_round=2)
    _, res = _run_det(cfg)
    assert res.det_w.shape == (2, 8)
    assert res.det_ppath.shape == (2, 2)
    tot = float(np.asarray(res.det_w).sum())
    assert 0 < tot <= float(np.asarray(res.exitance).sum()) + 1e-4
    # the central detector sits under the beam: it must catch more
    assert float(np.asarray(res.det_w)[0].sum()) > \
        float(np.asarray(res.det_w)[1].sum())


def test_detectors_do_not_perturb_physics():
    """Detector capture is pure observation: energy/exitance/accounting
    are bit-identical with and without detectors."""
    cfg = dataclasses.replace(V.SimConfig(), n_time_gates=4)
    vol, res_det = _run_det(cfg)
    fn = jax.jit(S.build_sim_fn(vol.shape, vol.unitinmm, cfg, 256,
                                source=_pencil_center()))
    res_plain = fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED, 0)
    np.testing.assert_array_equal(np.asarray(res_det.energy),
                                  np.asarray(res_plain.energy))
    np.testing.assert_array_equal(np.asarray(res_det.exitance),
                                  np.asarray(res_plain.exitance))
    assert float(res_det.escaped_w) == float(res_plain.escaped_w)
    assert int(res_det.steps) == int(res_plain.steps)


def test_detector_capture_engine_parity():
    cfg = dataclasses.replace(V.SimConfig(), n_time_gates=8,
                              steps_per_round=4)
    _, res_j = _run_det(cfg, engine="jnp")
    _, res_p = _run_det(cfg, engine="pallas")
    np.testing.assert_allclose(np.asarray(res_j.det_w),
                               np.asarray(res_p.det_w),
                               rtol=5e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_j.det_ppath),
                               np.asarray(res_p.det_ppath),
                               rtol=5e-5, atol=1e-5)


def test_tpsf_and_ppath_helpers():
    cfg = dataclasses.replace(V.SimConfig(), n_time_gates=8)
    vol, res = _run_det(cfg)
    times, curves = A.tpsf(res, cfg)
    assert times.shape == (8,) and curves.shape == (2, 8)
    # un-normalizing recovers the raw histogram
    np.testing.assert_allclose(
        curves * float(res.launched_w) * cfg.gate_width_ns,
        np.asarray(res.det_w), rtol=1e-6)
    # early-photon peak: the TPSF must peak before the last gate for a
    # detector adjacent to the source
    assert int(np.argmax(curves[0])) < 7
    mean_l = A.detector_mean_ppath(res)
    assert mean_l.shape == (2, 2)
    assert mean_l[0, 0] == 0.0  # medium 0 is exterior air: no pathlength
    assert mean_l[0, 1] > 0.0
    # rescaling to the SAME mua returns the detected weight unchanged;
    # higher absorption must attenuate it
    base = A.rescale_detected(res, vol, np.asarray(vol.media)[:, 0])
    np.testing.assert_allclose(base, np.asarray(res.det_w).sum(axis=1),
                               rtol=1e-6)
    up = np.asarray(vol.media)[:, 0] + np.asarray([0.0, 0.01])
    assert (A.rescale_detected(res, vol, up) < base + 1e-12).all()
    with pytest.raises(ValueError, match="gates"):
        A.tpsf(res, dataclasses.replace(cfg, n_time_gates=4))


def test_detector_results_match_across_chunked_run():
    """TPSF accumulators obey the same id-keyed determinism contract as
    the fluence grids: a chunked run over the same photon ids merges to
    the one-shot result to fp tolerance."""
    from repro.core.multidevice import ElasticSimulator

    vol, _ = _bench(False)
    cfg = dataclasses.replace(V.SimConfig(), n_time_gates=4)
    es = ElasticSimulator(vol, cfg, N_PHOTONS, 500, n_lanes=256, seed=SEED,
                          source=_pencil_center(), detectors=_DETS)
    res_chunked = es.run_to_completion()
    fn = jax.jit(S.build_sim_fn(vol.shape, vol.unitinmm, cfg, 256,
                                source=_pencil_center(), detectors=_DETS))
    res_one = fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED, 0)
    np.testing.assert_allclose(np.asarray(res_chunked.det_w),
                               np.asarray(res_one.det_w),
                               rtol=5e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_chunked.det_ppath),
                               np.asarray(res_one.det_ppath),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_chunked.energy),
                               np.asarray(res_one.energy),
                               rtol=5e-5, atol=1e-5)
    # checkpoint round-trip preserves the new accumulators
    state = es.state_dict()
    es2 = ElasticSimulator(vol, cfg, N_PHOTONS, 500, n_lanes=256, seed=SEED,
                           source=_pencil_center(), detectors=_DETS)
    es2.load_state_dict(state)
    np.testing.assert_array_equal(np.asarray(es2.result().det_w),
                                  np.asarray(res_chunked.det_w))
    assert es2.result().timed_out_w == res_chunked.timed_out_w
    # a mismatched detector set must refuse the checkpoint
    es3 = ElasticSimulator(vol, cfg, N_PHOTONS, 500, n_lanes=256, seed=SEED,
                           source=_pencil_center(),
                           detectors=(Detector(4.0, 4.0, 1.0),))
    with pytest.raises(AssertionError, match="detector mismatch"):
        es3.load_state_dict(state)
    # and a gate-count mismatch is caught by the grid-shape check
    es4 = ElasticSimulator(vol, dataclasses.replace(cfg, n_time_gates=8),
                           N_PHOTONS, 500, n_lanes=256, seed=SEED,
                           source=_pencil_center(), detectors=_DETS)
    with pytest.raises(AssertionError, match="energy grid mismatch"):
        es4.load_state_dict(state)


def test_detector_validation():
    with pytest.raises(ValueError, match="radius"):
        Detector(1.0, 1.0, 0.0)
    assert as_detectors(None) == ()
    dets = as_detectors([(1, 2, 3), {"x": 4, "y": 5, "radius": 6}])
    assert dets == (Detector(1.0, 2.0, 3.0), Detector(4.0, 5.0, 6.0))
    geom = np.asarray(det_geometry(dets))
    np.testing.assert_allclose(geom, [[1, 2, 9], [4, 5, 36]])
    with pytest.raises(ValueError, match="n_time_gates"):
        S.build_sim_fn((8, 8, 8), 1.0,
                       dataclasses.replace(V.SimConfig(), n_time_gates=0),
                       128)


# ---------------------------------------------------------------------------
# time_gate_bins edge contract (PR 4): the replay exit-gate index reuses
# this helper, so its clip-into-last-gate behavior is pinned here
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ntg", [1, 4, 32])
def test_gate_bins_edge_times(ntg):
    tmax = 5.0
    gw = tmax / ntg
    # exact edges: t=0 -> first gate; t=tmax (and beyond) clips into the
    # last gate — deposits of the partial segment crossing tmax belong
    # to the final gate, never out of range
    t = jnp.asarray([0.0, gw * 0.5, tmax - 1e-4, tmax, tmax + 1e-3,
                     10.0 * tmax], jnp.float32)
    g = np.asarray(ph.time_gate_bins(t, tmax, ntg))
    assert g[0] == 0
    assert g[-3] == ntg - 1   # t == tmax clips, not overflows
    assert g[-2] == ntg - 1   # t > tmax clips into the last gate
    assert g[-1] == ntg - 1
    assert g.min() >= 0 and g.max() < ntg
    # interior times land in their analytic gate
    assert g[1] == 0
    assert g[2] == ntg - 1


def test_gate_bins_cover_every_gate():
    ntg, tmax = 8, 4.0
    centers = (np.arange(ntg) + 0.5) * tmax / ntg
    g = np.asarray(ph.time_gate_bins(jnp.asarray(centers, jnp.float32),
                                     tmax, ntg))
    np.testing.assert_array_equal(g, np.arange(ntg))


try:  # property test: hypothesis is optional locally, pinned in CI
    from hypothesis import given, settings, strategies as hst
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(
        ntg=hst.integers(1, 64),
        tmax=hst.floats(1e-2, 100.0, allow_nan=False),
        ts=hst.lists(hst.floats(0.0, 1000.0, allow_nan=False), min_size=1,
                     max_size=32),
    )
    def test_property_gate_bins_in_range_and_monotone(ntg, tmax, ts):
        t = jnp.asarray(np.asarray(sorted(ts), np.float32))
        g = np.asarray(ph.time_gate_bins(t, tmax, ntg))
        assert g.min() >= 0 and g.max() <= ntg - 1
        assert (np.diff(g) >= 0).all()  # nondecreasing in time


# ---------------------------------------------------------------------------
# detector geometry validation (PR 4): disks that miss the volume
# footprint fail at make_simulator time with an actionable error
# ---------------------------------------------------------------------------

def test_detector_outside_footprint_rejected():
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig()
    # fully outside the (nx, ny) footprint — e.g. mm coordinates used on
    # a voxel-unit API
    with pytest.raises(ValueError, match="entirely outside the z=0 face"):
        S.make_simulator(vol, cfg, 128, detectors=[Detector(40.0, 8.0, 2.0)])
    # beyond a corner, radius too small to reach the face
    with pytest.raises(ValueError, match="entirely outside"):
        S.make_simulator(vol, cfg, 128,
                         detectors=[Detector(20.0, 20.0, 3.0)])
    # tangent disks (closest approach == radius) capture nothing: reject
    with pytest.raises(ValueError, match="entirely outside"):
        S.make_simulator(vol, cfg, 128,
                         detectors=[Detector(18.0, 8.0, 2.0)])
    # the error names the offending detector index
    with pytest.raises(ValueError, match="detector 1 "):
        S.make_simulator(vol, cfg, 128,
                         detectors=[Detector(8.0, 8.0, 2.0),
                                    Detector(-9.0, 8.0, 2.0)])


def test_detector_overhanging_edge_accepted():
    """A disk overhanging the footprint edge still captures on the
    overlap — it must pass validation, and a centered one obviously
    does."""
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig()
    for det in (Detector(0.0, 0.0, 2.0),      # corner, center on the rim
                Detector(17.0, 8.0, 2.0),     # center outside, overlaps
                Detector(8.0, 8.0, 30.0)):    # disk swallows the face
        fn = S.make_simulator(vol, cfg, 128, detectors=[det])
        res = fn(vol.labels.reshape(-1), vol.media, 200, 3)
        assert np.asarray(res.det_w).shape[0] == 1
