"""Physics validation of the photon transport core against ground truth.

The paper's own validation is "all simulations are verified to produce
correct solutions"; since wall-clock numbers don't transfer across
hardware, correctness here means: exact energy conservation, HG sampling
moments, Fresnel limits, diffusion-theory attenuation, and equivalence
of the optimized kernel variants with the oracle.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import photon as ph
from repro.core import rng as xrng
from repro.core import simulator as S
from repro.core import volume as V


@functools.lru_cache(maxsize=None)
def _run_b1(n_photons=15_000, lanes=2048, seed=42, shape=(40, 40, 40),
            deposit_mode="exact", specialize=True, mode="dynamic"):
    vol = V.benchmark_b1(shape)
    cfg = V.SimConfig(do_reflect=False, deposit_mode=deposit_mode,
                      specialize=specialize)
    res = S.simulate(vol, cfg, n_photons, lanes, seed, mode=mode)
    jax.block_until_ready(res)
    return vol, res


@functools.lru_cache(maxsize=None)
def _run_b2(n_photons=15_000, lanes=2048, seed=42, shape=(40, 40, 40),
            specialize=True):
    vol = V.benchmark_b2(shape)
    cfg = V.SimConfig(do_reflect=True, specialize=specialize)
    res = S.simulate(vol, cfg, n_photons, lanes, seed)
    jax.block_until_ready(res)
    return vol, res


# ---------------------------------------------------------------------------
# conservation + statistics
# ---------------------------------------------------------------------------

def test_b1_energy_conservation():
    # timed-out weight is tracked apart from the roulette residue, so the
    # residue bound is ~25x tighter than the old 1e-4 (which had to
    # absorb time-gate losses it could not distinguish)
    _, res = _run_b1()
    bal = A.energy_balance(res)
    assert bal["launched"] == 15_000
    assert bal["timed_out"] >= 0.0
    assert abs(bal["residue_frac"]) < 1e-5


def test_b2_energy_conservation():
    _, res = _run_b2()
    bal = A.energy_balance(res)
    assert bal["timed_out"] >= 0.0
    assert abs(bal["residue_frac"]) < 1e-5


def test_b1_axial_decay_matches_diffusion_theory():
    # paper geometry: 60 mm cube, source at the face center (30, 30, 0)
    vol, res = _run_b1(n_photons=30_000, lanes=4096, shape=(60, 60, 60))
    mu_fit = A.fit_axial_decay(res, vol, (10, 35), axis_xy=(30, 30))
    mu_th = A.mu_eff_theory(0.005, 1.0, 0.01)
    # small residual steepening from the finite absorbing cube is expected
    assert 0.9 * mu_th < mu_fit < 1.25 * mu_th


def test_b2_sphere_increases_absorption():
    _, res1 = _run_b1()
    _, res2 = _run_b2()
    # high-scattering sphere + internal reflections trap more energy
    assert float(jnp.sum(res2.energy)) > float(jnp.sum(res1.energy))


def test_exitance_is_reciprocal_near_source():
    vol, res = _run_b1()
    ex = np.asarray(res.exitance)
    # diffuse reflectance peaks near the source entry point (paper source
    # at (30, 30, 0) mm, 1 mm voxels)
    sx, sy = 30, 30
    peak = np.unravel_index(np.argmax(ex), ex.shape)
    assert abs(peak[0] - sx) <= 2 and abs(peak[1] - sy) <= 2


def test_determinism_same_seed():
    _, r1 = _run_b1(seed=9)
    vol = V.benchmark_b1((40, 40, 40))
    cfg = V.SimConfig(do_reflect=False)
    r2 = S.simulate(vol, cfg, 15_000, 2048, 9)
    np.testing.assert_array_equal(np.asarray(r1.energy), np.asarray(r2.energy))


def test_different_seed_differs():
    _, r1 = _run_b1(seed=9)
    _, r2 = _run_b1(seed=10)
    assert not np.array_equal(np.asarray(r1.energy), np.asarray(r2.energy))


# ---------------------------------------------------------------------------
# kernel-variant equivalence (Opt1/Opt3 vs oracle)
# ---------------------------------------------------------------------------

def test_specialized_kernel_bitwise_matches_general():
    """Opt3 changes the compiled graph, not the trajectories."""
    _, r_spec = _run_b1(specialize=True)
    _, r_gen = _run_b1(specialize=False)
    np.testing.assert_allclose(
        np.asarray(r_spec.energy), np.asarray(r_gen.energy), rtol=0, atol=1e-6
    )
    assert int(r_spec.steps) == int(r_gen.steps)


def test_specialized_kernel_matches_general_b2():
    _, r_spec = _run_b2(specialize=True)
    _, r_gen = _run_b2(specialize=False)
    np.testing.assert_allclose(
        np.asarray(r_spec.energy), np.asarray(r_gen.energy), rtol=0, atol=1e-6
    )


def test_taylor_deposit_close_to_exact():
    """Opt1 trades one exp() per segment for <1% deposition error."""
    _, r_exact = _run_b1(deposit_mode="exact")
    _, r_taylor = _run_b1(deposit_mode="taylor")
    e1 = float(jnp.sum(r_exact.energy))
    e2 = float(jnp.sum(r_taylor.energy))
    assert abs(e1 - e2) / e1 < 0.02
    bal = A.energy_balance(r_taylor)
    assert abs(bal["residue_frac"]) < 1e-4


def test_static_and_dynamic_modes_agree_statistically():
    _, r_dyn = _run_b1(mode="dynamic")
    _, r_sta = _run_b1(mode="static")
    assert int(r_sta.n_launched) == int(r_dyn.n_launched)
    a = float(jnp.sum(r_dyn.energy))
    b = float(jnp.sum(r_sta.energy))
    assert abs(a - b) / a < 0.05  # same distribution, different photon ids


# ---------------------------------------------------------------------------
# micro-physics units
# ---------------------------------------------------------------------------

def test_hg_mean_cosine():
    """<cos theta> of the HG sampler must equal g."""
    n = 60_000
    state = xrng.seed_state(3, jnp.arange(n, dtype=jnp.uint32))
    state, u_cos = xrng.next_uniform(state)
    state, u_phi = xrng.next_uniform(state)
    d0 = jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32), (n, 1))
    for g in (0.0, 0.01, 0.9):
        out = ph._hg_scatter(d0, jnp.full((n,), g, jnp.float32), u_cos, u_phi)
        mean_cos = float(jnp.mean(out[:, 2]))  # cos vs original +z axis
        assert abs(mean_cos - g) < 0.01, (g, mean_cos)
        norms = np.asarray(jnp.linalg.norm(out, axis=-1))
        np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_fresnel_normal_incidence():
    r, cos_t, tir = ph._fresnel(
        jnp.asarray([1.37]), jnp.asarray([1.0]), jnp.asarray([1.0])
    )
    expected = ((1.37 - 1.0) / (1.37 + 1.0)) ** 2
    np.testing.assert_allclose(float(r[0]), expected, rtol=1e-5)
    assert not bool(tir[0])


def test_fresnel_total_internal_reflection():
    # critical angle for 1.37 -> 1.0 is asin(1/1.37) ~ 46.9 deg
    cos_i = jnp.cos(jnp.deg2rad(jnp.asarray([60.0])))  # beyond critical
    r, _, tir = ph._fresnel(jnp.asarray([1.37]), jnp.asarray([1.0]), cos_i)
    assert bool(tir[0]) and float(r[0]) == 1.0


def test_fresnel_grazing_reflects():
    r, _, _ = ph._fresnel(
        jnp.asarray([1.0]), jnp.asarray([1.37]), jnp.asarray([1e-4])
    )
    assert float(r[0]) > 0.95


def test_boundary_distance_simple():
    pos = jnp.asarray([[0.5, 0.5, 0.5]], jnp.float32)
    ivox = jnp.asarray([[0, 0, 0]], jnp.int32)
    d, ax = ph._boundary_distance(
        pos, jnp.asarray([[1.0, 0.0, 0.0]], jnp.float32), ivox
    )
    np.testing.assert_allclose(float(d[0]), 0.5, rtol=1e-6)
    assert int(ax[0]) == 0
    d, ax = ph._boundary_distance(
        pos, jnp.asarray([[0.0, 0.0, -1.0]], jnp.float32), ivox
    )
    np.testing.assert_allclose(float(d[0]), 0.5, rtol=1e-6)
    assert int(ax[0]) == 2


def test_time_gate_terminates():
    vol = V.benchmark_b1((40, 40, 40))
    cfg = V.SimConfig(do_reflect=False, tmax_ns=0.05)  # ~11 mm of path
    res = S.simulate(vol, cfg, 2000, 512, 3)
    bal = A.energy_balance(res)
    # gate kills weight in flight: it is accounted as timed_out, NOT as
    # residue — the balance stays closed to roulette statistics even
    # when the gate retires a large fraction of the launched weight
    assert bal["timed_out"] > 100.0  # most photons die at this gate
    assert float(res.timed_out_w) == bal["timed_out"]
    assert abs(bal["residue_frac"]) < 1e-6
    assert int(res.steps) < 2000


def test_off_center_source_axial_fit_clamps():
    """Regression: a beam axis within 2 voxels of the volume edge used to
    produce a negative slice start (empty/wrapped neighborhood) in
    fit_axial_decay; the clamped neighborhood must return a finite
    positive decay slope in the same ballpark as the centered fit."""
    from repro import sources as SRC

    vol = V.benchmark_b1((40, 40, 40))
    cfg = V.SimConfig(do_reflect=False)
    src = SRC.Pencil(pos=(1.0, 20.0, 0.0))  # 1 voxel from the x=0 edge
    res = S.simulate(vol, cfg, 30_000, 4096, 11, source=src)
    mu_fit = A.fit_axial_decay(res, vol, (8, 25), axis_xy=(1, 20))
    assert np.isfinite(mu_fit) and mu_fit > 0
    mu_th = A.mu_eff_theory(0.005, 1.0, 0.01)
    # edge losses steepen the decay vs the infinite-medium theory value,
    # but the clamped fit must stay in a physical range (the wrapped
    # slice used to average in far-side voxels, skewing it arbitrarily)
    assert 0.5 * mu_th < mu_fit < 3.0 * mu_th
    with pytest.raises(ValueError, match="outside volume"):
        A.fit_axial_decay(res, vol, (8, 25), axis_xy=(40, 20))
