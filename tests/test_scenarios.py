"""Batched multi-scenario execution tests (repro.scenarios).

The contract under test (DESIGN.md §batching): ``simulate_many`` over
heterogeneous scenarios is bit-identical per scenario to sequential
``simulate_one`` runs — both engines — with exactly one compile per
distinct config shape, an LRU compile cache whose counters reconcile
against telemetry spans, and a scenario axis that composes with the
device mesh (slow subprocess test, 8 fake devices).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import volume as V
from repro.core.volume import SimConfig
from repro.scenarios import (CompileCache, Scenario, group_key,
                             make_batched, simulate_many, simulate_one)
from repro.sources import Cone, Disk, StagedSource, stage_source
from repro.telemetry import InMemorySink, Tracer

SHAPE = (8, 8, 8)
LANES = 16
DET = ({"x": 4.0, "y": 4.0, "radius": 2.0},)
DET2 = ({"x": 3.0, "y": 5.0, "radius": 2.5},)


def _cfg(**kw):
    base = dict(do_reflect=True, steps_per_round=2, n_time_gates=2,
                max_steps=64)
    base.update(kw)
    return SimConfig(**base)


def _vol(mua_scale=1.0):
    vol = V.benchmark_b1(SHAPE)
    if mua_scale == 1.0:
        return vol
    media = np.asarray(vol.media).copy()
    media[1:, 0] *= mua_scale
    return dataclasses.replace(vol, media=media)


def _heterogeneous():
    """N=5 scenarios spanning 4 config shapes: grouped disks (different
    media/radius/detector coords/seeds/budgets/id offsets), a cone, a
    pencil, and a detector-free CW run with a different SimConfig."""
    return [
        Scenario(_vol(), _cfg(), 200, seed=1,
                 source=Disk(pos=(4, 4, 0), radius=2.0), detectors=DET),
        Scenario(_vol(1.5), _cfg(), 300, seed=2,
                 source=Disk(pos=(4, 4, 0), radius=1.0), detectors=DET2,
                 id_offset=1000),
        Scenario(_vol(), _cfg(), 150, seed=3,
                 source=Cone(pos=(4, 4, 0), half_angle_deg=25.0),
                 detectors=DET),
        Scenario(_vol(), _cfg(), 250, seed=4, detectors=DET2),
        Scenario(_vol(), SimConfig(do_reflect=True), 100, seed=5),
    ]


def _assert_results_equal(got, want, ctx=""):
    for f in want._fields:
        a, b = getattr(got, f), getattr(want, f)
        if a is None and b is None:
            continue
        assert np.array_equal(np.asarray(a), np.asarray(b)), (ctx, f)


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_simulate_many_bit_identical_to_sequential(engine):
    scs = _heterogeneous()
    cache = CompileCache()
    res = simulate_many(scs, n_lanes=LANES, engine=engine, block_lanes=8,
                        interpret=True, cache=cache)
    assert len(res) == len(scs)
    for i, sc in enumerate(scs):
        ref = simulate_one(sc, n_lanes=LANES, engine=engine, block_lanes=8,
                           interpret=True)
        _assert_results_equal(res[i], ref, ctx=(engine, i))
    # exactly one compile per distinct config shape: the two disks share
    # a group; cone/pencil/no-det each get their own
    keys = {group_key(sc, LANES, engine=engine, block_lanes=8,
                      interpret=True) for sc in scs}
    assert cache.misses == len(keys) == 4
    assert cache.hits == 0


def test_same_shape_hit_across_calls():
    def batch(seed0):
        return [Scenario(_vol(), _cfg(), 100 + 40 * i, seed=seed0 + i,
                         source=Disk(pos=(4, 4, 0), radius=1.0 + 0.3 * i),
                         detectors=DET, id_offset=10_000 * i)
                for i in range(4)]

    cache = CompileCache()
    r1 = simulate_many(batch(1), n_lanes=LANES, cache=cache)
    assert cache.stats() == {"hits": 0, "misses": 1, "evictions": 0,
                             "entries": 1, "hit_rate": 0.0}
    r2 = simulate_many(batch(9), n_lanes=LANES, cache=cache)
    st = cache.stats()
    assert st["misses"] == 1 and st["hits"] == 1 and st["hit_rate"] == 0.5
    # and the hit call still returns correct per-scenario physics
    _assert_results_equal(r2[2], simulate_one(batch(9)[2], n_lanes=LANES))
    # different values, same shape: results must differ, executable not
    assert not np.array_equal(np.asarray(r1[0].energy),
                              np.asarray(r2[0].energy))


def test_distinct_shape_misses():
    cache = CompileCache()
    sc = Scenario(_vol(), _cfg(), 100, detectors=DET)
    simulate_many([sc], n_lanes=LANES, cache=cache)
    # each structural change is a new shape: ntg, detector count, lane
    # count, source structure (pencil vs disk)
    simulate_many([dataclasses.replace(sc, cfg=_cfg(n_time_gates=4))],
                  n_lanes=LANES, cache=cache)
    simulate_many([dataclasses.replace(sc, detectors=DET + DET2)],
                  n_lanes=LANES, cache=cache)
    simulate_many([sc], n_lanes=LANES * 2, cache=cache)
    simulate_many([dataclasses.replace(
        sc, source=Disk(pos=(4, 4, 0), radius=1.0))],
        n_lanes=LANES, cache=cache)
    assert cache.misses == 5 and cache.hits == 0
    # ... and every one of those shapes is now warm
    simulate_many([sc], n_lanes=LANES, cache=cache)
    assert cache.hits == 1


def test_keyed_lru_eviction():
    cache = CompileCache(max_entries=1)
    a = Scenario(_vol(), _cfg(), 60, detectors=DET)
    b = Scenario(_vol(), _cfg(n_time_gates=4), 60, detectors=DET)
    simulate_many([a], n_lanes=LANES, cache=cache)
    simulate_many([b], n_lanes=LANES, cache=cache)   # evicts a's entry
    assert cache.evictions == 1 and len(cache) == 1
    simulate_many([a], n_lanes=LANES, cache=cache)   # re-miss: a was evicted
    assert cache.misses == 3 and cache.hits == 0
    # LRU order: touching a then adding b evicts... a is most-recent, so
    # adding b evicts nothing until capacity; re-running b must re-miss
    simulate_many([b], n_lanes=LANES, cache=cache)
    assert cache.misses == 4


def test_cache_counters_reconcile_with_telemetry_spans():
    sink = InMemorySink()
    tracer = Tracer(sinks=[sink])
    cache = CompileCache()
    scs = _heterogeneous()
    simulate_many(scs, n_lanes=LANES, cache=cache, tracer=tracer)
    simulate_many(scs, n_lanes=LANES, cache=cache, tracer=tracer)
    compile_spans = [e for e in tracer.events
                     if e.name == "scenarios.compile"]
    batch_spans = [e for e in tracer.events if e.name == "scenarios.batch"]
    assert len(compile_spans) == cache.misses == 4
    assert len(batch_spans) == cache.misses + cache.hits == 8
    # counter stream carries the same ledger
    recs = [r for r in sink.events if r.get("type") == "counter"]
    hits = sum(r["value"] for r in recs
               if r["name"] == "scenarios.cache.hit")
    misses = sum(r["value"] for r in recs
                 if r["name"] == "scenarios.cache.miss")
    assert hits == cache.hits and misses == cache.misses
    rates = [r["value"] for r in recs
             if r["name"] == "scenarios.cache.hit_rate"]
    assert rates and rates[-1] == cache.stats()["hit_rate"] == 0.5


def test_staged_source_matches_static_sampling():
    import jax.numpy as jnp

    from repro.sources import demo_menu
    ids = jnp.arange(32, dtype=jnp.uint32)
    for name, src in demo_menu(16).items():
        cls, staged = stage_source(src)
        a = src.sample(ids, 99)
        b = StagedSource(cls, staged).sample(ids, 99)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name


def test_make_batched_rejects_mixed_groups():
    with pytest.raises(ValueError, match="single scenario group"):
        make_batched([Scenario(_vol(), _cfg(), 10),
                      Scenario(_vol(), _cfg(n_time_gates=4), 10)],
                     n_lanes=LANES)


def test_retrace_same_shape_is_value_free():
    """The REP805 property, asserted directly: a new batch of the same
    shape (new seeds, budgets, radii, detector coords, media) traces to
    a byte-identical jaxpr — no per-scenario value bakes into the graph."""
    def batch(s):
        return [Scenario(_vol(1.0 + 0.1 * s), _cfg(), 50 + s + i,
                         seed=s + i, source=Disk(pos=(4, 4, 0),
                                                 radius=1.0 + 0.1 * s),
                         detectors=({"x": 4.0, "y": 4.0 - 0.1 * s,
                                     "radius": 2.0},))
                for i in range(3)]

    texts = []
    for s in (0, 3):
        fn, args = make_batched(batch(s), n_lanes=LANES)
        texts.append(str(jax.make_jaxpr(fn)(*args)))
    assert texts[0] == texts[1]


def test_scenario_from_dict_roundtrip():
    sc = Scenario.from_dict({
        "bench": "B1", "size": 8, "photons": 120, "seed": 7,
        "source": {"type": "disk", "pos": [4, 4, 0], "radius": 2},
        "detectors": [{"x": 4, "y": 4, "radius": 2}],
        "time_gates": 2, "steps_per_round": 2, "id_offset": 512,
    })
    assert sc.volume.shape == (8, 8, 8)
    assert sc.cfg.n_time_gates == 2 and sc.cfg.steps_per_round == 2
    res = simulate_many([sc], n_lanes=LANES)[0]
    _assert_results_equal(res, simulate_one(sc, n_lanes=LANES))
    with pytest.raises(ValueError, match="unknown scenario keys"):
        Scenario.from_dict({"photons": 1, "nope": 2})


def test_empty_and_unknown_engine():
    assert simulate_many([]) == []
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_many([Scenario(_vol(), _cfg(), 10)], engine="tpu")


@pytest.mark.slow
def test_mesh_sharded_scenario_axis_bit_identical():
    """simulate_many under an 8-fake-device mesh: the scenario axis
    shard_maps (with zero-photon padding to the device count) and stays
    bit-identical to the unsharded and sequential paths."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = """
import jax, numpy as np
from repro.core import volume as V
from repro.core.volume import SimConfig
from repro.scenarios import Scenario, simulate_many, simulate_one, CompileCache
from repro.sources import Disk
vol = V.benchmark_b1((8, 8, 8))
cfg = SimConfig(do_reflect=True, steps_per_round=2, n_time_gates=2,
                max_steps=64)
det = ({"x": 4.0, "y": 4.0, "radius": 2.0},)
scs = [Scenario(vol, cfg, 100 + 40 * i, seed=1 + i,
                source=Disk(pos=(4, 4, 0), radius=1.0 + 0.3 * i),
                detectors=det, id_offset=10_000 * i) for i in range(5)]
assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("data",))
for engine in ("jnp", "pallas"):
    cache = CompileCache()
    got = simulate_many(scs, n_lanes=16, engine=engine, block_lanes=8,
                        interpret=True, mesh=mesh, cache=cache)
    assert cache.misses == 1, cache.stats()
    for i, sc in enumerate(scs):
        ref = simulate_one(sc, n_lanes=16, engine=engine, block_lanes=8,
                           interpret=True)
        for f in ref._fields:
            a, b = getattr(got[i], f), getattr(ref, f)
            if a is None and b is None:
                continue
            assert np.array_equal(np.asarray(a), np.asarray(b)), (engine, i, f)
print("MESH-OK")
"""
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "MESH-OK" in proc.stdout
