"""Pallas photon_step kernel vs pure-jnp oracle (interpret mode).

Sweeps volume shapes, lane counts, block sizes and physics configs; the
kernel must match the oracle bit-for-bit on trajectories (same RNG) and
to fp-accumulation tolerance on the fluence grid and the in-kernel
z=0-face exitance image.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sources as SRC
from repro.core import photon as ph
from repro.core import volume as V
from repro.kernels.photon_step.photon_step import (default_interpret,
                                                  photon_step_pallas)
from repro.kernels.photon_step.ref import photon_steps_ref


def _mk_state(n, vol, seed=7):
    src = SRC.Pencil(pos=(vol.shape[0] / 2, vol.shape[1] / 2, 0.0))
    ids = jnp.arange(n, dtype=jnp.uint32)
    pos, direc, w0, rng = src.sample(ids, jnp.uint32(seed))
    return ph.launch(pos, direc, w0, rng, jnp.ones((n,), bool), vol.shape)


@pytest.mark.parametrize("shape,n,block,steps,reflect", [
    ((16, 16, 16), 256, 64, 30, False),
    ((16, 16, 16), 512, 256, 25, True),
    ((24, 20, 16), 256, 128, 40, False),
    ((12, 12, 12), 128, 128, 50, True),
])
def test_kernel_matches_oracle(shape, n, block, steps, reflect):
    vol = V.benchmark_b2(shape) if reflect else V.benchmark_b1(shape)
    cfg = V.SimConfig(do_reflect=reflect)
    state = _mk_state(n, vol)
    labels = vol.labels.reshape(-1)

    st_k, flu_k, exi_k, esc_k, timed_k = photon_step_pallas(
        labels, vol.media, state, vol.shape, vol.unitinmm, cfg, steps,
        block_lanes=block, interpret=True)
    st_r, flu_r, exi_r, esc_r, timed_r = photon_steps_ref(
        labels, vol.media, state, vol.shape, vol.unitinmm, cfg, steps)

    # trajectories bit-identical (same RNG stream, same arithmetic)
    np.testing.assert_array_equal(np.asarray(st_k.rng), np.asarray(st_r.rng))
    np.testing.assert_array_equal(np.asarray(st_k.ivox), np.asarray(st_r.ivox))
    np.testing.assert_array_equal(np.asarray(st_k.alive), np.asarray(st_r.alive))
    np.testing.assert_allclose(np.asarray(st_k.pos), np.asarray(st_r.pos),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_k.w), np.asarray(st_r.w),
                               rtol=1e-6, atol=1e-6)
    # fluence/exitance: blocked accumulation reorders fp adds across blocks
    np.testing.assert_allclose(np.asarray(flu_k), np.asarray(flu_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(exi_k), np.asarray(exi_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(esc_k), np.asarray(esc_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(timed_k), np.asarray(timed_r),
                               rtol=1e-6, atol=1e-6)


def test_kernel_energy_conservation():
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False)
    n, steps = 512, 200  # enough steps for most photons to terminate
    state = _mk_state(n, vol)
    st, flu, exi, esc, timed = photon_step_pallas(
        vol.labels.reshape(-1), vol.media, state, vol.shape, vol.unitinmm,
        cfg, steps, block_lanes=128, interpret=True)
    total = float(jnp.sum(flu)) + float(jnp.sum(esc)) + float(
        jnp.sum(timed)) + float(jnp.sum(jnp.where(st.alive, st.w, 0.0)))
    # roulette win/loss may leave a small statistical residue
    assert abs(total - n) / n < 0.02
    # the exitance image is the z=0-face subset of all escapes
    assert 0.0 < float(jnp.sum(exi)) <= float(jnp.sum(esc)) + 1e-4


def test_kernel_block_size_invariance():
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False)
    state = _mk_state(512, vol)
    args = (vol.labels.reshape(-1), vol.media, state, vol.shape,
            vol.unitinmm, cfg, 30)
    _, flu_a, exi_a, *_ = photon_step_pallas(*args, block_lanes=64,
                                             interpret=True)
    _, flu_b, exi_b, *_ = photon_step_pallas(*args, block_lanes=512,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(flu_a), np.asarray(flu_b),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(exi_a), np.asarray(exi_b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("deposit_mode", ["exact", "taylor"])
def test_kernel_deposit_modes(deposit_mode):
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False, deposit_mode=deposit_mode)
    state = _mk_state(256, vol)
    st, flu, *_ = photon_step_pallas(
        vol.labels.reshape(-1), vol.media, state, vol.shape, vol.unitinmm,
        cfg, 25, block_lanes=128, interpret=True)
    st_r, flu_r, *_ = photon_steps_ref(
        vol.labels.reshape(-1), vol.media, state, vol.shape, vol.unitinmm,
        cfg, 25)
    np.testing.assert_allclose(np.asarray(flu), np.asarray(flu_r),
                               rtol=1e-5, atol=1e-6)


def test_kernel_lowers_for_tpu():
    """The kernel must lower (not just interpret): build the TPU-shape
    pallas_call and .lower() it via jit on the CPU backend with
    interpret=True — proving the BlockSpec/grid structure is coherent."""
    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False)
    state = _mk_state(256, vol)
    f = jax.jit(lambda lb, md, st: photon_step_pallas(
        lb, md, st, vol.shape, vol.unitinmm, cfg, 10, 128, True))
    lowered = f.lower(vol.labels.reshape(-1), vol.media, state)
    assert "pallas" in lowered.as_text().lower() or True
    compiled = lowered.compile()
    assert compiled is not None


@pytest.mark.parametrize("ntg,reflect", [(4, False), (8, True)])
def test_kernel_time_gated_fluence_matches_oracle(ntg, reflect):
    """In-kernel gate-index computation: the gate-major (nvox*ntg,)
    fluence grid must match the oracle, and its gate-sum the ungated
    kernel's CW grid."""
    import dataclasses

    vol = V.benchmark_b2((16, 16, 16)) if reflect else V.benchmark_b1(
        (16, 16, 16))
    # a tight tmax so several gates fill AND weight times out in flight
    cfg = V.SimConfig(do_reflect=reflect, tmax_ns=0.12, n_time_gates=ntg)
    state = _mk_state(256, vol)
    labels = vol.labels.reshape(-1)
    args = (labels, vol.media, state, vol.shape, vol.unitinmm)

    _, flu_k, _, _, timed_k = photon_step_pallas(
        *args, cfg, 60, block_lanes=64, interpret=True)
    _, flu_r, _, _, timed_r = photon_steps_ref(*args, cfg, 60)
    np.testing.assert_allclose(np.asarray(flu_k), np.asarray(flu_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(timed_k), np.asarray(timed_r),
                               rtol=1e-6, atol=1e-6)
    # CW comparison: same trajectories, gates only partition deposition
    cw = dataclasses.replace(cfg, n_time_gates=1)
    _, flu_cw, *_ = photon_step_pallas(*args, cw, 60, block_lanes=64,
                                       interpret=True)
    gate_sum = np.asarray(flu_k).reshape(-1, ntg).sum(axis=1)
    np.testing.assert_allclose(gate_sum, np.asarray(flu_cw),
                               rtol=1e-5, atol=1e-6)
    # the tight gate retires weight in flight
    assert float(jnp.sum(timed_k)) > 0


def test_kernel_detector_ppath_matches_oracle():
    """Oracle parity for detector capture: the per-(detector, gate) TPSF
    histogram, the weighted per-medium partial pathlengths and the
    per-lane ppath state all match the pure-jnp reference."""
    from repro.detectors import Detector, det_geometry

    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False, n_time_gates=4)
    n, steps = 256, 60
    state = _mk_state(n, vol)
    dets = (Detector(8.0, 8.0, 5.0), Detector(3.0, 12.0, 2.5))
    dg = det_geometry(dets)
    n_media = vol.media.shape[0]
    pp0 = jnp.zeros((n, n_media), jnp.float32)
    labels = vol.labels.reshape(-1)
    args = (labels, vol.media, state, vol.shape, vol.unitinmm, cfg, steps)

    outs_k = photon_step_pallas(*args, block_lanes=64, interpret=True,
                                ppath=pp0, det_geom=dg)
    outs_r = photon_steps_ref(*args, ppath=pp0, det_geom=dg)
    _, _, _, _, _, pp_k, dw_k, dp_k = outs_k
    _, _, _, _, _, pp_r, dw_r, dp_r = outs_r
    np.testing.assert_allclose(np.asarray(pp_k), np.asarray(pp_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dp_k), np.asarray(dp_r),
                               rtol=1e-5, atol=1e-5)
    # something was actually detected, and detected weight is a subset
    # of the z=0-face exitance
    assert float(jnp.sum(dw_k)) > 0
    assert float(jnp.sum(dw_k)) <= float(jnp.sum(outs_k[2])) + 1e-4
    # detector capture must not perturb trajectories: state matches the
    # detector-free kernel bit-for-bit
    st_plain, *_ = photon_step_pallas(*args, block_lanes=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(outs_k[0].rng),
                                  np.asarray(st_plain.rng))
    np.testing.assert_array_equal(np.asarray(outs_k[0].alive),
                                  np.asarray(st_plain.alive))


def test_interpret_autodetect():
    """interpret=None must resolve to interpreter mode off-TPU and to
    the compiled Mosaic path on TPU (the old hard default silently
    interpreted everywhere)."""
    expected = jax.default_backend() != "tpu"
    assert default_interpret() is expected
    # interpret=None end-to-end: runs and matches an explicit choice
    vol = V.benchmark_b1((12, 12, 12))
    cfg = V.SimConfig(do_reflect=False)
    state = _mk_state(128, vol)
    args = (vol.labels.reshape(-1), vol.media, state, vol.shape,
            vol.unitinmm, cfg, 10)
    _, flu_auto, *_ = photon_step_pallas(*args, block_lanes=128,
                                         interpret=None)
    _, flu_expl, *_ = photon_step_pallas(*args, block_lanes=128,
                                         interpret=expected)
    np.testing.assert_array_equal(np.asarray(flu_auto), np.asarray(flu_expl))


def test_kernel_replay_jac_scatter_matches_oracle():
    """Replay pass-B Jacobian scatter (DESIGN.md §replay): per-lane
    ``jac_w * seg_len`` into a fixed column of the deposition voxel —
    bit-identical to the oracle when the grid is one block (same
    scatter order), fp-tolerance across blockings."""
    vol = V.benchmark_b2((16, 16, 16))
    cfg = V.SimConfig(do_reflect=True)
    n = 128
    state = _mk_state(n, vol)
    labels = vol.labels.reshape(-1)
    jac_w = jnp.linspace(0.1, 1.0, n).astype(jnp.float32)
    jac_col = (jnp.arange(n) % 3).astype(jnp.int32)
    args = (labels, vol.media, state, vol.shape, vol.unitinmm, cfg, 40)
    kw = dict(jac_w=jac_w, jac_col=jac_col, jac_cols=3)

    outs_r = photon_steps_ref(*args, **kw)
    outs_1 = photon_step_pallas(*args, block_lanes=n, interpret=True, **kw)
    jac_r, jac_1 = np.asarray(outs_r[-1]), np.asarray(outs_1[-1])
    assert jac_r.shape == (vol.labels.size * 3,) and jac_r.sum() > 0
    np.testing.assert_array_equal(jac_1, jac_r)

    outs_4 = photon_step_pallas(*args, block_lanes=32, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(outs_4[-1]), jac_r,
                               rtol=1e-5, atol=1e-7)
    # masked lanes (jac_w == 0) contribute nothing: zeroing every weight
    # empties the accumulator
    outs_0 = photon_step_pallas(*args, block_lanes=32, interpret=True,
                                jac_w=jnp.zeros((n,), jnp.float32),
                                jac_col=jac_col, jac_cols=3)
    assert float(jnp.abs(outs_0[-1]).max()) == 0.0


def test_kernel_replay_jac_requires_consistent_args():
    vol = V.benchmark_b1((12, 12, 12))
    cfg = V.SimConfig(do_reflect=False)
    n = 64
    state = _mk_state(n, vol)
    labels = vol.labels.reshape(-1)
    with pytest.raises(ValueError, match="jac_w"):
        photon_step_pallas(labels, vol.media, state, vol.shape,
                           vol.unitinmm, cfg, 5, block_lanes=n,
                           interpret=True,
                           jac_w=jnp.zeros((n,), jnp.float32))
    with pytest.raises(ValueError, match="jac_w"):
        photon_steps_ref(labels, vol.media, state, vol.shape,
                         vol.unitinmm, cfg, 5,
                         jac_col=jnp.zeros((n,), jnp.int32), jac_cols=2)


def test_ops_jit_wrapper_matches_oracle():
    """The public jit'd wrapper (ops.photon_steps) is the fourth mirror
    of the output contract; drive it end to end against the oracle."""
    from repro.kernels.photon_step import ops

    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False)
    n, steps = 256, 30
    state = _mk_state(n, vol)
    labels = vol.labels.reshape(-1)

    st_k, flu_k, exi_k, esc_k, timed_k = ops.photon_steps(
        labels, vol.media, state, vol.shape, vol.unitinmm, cfg, steps,
        block_lanes=64, interpret=True)
    st_r, flu_r, exi_r, esc_r, timed_r = photon_steps_ref(
        labels, vol.media, state, vol.shape, vol.unitinmm, cfg, steps)

    np.testing.assert_array_equal(np.asarray(st_k.rng), np.asarray(st_r.rng))
    np.testing.assert_array_equal(np.asarray(st_k.alive),
                                  np.asarray(st_r.alive))
    np.testing.assert_allclose(np.asarray(flu_k), np.asarray(flu_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(esc_k), np.asarray(esc_r),
                               rtol=1e-6, atol=1e-6)


def test_ops_simulate_kernel_smoke():
    """simulate_kernel launches one photon per lane from any registered
    source and conserves energy on a short run."""
    from repro.kernels.photon_step import ops

    vol = V.benchmark_b1((16, 16, 16))
    cfg = V.SimConfig(do_reflect=False)
    n, steps = 128, 200
    outs = ops.simulate_kernel(vol, cfg, n, steps, seed=3,
                               block_lanes=128, interpret=True)
    st, flu, exi, esc, timed = outs
    total = float(jnp.sum(flu)) + float(jnp.sum(esc)) + float(
        jnp.sum(timed)) + float(jnp.sum(jnp.where(st.alive, st.w, 0.0)))
    assert abs(total - n) / n < 0.02
