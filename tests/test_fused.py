"""Fused multi-segment rounds (DESIGN.md §rounds).

Contracts under test:

  * ``steps_per_round=1`` with ``engine="jnp"`` reproduces the pre-PR
    (seed) engine **bit-for-bit** — energy, exitance, escaped_w,
    n_launched, launched_w, steps.  The seed loop (one regeneration +
    one per-segment scatter per outer iteration) is embedded verbatim
    below as the reference.
  * K>1 changes only fp accumulation order: trajectories/RNG are
    id-keyed, so energy/exitance/escaped agree with K=1 to
    fp-accumulation tolerance and the photon accounting is exact.
  * ``engine="pallas"`` matches ``engine="jnp"`` on the same round
    config to fp-accumulation tolerance (blocked in-kernel scatters).
"""

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import photon as ph
from repro.core import simulator as S
from repro.core import volume as V
from repro.sources import as_source


# ---------------------------------------------------------------------------
# Verbatim copy of the pre-PR engine loop: regeneration + ONE segment +
# per-segment global scatters on every while_loop iteration.
# ---------------------------------------------------------------------------

class _SeedCarry(NamedTuple):
    state: ph.PhotonState
    energy: jnp.ndarray
    exitance: jnp.ndarray
    escaped_w: jnp.ndarray
    remaining: jnp.ndarray
    launched_per_lane: jnp.ndarray
    next_id: jnp.ndarray
    launched_w: jnp.ndarray
    steps: jnp.ndarray


def _seed_sim_fn(shape, unitinmm, cfg, n_lanes, mode="dynamic", source=None):
    source = as_source(source)
    nx, ny, nz = shape
    nvox = nx * ny * nz

    def sim_fn(labels_flat, media, n_photons, seed, id_offset=0):
        n_photons = jnp.asarray(n_photons, jnp.int32)
        seed = jnp.asarray(seed, jnp.uint32)
        id_offset = jnp.asarray(id_offset, jnp.int32)
        lane_idx = jnp.arange(n_lanes, dtype=jnp.int32)
        quota = n_photons // n_lanes + (lane_idx < n_photons % n_lanes)
        state0 = ph.PhotonState(
            pos=jnp.zeros((n_lanes, 3), jnp.float32),
            dir=jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32),
                         (n_lanes, 1)),
            ivox=jnp.zeros((n_lanes, 3), jnp.int32),
            w=jnp.zeros((n_lanes,), jnp.float32),
            s_left=jnp.zeros((n_lanes,), jnp.float32),
            t=jnp.zeros((n_lanes,), jnp.float32),
            rng=jnp.zeros((n_lanes, 4), jnp.uint32),
            alive=jnp.zeros((n_lanes,), bool),
        )
        carry0 = _SeedCarry(
            state0, jnp.zeros((nvox,), jnp.float32),
            jnp.zeros((nx, ny), jnp.float32), jnp.float32(0.0), n_photons,
            jnp.zeros((n_lanes,), jnp.int32),
            (id_offset.astype(jnp.uint32), jnp.uint32(0)),
            jnp.float32(0.0), jnp.int32(0),
        )

        def cond(c):
            has_work = jnp.any(c.state.alive)
            if mode == "dynamic":
                has_work = has_work | (c.remaining > 0)
            else:
                has_work = has_work | jnp.any(c.launched_per_lane < quota)
            return has_work & (c.steps < cfg.max_steps)

        def body(c):
            # _regenerate now carries the id counter as a 64-bit
            # (lo, hi) uint32 pair; hi=0 is bit-identical to the seed
            # engine's int32 counter, so the copy keeps its contract
            state, remaining, launched, next_id, w_new = S._regenerate(
                c.state, c.remaining, c.launched_per_lane, c.next_id,
                quota, source, seed, mode, shape)
            res = ph.step(state, labels_flat, media, shape, unitinmm, cfg)
            energy = c.energy.at[res.dep_idx].add(res.dep_w)
            escaped_w = c.escaped_w + jnp.sum(res.esc_w)
            z_exit = res.esc_pos[:, 2] < ph.Z_EXIT_FACE_VOX
            hit = (res.esc_w > 0) & z_exit
            ex = jnp.clip(jnp.floor(res.esc_pos[:, 0]).astype(jnp.int32),
                          0, nx - 1)
            ey = jnp.clip(jnp.floor(res.esc_pos[:, 1]).astype(jnp.int32),
                          0, ny - 1)
            exitance = c.exitance.at[ex, ey].add(
                jnp.where(hit, res.esc_w, 0.0))
            return _SeedCarry(res.state, energy, exitance, escaped_w,
                              remaining, launched, next_id,
                              c.launched_w + w_new, c.steps + 1)

        final = jax.lax.while_loop(cond, body, carry0)
        return S.SimResult(
            energy=final.energy.reshape(shape),
            exitance=final.exitance,
            escaped_w=final.escaped_w,
            n_launched=(final.next_id[0]
                        - id_offset.astype(jnp.uint32)).astype(jnp.int32),
            launched_w=final.launched_w,
            steps=final.steps,
        )

    return sim_fn


SHAPE = (16, 16, 16)
N_PHOTONS = 3000
LANES = 512
SEED = 42


def _bench(reflect):
    vol = V.benchmark_b2(SHAPE) if reflect else V.benchmark_b1(SHAPE)
    return vol, V.SimConfig(do_reflect=reflect)


def _run(vol, cfg, mode="dynamic", engine="jnp", lanes=LANES,
         id_offset=0):
    fn = jax.jit(S.build_sim_fn(vol.shape, vol.unitinmm, cfg, lanes, mode,
                                engine=engine))
    return fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED, id_offset)


# ---------------------------------------------------------------------------
# K=1 — bit-identical to the seed engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reflect,mode", [
    (False, "dynamic"),   # B1, the pencil-beam benchmark config
    (True, "dynamic"),    # B2
    (False, "static"),
])
def test_k1_bit_identical_to_seed_engine(reflect, mode):
    vol, cfg = _bench(reflect)
    assert cfg.steps_per_round == 1
    seed_fn = jax.jit(_seed_sim_fn(vol.shape, vol.unitinmm, cfg, LANES, mode))
    ref = seed_fn(vol.labels.reshape(-1), vol.media, N_PHOTONS, SEED)
    res = _run(vol, cfg, mode)
    np.testing.assert_array_equal(np.asarray(ref.energy),
                                  np.asarray(res.energy))
    np.testing.assert_array_equal(np.asarray(ref.exitance),
                                  np.asarray(res.exitance))
    assert float(ref.escaped_w) == float(res.escaped_w)
    assert int(ref.n_launched) == int(res.n_launched)
    assert float(ref.launched_w) == float(res.launched_w)
    assert int(ref.steps) == int(res.steps)


# ---------------------------------------------------------------------------
# K>1 — same physics, fp-accumulation-order changes only
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [4, 16])
@pytest.mark.parametrize("reflect", [False, True])
def test_fused_rounds_match_k1(k, reflect):
    vol, cfg1 = _bench(reflect)
    res1 = _run(vol, cfg1, "dynamic")
    cfgk = dataclasses.replace(cfg1, steps_per_round=k)
    resk = _run(vol, cfgk, "dynamic")
    # photon accounting is exact: same id-keyed photon set launches
    assert int(res1.n_launched) == int(resk.n_launched) == N_PHOTONS
    assert float(res1.launched_w) == float(resk.launched_w)
    np.testing.assert_allclose(np.asarray(res1.energy),
                               np.asarray(resk.energy),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res1.exitance),
                               np.asarray(resk.exitance),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(float(res1.escaped_w), float(resk.escaped_w),
                               rtol=1e-5)
    # a round only ever runs whole: steps is a multiple of K
    assert int(resk.steps) % k == 0


@pytest.mark.parametrize("k", [4])
def test_fused_static_mode(k):
    vol, cfg1 = _bench(False)
    res1 = _run(vol, cfg1, "static")
    resk = _run(vol, dataclasses.replace(cfg1, steps_per_round=k), "static")
    assert int(res1.n_launched) == int(resk.n_launched) == N_PHOTONS
    np.testing.assert_allclose(np.asarray(res1.energy),
                               np.asarray(resk.energy),
                               rtol=5e-5, atol=1e-5)


def test_fused_id_offset_determinism():
    """Fused rounds keep the §determinism contract: a shard simulating
    ids [offset, offset+n) is unaffected by K."""
    vol, cfg1 = _bench(False)
    cfg8 = dataclasses.replace(cfg1, steps_per_round=8)
    a = _run(vol, cfg1, id_offset=7777)
    b = _run(vol, cfg8, id_offset=7777)
    assert int(a.n_launched) == int(b.n_launched)
    np.testing.assert_allclose(np.asarray(a.energy), np.asarray(b.energy),
                               rtol=5e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine="pallas" — parity with the jnp engine on the same round config
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,reflect", [(4, False), (8, True)])
def test_pallas_engine_parity(k, reflect):
    vol, cfg = _bench(reflect)
    cfg = dataclasses.replace(cfg, steps_per_round=k)
    res_j = _run(vol, cfg, engine="jnp", lanes=256)
    res_p = _run(vol, cfg, engine="pallas", lanes=256)
    assert int(res_j.n_launched) == int(res_p.n_launched) == N_PHOTONS
    assert int(res_j.steps) == int(res_p.steps)
    np.testing.assert_allclose(np.asarray(res_j.energy),
                               np.asarray(res_p.energy),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_j.exitance),
                               np.asarray(res_p.exitance),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(float(res_j.escaped_w),
                               float(res_p.escaped_w), rtol=1e-5)


def test_pallas_engine_simulate_api():
    """simulate(engine="pallas") end to end, energy balance closed."""
    from repro.core import analysis as A

    vol, cfg = _bench(False)
    cfg = dataclasses.replace(cfg, steps_per_round=8)
    res = S.simulate(vol, cfg, 1500, n_lanes=256, seed=3, engine="pallas")
    bal = A.energy_balance(res)
    assert abs(bal["residue_frac"]) < 1e-4
    assert int(res.n_launched) == 1500


def test_engine_validation():
    vol, cfg = _bench(False)
    with pytest.raises(ValueError, match="unknown engine"):
        S.build_sim_fn(vol.shape, vol.unitinmm, cfg, 128, engine="cuda")
    with pytest.raises(ValueError, match="steps_per_round"):
        S.build_sim_fn(vol.shape, vol.unitinmm,
                       dataclasses.replace(cfg, steps_per_round=0), 128)


def test_autotune_rounds_2d():
    """The 2-D Opt2 sweep returns a (lanes, K) grid of timings."""
    vol, cfg = _bench(False)
    (lanes, k), timings = S.autotune_rounds(
        vol, cfg, n_pilot=400, lane_candidates=(128, 256),
        round_candidates=(1, 4), repeats=1)
    assert set(timings) == {(128, 1), (128, 4), (256, 1), (256, 4)}
    assert (lanes, k) in timings
    assert timings[(lanes, k)] == min(timings.values())
    # the legacy 1-D interface still works on top of the 2-D sweep
    best, t1d = S.autotune_lanes(vol, cfg, n_pilot=400,
                                 candidates=(128, 256), repeats=1)
    assert best in (128, 256) and set(t1d) == {128, 256}
