"""Multi-device distribution tests.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process
must keep seeing exactly 1 device, per the dry-run isolation rule).
"""

import os
import subprocess
import sys

import pytest

# every test here spawns a fresh interpreter (8 fake XLA devices) and
# re-runs compilation from scratch — the expensive tail of tier-1.  CI
# keeps a fast `-m "not slow"` lane ahead of the full suite.
pytestmark = pytest.mark.slow

_ENV = dict(os.environ)
_ENV["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
_ENV["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", script],
        env=_ENV,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


_PRELUDE = """
import jax, numpy as np, jax.numpy as jnp
from repro.core import volume as V, simulator as S, analysis as A
from repro.core.multidevice import (simulate_sharded, ChunkScheduler,
                                    ElasticSimulator)
vol = V.benchmark_b1((30, 30, 30)); cfg = V.b1_config()
ref = S.simulate(vol, cfg, 6000, 2048, 5)
"""


def test_sharded_equals_single_device():
    out = _run(_PRELUDE + """
mesh = jax.make_mesh((8,), ("data",))
res = simulate_sharded(vol, cfg, 6000, mesh, n_lanes=256, seed=5)
assert int(res.n_launched) == 6000
bal = A.energy_balance(res)
assert abs(bal["residue_frac"]) < 1e-4, bal
diff = np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
assert rel < 1e-3, (diff, rel)
print("OK", rel)
""")
    assert "OK" in out


def test_sharded_uneven_partition():
    out = _run(_PRELUDE + """
mesh = jax.make_mesh((8,), ("data",))
part = [1500, 1500, 750, 750, 375, 375, 375, 375]
res = simulate_sharded(vol, cfg, 6000, mesh, partition=part, n_lanes=256, seed=5)
diff = np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
assert rel < 1e-3, rel
print("OK", rel)
""")
    assert "OK" in out


def test_multipod_axes_lower_and_run():
    """2x4 mesh with ('pod', 'data') axes — the multi-pod photon sharding."""
    out = _run(_PRELUDE + """
mesh = jax.make_mesh((2, 4), ("pod", "data"))
res = simulate_sharded(vol, cfg, 6000, mesh, axis_names=("pod", "data"),
                       n_lanes=256, seed=5)
assert int(res.n_launched) == 6000
diff = np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
assert rel < 1e-3, rel
print("OK", rel)
""")
    assert "OK" in out


def test_chunk_scheduler_covers_and_matches():
    out = _run(_PRELUDE + """
sched = ChunkScheduler(vol, cfg, n_lanes=256)
tot, stats = sched.run(6000, 400, seed=5)
assert int(tot.n_launched) == 6000
assert sum(stats.values()) == 6000
assert len([d for d, n in stats.items() if n > 0]) >= 2  # used >1 device
diff = np.abs(np.asarray(tot.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
assert rel < 1e-3, rel
print("OK", stats)
""")
    assert "OK" in out


def test_elastic_failure_and_restart_deterministic():
    out = _run(_PRELUDE + """
es = ElasticSimulator(vol, cfg, 6000, 400, n_lanes=256, seed=5)
killed = [True]
es.run_round(fail=lambda ch, dev: ch.start_id == 0 and killed
             and (killed.pop(), True)[1])
state = es.state_dict()
# restart from checkpoint in a fresh instance (simulates process loss)
es2 = ElasticSimulator(vol, cfg, 6000, 400, n_lanes=256, seed=5)
es2.load_state_dict(state)
res = es2.run_to_completion()
assert int(res.n_launched) == 6000
diff = np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
assert rel < 1e-3, rel
print("OK", rel)
""")
    assert "OK" in out


def test_time_gated_sharded_and_chunked():
    """Gate-aware merges (DESIGN.md §time-resolved): the 4-D time-gated
    energy grid, detector TPSF histograms and timed-out accounting
    survive the psum'd shard_map path and the host-side ChunkScheduler
    merge, agreeing with the single-device run of the same photon set."""
    out = _run("""
import dataclasses
import jax, numpy as np
from repro.core import volume as V, simulator as S, analysis as A
from repro.core.multidevice import simulate_sharded, ChunkScheduler
from repro.detectors import Detector
vol = V.benchmark_b1((16, 16, 16))
cfg = dataclasses.replace(V.b1_config(), n_time_gates=6, steps_per_round=2)
dets = (Detector(8.0, 8.0, 5.0),)
from repro import sources as SRC
src = SRC.Pencil(pos=(8.0, 8.0, 0.0))
ref = S.simulate(vol, cfg, 2400, 256, 5, source=src, detectors=dets)
assert ref.energy.shape == (16, 16, 16, 6)

mesh = jax.make_mesh((8,), ("data",))
res = simulate_sharded(vol, cfg, 2400, mesh, n_lanes=128, seed=5,
                       source=src, detectors=dets)
assert res.energy.shape == (16, 16, 16, 6)
assert int(res.n_launched) == 2400
assert abs(A.energy_balance(res)["residue_frac"]) < 1e-5
rel = (np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
       / np.asarray(ref.energy).max())
assert rel < 1e-3, rel
dw = np.abs(np.asarray(res.det_w) - np.asarray(ref.det_w)).max()
assert dw < 1e-3 * max(np.asarray(ref.det_w).max(), 1.0), dw

sched = ChunkScheduler(vol, cfg, n_lanes=128, source=src, detectors=dets)
tot, stats = sched.run(2400, 600, seed=5)
assert int(tot.n_launched) == 2400
rel = (np.abs(np.asarray(tot.energy) - np.asarray(ref.energy)).max()
       / np.asarray(ref.energy).max())
assert rel < 1e-3, rel
assert np.abs(np.asarray(tot.det_ppath) - np.asarray(ref.det_ppath)).max() \
    < 1e-2
print("OK")
""")
    assert "OK" in out


def test_fused_pallas_engine_sharded_and_chunked():
    """The fused Pallas round executor runs under every scheduler
    (DESIGN.md §rounds): shard_map'd, chunked, and elastic runs agree
    with the single-device jnp reference on the same photon set."""
    out = _run("""
import dataclasses
import jax, numpy as np
from repro.core import volume as V, simulator as S, analysis as A
from repro.core.multidevice import (simulate_sharded, ChunkScheduler,
                                    ElasticSimulator)
vol = V.benchmark_b1((16, 16, 16)); cfg = V.b1_config()
cfg = dataclasses.replace(cfg, steps_per_round=4)
ref = S.simulate(vol, cfg, 1200, 256, 5)

mesh = jax.make_mesh((8,), ("data",))
res = simulate_sharded(vol, cfg, 1200, mesh, n_lanes=128, seed=5,
                       engine="pallas")
assert int(res.n_launched) == 1200
assert abs(A.energy_balance(res)["residue_frac"]) < 1e-4
rel = (np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
       / np.asarray(ref.energy).max())
assert rel < 1e-3, rel

sched = ChunkScheduler(vol, cfg, n_lanes=128, engine="pallas")
tot, stats = sched.run(1200, 300, seed=5)
assert int(tot.n_launched) == 1200 and sum(stats.values()) == 1200
rel = (np.abs(np.asarray(tot.energy) - np.asarray(ref.energy)).max()
       / np.asarray(ref.energy).max())
assert rel < 1e-3, rel

es = ElasticSimulator(vol, cfg, 1200, 300, n_lanes=128, seed=5,
                      engine="pallas")
er = es.run_to_completion()
assert int(er.n_launched) == 1200
rel = (np.abs(np.asarray(er.energy) - np.asarray(ref.energy)).max()
       / np.asarray(ref.energy).max())
assert rel < 1e-3, rel
print("OK")
""")
    assert "OK" in out


def test_detected_records_sharded_chunked_elastic():
    """Detected-photon id records (DESIGN.md §replay) thread through
    every scheduler: the sharded concatenated buffers, the chunked and
    elastic host-side merges, and an elastic checkpoint/restart all
    reproduce the single-device record *set* exactly (order is
    scheduler-dependent), with 64-bit-safe chunk id offsets."""
    out = _run("""
import jax, numpy as np
from repro.core import volume as V, simulator as S
from repro.core.multidevice import (simulate_sharded, ChunkScheduler,
                                    ElasticSimulator)
from repro.detectors import Detector
from repro.replay import detected_records
from repro import sources as SRC
vol = V.benchmark_b1((16, 16, 16)); cfg = V.b1_config()
dets = (Detector(11.0, 8.0, 3.0),)
src = SRC.Pencil(pos=(8.0, 8.0, 0.0))

def row_sorted(rec):
    # lexicographic ROW sort — np.sort(axis=0) would sort each column
    # independently and could equate genuinely different record sets
    return np.asarray(sorted(map(tuple, rec)), np.uint32).reshape(-1, 4)

ref = S.simulate(vol, cfg, 4000, 512, 5, source=src, detectors=dets,
                 record_detected=2048)
recs_ref = row_sorted(detected_records(ref))
assert recs_ref.shape[0] > 0 and int(ref.det_rec_overflow) == 0

mesh = jax.make_mesh((8,), ("data",))
res = simulate_sharded(vol, cfg, 4000, mesh, n_lanes=128, seed=5,
                       source=src, detectors=dets, record_detected=512)
assert np.asarray(res.det_rec_n).shape == (8,)
assert np.array_equal(row_sorted(detected_records(res)), recs_ref)

sched = ChunkScheduler(vol, cfg, n_lanes=128, source=src, detectors=dets,
                       record_detected=512)
tot, stats = sched.run(4000, 500, seed=5)
assert np.array_equal(row_sorted(detected_records(tot)), recs_ref)

es = ElasticSimulator(vol, cfg, 4000, 500, n_lanes=128, seed=5,
                      source=src, detectors=dets, record_detected=512)
es.run_round()
sd = es.state_dict()
es2 = ElasticSimulator(vol, cfg, 4000, 500, n_lanes=128, seed=5,
                       source=src, detectors=dets, record_detected=512)
es2.load_state_dict(sd)
er = es2.run_to_completion()
assert np.array_equal(row_sorted(detected_records(er)), recs_ref)
print("OK")
""")
    assert "OK" in out


def test_sharded_replay_matches_single_device():
    """shard_map'd replay (DESIGN.md §replay): record batches fan out
    over 8 fake devices through multidevice.sharded_replay_fn; the
    per-record outputs are bit-equal to the single-device replay
    (trajectories are id-keyed) and the psum'd Jacobian agrees to
    fp-accumulation order — for both round executors and the
    gate-resolved scatter."""
    out = _run("""
import dataclasses
import jax, numpy as np
from repro.core import volume as V, simulator as S, analysis as A
from repro.detectors import Detector
from repro.replay import detected_records, replay_jacobian
vol = V.benchmark_b1((16, 16, 16))
cfg = dataclasses.replace(V.b1_config(), steps_per_round=2,
                          tmax_ns=0.5, n_time_gates=4)
dets = (Detector(11.0, 8.0, 3.0),)
src = {"type": "pencil", "pos": (8.0, 8.0, 0.0)}
res = S.simulate(vol, cfg, 1500, 256, 7, source=src, detectors=dets,
                 record_detected=4096)
rec = detected_records(res)
assert rec.shape[0] > 50

single = replay_jacobian(vol, cfg, rec, dets, source=src, seed=7,
                         n_lanes=64)
mesh = jax.make_mesh((8,), ("data",))
shard = replay_jacobian(vol, cfg, rec, dets, source=src, seed=7,
                        n_lanes=64, mesh=mesh)
assert np.array_equal(single.w_exit, shard.w_exit)
assert np.array_equal(single.gate, shard.gate)
assert np.array_equal(single.replayed_det, shard.replayed_det)
np.testing.assert_allclose(shard.jacobian, single.jacobian,
                           rtol=1e-5, atol=1e-9)

# pallas executor + gate-resolved scatter through the same fan-out
sg = replay_jacobian(vol, cfg, rec, dets, source=src, seed=7,
                     n_lanes=64, mesh=mesh, engine="pallas",
                     gate_resolved=True)
assert sg.jacobian.shape == (16, 16, 16, 1, 4)
assert np.array_equal(sg.w_exit, single.w_exit)
np.testing.assert_allclose(sg.jacobian.sum(axis=-1), single.jacobian,
                           rtol=1e-5, atol=1e-9)
M = A.jacobian_medium_sums(sg.jacobian, vol)
np.testing.assert_allclose(M, np.asarray(res.det_ppath, np.float64),
                           rtol=1e-4, atol=1e-4)
print("OK")
""")
    assert "OK" in out


def test_sharded_collect_stats_bit_identity_and_psum():
    """collect_stats under shard_map: the RoundStats pytree psums across
    shards without touching any physics bit, and the merged counters
    keep exact photon accounting (DESIGN.md §observability)."""
    out = _run(_PRELUDE + """
import dataclasses
mesh = jax.make_mesh((8,), ("data",))
off = simulate_sharded(vol, cfg, 6000, mesh, n_lanes=256, seed=5)
cfg_on = dataclasses.replace(cfg, collect_stats=True)
on = simulate_sharded(vol, cfg_on, 6000, mesh, n_lanes=256, seed=5)
assert off.stats is None and on.stats is not None
assert np.array_equal(np.asarray(off.energy), np.asarray(on.energy))
assert np.array_equal(np.asarray(off.exitance), np.asarray(on.exitance))
assert float(off.escaped_w) == float(on.escaped_w)
assert int(off.n_launched) == int(on.n_launched)
st = on.stats
assert int(st.relaunched) == int(on.n_launched) == 6000
assert float(st.escaped_w) == float(on.escaped_w)
occ = st.lane_occupancy()
assert 0.0 < occ <= 1.0, occ
bal = A.energy_balance(on)
rel = abs(float(st.deposited_w) - bal["absorbed"]) / max(bal["absorbed"], 1e-9)
assert rel < 1e-5, rel
print("OK", occ)
""")
    assert "OK" in out


def test_chaos_anchor_mixed_fleet_bit_identity():
    """The DESIGN.md §resilience acceptance anchor at fleet scale: a
    heterogeneous DevicePool over 8 fake devices — mixed jnp/pallas
    specs, one throttled straggler — under a seeded fault schedule
    (dispatch failures, NaN corruption, delays, a scheduled dropout,
    deadline-triggered speculation) produces a SimResult bit-identical
    to the fault-free run of the same fleet, with no chunk merged
    twice and the quarantine/retry accounting adding up."""
    out = _run("""
import jax, numpy as np
from repro.core import volume as V
from repro.resilience import DevicePool, DeviceSpec, FaultInjector, RetryPolicy
vol = V.benchmark_b1((16, 16, 16)); cfg = V.SimConfig(do_reflect=False)
N, CHUNK, SEED = 6000, 500, 11
devs = jax.devices()
assert len(devs) == 8
specs = [DeviceSpec(device=devs[i], engine="jnp", n_lanes=256,
                    label=f"jnp{i}") for i in range(6)]
specs += [DeviceSpec(device=devs[6], engine="pallas", n_lanes=256,
                     label="pal6"),
          DeviceSpec(device=devs[7], engine="jnp", n_lanes=256,
                     label="lag7", throttle_s=0.4)]

clean = DevicePool(vol, cfg, specs, chunk_timeout_s=0.2)
ref, rep_ref = clean.run(N, CHUNK, seed=SEED, deadline_s=600)
assert rep_ref.merged == rep_ref.n_chunks == 12

inj = FaultInjector(seed=4, p_fail=0.25, p_nan=0.15, p_delay=0.25,
                    delay_s=0.05, dropout={"jnp3": 1})
chaos = DevicePool(vol, cfg, specs, chunk_timeout_s=0.2,
                   fault_injector=inj,
                   retry_policy=RetryPolicy(max_attempts=12,
                                            quarantine_after=50))
res, rep = chaos.run(N, CHUNK, seed=SEED, deadline_s=600)
for f in ("energy", "exitance", "escaped_w", "timed_out_w", "det_w",
          "det_ppath", "launched_w", "n_launched"):
    a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(res, f))
    assert np.array_equal(a, b), f
assert rep.merged == rep.n_chunks == 12 and not rep.quarantined_chunks
assert int(res.n_launched) == N
assert rep.injected_faults > 0 and rep.retries > 0
assert rep.workers_quarantined >= 1          # the scheduled dropout
assert rep.rebound == 0                      # jnp class never extinct...
assert rep_ref.rebound == 0
# ...so bit-identity held the strong way, not via engine parity
print("OK", rep.counters())
""")
    assert "OK" in out
