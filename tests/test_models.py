"""Model-stack correctness: oracles + decode/forward equivalence.

The decode-vs-forward test is the load-bearing one: it proves the GQA KV
cache, the MLA absorbed-latent cache, the sliding-window ring buffer and
the SSM recurrent state all reproduce the full-sequence (chunked
flash-style) computation token by token.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import api as API
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as SSM


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32", remat=False)


# ---------------------------------------------------------------------------
# chunked attention vs naive softmax oracle
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True, window=0):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * d**-0.5
    s = s.astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])


@pytest.mark.parametrize("sq,sk,h,kv,window", [
    (33, 33, 4, 2, 0),
    (64, 64, 4, 4, 0),
    (40, 40, 8, 2, 16),
    (17, 17, 2, 1, 0),
])
def test_chunked_attention_matches_naive(sq, sk, h, kv, window):
    key = jax.random.PRNGKey(0)
    kq, kk, kvv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, sq, h, 16), jnp.float32)
    k = jax.random.normal(kk, (2, sk, kv, 16), jnp.float32)
    v = jax.random.normal(kvv, (2, sk, kv, 16), jnp.float32)
    got = L._chunked_attention(q, k, v, causal=True, window=window,
                               chunk_q=16, chunk_k=16)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_mla_value_dim():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 24, 4, 24), jnp.float32)
    k = jax.random.normal(key, (1, 24, 4, 24), jnp.float32)
    v = jax.random.normal(key, (1, 24, 4, 16), jnp.float32)  # Dv != D
    got = L._chunked_attention(q, k, v, causal=True, chunk_q=8, chunk_k=8)
    want = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# MoE dispatch vs dense oracle
# ---------------------------------------------------------------------------

def test_moe_matches_dense_reference_when_no_drops():
    cfg = dataclasses.replace(
        _f32(C.get_smoke_config("mixtral-8x7b")), capacity_factor=8.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                          jnp.float32)
    got = M.moe_ffn(p, x, cfg)
    want = M.moe_ffn_dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_moe_capacity_drops_bounded():
    """With tight capacity, outputs differ only for dropped tokens."""
    cfg = dataclasses.replace(
        _f32(C.get_smoke_config("mixtral-8x7b")), capacity_factor=0.5)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    got = M.moe_ffn(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))
    # dropped tokens pass through with zero FFN contribution, so the
    # output norm must not exceed the no-drop reference norm by much
    want = M.moe_ffn_dense_reference(p, x, cfg)
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(want)) * 1.5


def test_moe_shared_expert_always_active():
    cfg = dataclasses.replace(
        _f32(C.get_smoke_config("deepseek-v3-671b")), capacity_factor=8.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32)
    got = M.moe_ffn(p, x, cfg)
    want = M.moe_ffn_dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan vs naive recurrence oracle
# ---------------------------------------------------------------------------

def _ssd_naive(x, dt, a_log, b, c, d_skip):
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    reps = h // g
    bh = jnp.repeat(b, reps, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, reps, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    hstate = jnp.zeros((bsz, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        a = jnp.exp(-a_log[None, :] * dtf[:, t])  # (B, H)
        hstate = hstate * a[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtf[:, t], bh[:, t], xf[:, t])
        y = jnp.einsum("bhn,bhnp->bhp", ch[:, t], hstate)
        ys.append(y + xf[:, t] * d_skip[None, :, None])
    return jnp.stack(ys, axis=1)


def test_ssd_scan_matches_naive_recurrence():
    bsz, s, h, p, g, n = 2, 37, 4, 8, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a_log = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    b = jax.random.normal(ks[3], (bsz, s, g, n), jnp.float32)
    c = jax.random.normal(ks[4], (bsz, s, g, n), jnp.float32)
    d_skip = jnp.ones((h,))
    got = SSM.ssd_scan(x, dt, a_log, b, c, d_skip, chunk=8)
    want = _ssd_naive(x, dt, a_log, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode == forward (per arch) — validates every cache variant
# ---------------------------------------------------------------------------

_DECODE_ARCHS = [
    "mistral-nemo-12b", "phi3-medium-14b", "granite-20b", "llama3.2-1b",
    "llama-3.2-vision-11b", "whisper-medium", "deepseek-v3-671b",
    "mixtral-8x7b", "mamba2-1.3b", "hymba-1.5b",
]


@pytest.mark.parametrize("arch", _DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _f32(C.get_smoke_config(arch))
    if cfg.meta_tokens:
        cfg = dataclasses.replace(cfg, meta_tokens=0)  # see DESIGN.md §serve
    model = API.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    ctx = None
    if cfg.kind == "vlm":
        ctx = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_image_tokens, cfg.d_model)
        ).astype(cfg.jax_dtype)
    if cfg.kind == "encdec":
        ctx = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_frames, cfg.d_model)
        ).astype(cfg.jax_dtype)

    full = model.forward(params, tokens, ctx_embeds=ctx)  # (B, S, V)

    cache = model.init_cache(b, s + 4)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, ctx_embeds=ctx))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, tokens[:, t : t + 1], t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_wraps():
    """Decode past the window length: ring buffer must stay correct."""
    cfg = dataclasses.replace(_f32(C.get_smoke_config("mixtral-8x7b")),
                              sliding_window=8, capacity_factor=8.0)
    model = API.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 20  # > 2x window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full = model.forward(params, tokens)
    cache = model.init_cache(b, s)  # ring buffer: window-sized internally
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, tokens[:, t : t + 1], t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# smoke: one jit'd train step per arch, loss finite + decreases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", _DECODE_ARCHS)
def test_smoke_train_step(arch):
    cfg = C.get_smoke_config(arch)
    model = API.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    train_step, opt = API.make_train_step(model)
    opt_state = opt.init(params)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.kind == "vlm":
        batch["ctx"] = jnp.zeros((b, cfg.n_image_tokens, cfg.d_model),
                                 cfg.jax_dtype)
    if cfg.kind == "encdec":
        batch["ctx"] = jnp.zeros((b, cfg.encoder_frames, cfg.d_model),
                                 cfg.jax_dtype)
    jstep = jax.jit(train_step)
    losses = []
    for _ in range(3):
        params, opt_state, m = jstep(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]  # memorizing a fixed batch
