"""Substrate tests: data pipeline, checkpointer, optimizer, compression."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # keep tier-1 collection alive without the extra dep
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.data import SyntheticLM, make_pipeline
from repro.optim import adamw, apply_updates, quantize_int8, dequantize_int8


def test_pipeline_deterministic_and_resumable(tmp_path):
    p1 = SyntheticLM(vocab=256, batch=4, seq_len=16, seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.state_dict()
    more = [p1.next_batch() for _ in range(3)]
    p2 = SyntheticLM(vocab=256, batch=4, seq_len=16, seed=3)
    p2.load_state_dict(state)
    more2 = [p2.next_batch() for _ in range(3)]
    for a, b in zip(more, more2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])


def test_pipeline_sharding_partitions_batch():
    p = SyntheticLM(vocab=64, batch=8, seq_len=8, seed=1)
    full = p.next_batch()
    p2 = SyntheticLM(vocab=64, batch=8, seq_len=8, seed=1)
    s0 = p2.next_batch(shard=(0, 2))
    p3 = SyntheticLM(vocab=64, batch=8, seq_len=8, seed=1)
    s1 = p3.next_batch(shard=(1, 2))
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), full["tokens"])


def test_checkpointer_roundtrip_and_keep(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (10, 20, 30):
        ck.save(step, jax.tree.map(lambda x: x * step, tree))
    assert ck.steps() == [20, 30]  # keep=2 garbage-collected step 10
    step, restored = ck.restore(tree)
    assert step == 30
    np.testing.assert_allclose(
        np.asarray(restored["a"], np.float32),
        np.asarray(tree["a"]) * 30)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpointer_crash_safety(tmp_path):
    """A stray .tmp file (simulated crash) must not break restore."""
    ck = Checkpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((3,))}
    ck.save(1, tree)
    with open(os.path.join(str(tmp_path), "garbage.tmp"), "wb") as f:
        f.write(b"partial write")
    # incomplete npz without manifest is ignored
    with open(os.path.join(str(tmp_path), "step_0000000099.npz"), "wb") as f:
        f.write(b"corrupt")
    assert ck.latest_step() == 1
    step, restored = ck.restore(tree)
    assert step == 1


def test_adamw_converges_quadratic():
    opt = adamw(lr=0.1, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_clip_bounds_update():
    opt = adamw(lr=1.0, clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    grads = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    updates, state = opt.update(grads, state, params)
    assert np.all(np.isfinite(np.asarray(updates["w"])))


@settings(max_examples=30, deadline=None)
@given(scale=st.floats(1e-6, 1e4), seed=st.integers(0, 2**31))
def test_property_int8_quantization_bounded_error(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    absmax = float(jnp.max(jnp.abs(x)))
    assert err.max() <= absmax / 127.0 * 0.5 + 1e-9


def test_compressed_psum_multidevice():
    """int8 EF-psum across 8 host devices: mean error shrinks over steps."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compressed_psum
mesh = jax.make_mesh((8,), ("data",))
def step(g, e):
    return compressed_psum(g, e, "data")
f = jax.jit(jax.shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                          out_specs=(P(), P("data")), check_vma=False))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
e = jnp.zeros((8, 128), jnp.float32)
true_mean = np.asarray(g).mean(axis=0)
total_err = 0.0
acc = np.zeros(128); acc_true = np.zeros(128)
for i in range(20):
    mean, e = f(g, e)
    acc += np.asarray(mean).reshape(128)
    acc_true += true_mean
# error feedback: accumulated compressed means converge to accumulated truth
rel = np.abs(acc - acc_true).max() / (np.abs(acc_true).max() + 1e-9)
assert rel < 0.02, rel
print("OK", rel)
"""
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_train_driver_smoke_and_resume(tmp_path):
    """End-to-end: train 6 steps, checkpoint, resume, loss decreases."""
    from repro.launch import train as T

    ckpt = str(tmp_path / "ck")
    losses = T.main(["--arch", "llama3.2-1b", "--smoke", "--steps", "6",
                     "--batch", "4", "--seq_len", "32", "--ckpt_every", "3",
                     "--ckpt_dir", ckpt, "--lr", "1e-2"])
    assert losses[-1] < losses[0]
    # resume continues from step 6 checkpoint
    losses2 = T.main(["--arch", "llama3.2-1b", "--smoke", "--steps", "8",
                      "--batch", "4", "--seq_len", "32", "--ckpt_every", "100",
                      "--ckpt_dir", ckpt, "--resume", "--lr", "1e-2"])
    assert len(losses2) == 2  # only steps 6,7 ran
