"""Substrate tests: the crash-safe checkpointer.

(The checkpointer is the persistence layer under the resilience
frontier checkpoints — DESIGN.md §resilience.)
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer


def test_checkpointer_roundtrip_and_keep(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (10, 20, 30):
        ck.save(step, jax.tree.map(lambda x: x * step, tree))
    assert ck.steps() == [20, 30]  # keep=2 garbage-collected step 10
    step, restored = ck.restore(tree)
    assert step == 30
    np.testing.assert_allclose(
        np.asarray(restored["a"], np.float32),
        np.asarray(tree["a"]) * 30)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpointer_crash_safety(tmp_path):
    """A stray .tmp file (simulated crash) must not break restore."""
    ck = Checkpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((3,))}
    ck.save(1, tree)
    with open(os.path.join(str(tmp_path), "garbage.tmp"), "wb") as f:
        f.write(b"partial write")
    # incomplete npz without manifest is ignored
    with open(os.path.join(str(tmp_path), "step_0000000099.npz"), "wb") as f:
        f.write(b"corrupt")
    assert ck.latest_step() == 1
    step, restored = ck.restore(tree)
    assert step == 1
