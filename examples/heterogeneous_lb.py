"""Heterogeneous multi-device simulation with pilot-fitted load balancing.

Reproduces the paper's device-level workflow end to end: pilot runs fit
T = a*n + T0 per device class, the S3 minimax partitioner splits the
budget, and the chunk scheduler absorbs stragglers dynamically.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/heterogeneous_lb.py
"""

import time

import jax
import numpy as np

from repro.core import analysis as A
from repro.core import loadbalance as LB
from repro.core import simulator as S
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler, simulate_sharded

vol = V.benchmark_b1((40, 40, 40))
cfg = V.b1_config()
N = 40_000

# --- pilot fit on the real simulator (the paper's two-run protocol) ---
fn = S.make_simulator(vol, cfg, 2048)


def run_n(k):
    args = (vol.labels.reshape(-1), vol.media, k, 7)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


model = LB.run_pilot(run_n, 4000, 20_000, name="local")
print(f"pilot fit: a={model.a:.3e} s/photon, T0={model.t0*1e3:.1f} ms, "
      f"throughput={model.throughput/1e3:.1f} photons/ms")

# --- S1/S2/S3 on a synthetic heterogeneous mix from the measured slope ---
mix = [
    LB.DeviceModel("gpu-fast", a=model.a / 4, t0=model.t0, cores=4096),
    LB.DeviceModel("gpu-slow", a=model.a / 2, t0=model.t0 * 2, cores=2048),
    LB.DeviceModel("cpu", a=model.a, t0=model.t0 / 2, cores=16),
]
for strat in ("S1", "S2", "S3"):
    part = LB.PARTITIONERS[strat](N, mix)
    print(f"{strat}: partition={part} makespan={LB.makespan(part, mix):.3f}s")
print(f"ideal: {LB.ideal_makespan(N, mix):.3f}s")

# --- run for real on however many local devices exist ---
ndev = len(jax.devices())
if ndev > 1:
    mesh = jax.make_mesh((ndev,), ("data",))
    res = simulate_sharded(vol, cfg, N, mesh, n_lanes=1024, seed=7)
else:
    res = S.simulate(vol, cfg, N, 2048, 7)
jax.block_until_ready(res)
print(f"distributed run on {ndev} device(s):", A.energy_balance(res))

# --- dynamic chunk scheduling (straggler mitigation) ---
sched = ChunkScheduler(vol, cfg, n_lanes=1024)
tot, stats = sched.run(N, chunk_size=N // 8, seed=7)
print("chunk scheduler per-device photons:", stats)
