"""Fault-tolerant photon campaign: chaos, checkpoints, crash, restart.

Simulates the large-run lifecycle end to end (DESIGN.md §resilience):

  1. a resilient DevicePool run under a *seeded* chaos schedule —
     injected dispatch failures, NaN-corrupted results (rejected by the
     merge guard) and delays — is bit-identical to the fault-free run;
  2. an ElasticSimulator campaign auto-checkpoints every merged chunk,
     the host "crashes" (FaultInjector.kill_after_merges), and a fresh
     process restores from the atomic keep-k Checkpointer and finishes
     — again bit-identical to an uninterrupted run (counter-based RNG
     keys photons by global id, so every replay is exact).

  PYTHONPATH=src python examples/fault_tolerant_campaign.py
"""

import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import analysis as A
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler, ElasticSimulator
from repro.resilience import FaultInjector, InjectedCrash, RetryPolicy

vol = V.benchmark_b2((30, 30, 30))
cfg = V.b2_config()
N, CHUNK = 20_000, 2_000

# ---- 1. chaos drill: faults change no output bit ----
clean = ChunkScheduler(vol, cfg, n_lanes=1024)
ref, _ = clean.run(N, CHUNK, seed=5)

chaos = ChunkScheduler(
    vol, cfg, n_lanes=1024,
    fault_injector=FaultInjector(seed=3, p_fail=0.25, p_nan=0.15,
                                 p_delay=0.2, delay_s=0.02),
    retry_policy=RetryPolicy(max_attempts=10))
res, _ = chaos.run(N, CHUNK, seed=5, deadline_s=600)
rep = chaos.last_report
print(f"chaos drill: {rep.merged}/{rep.n_chunks} chunks merged with "
      f"{rep.retries} retries ({rep.validation_failures} rejected merges, "
      f"{rep.dispatch_failures} failed dispatches)")
assert np.array_equal(np.asarray(res.energy), np.asarray(ref.energy))
print("OK: bit-identical to the fault-free run under injected faults\n")

# ---- 2. crash mid-campaign + restart from auto-checkpoint ----
ck = Checkpointer("/tmp/repro_campaign", keep=2)
sim = ElasticSimulator(vol, cfg, N, CHUNK, n_lanes=1024, seed=5,
                       fault_injector=FaultInjector(kill_after_merges=4),
                       checkpointer=ck, checkpoint_every=1)
try:
    sim.run_to_completion()
except InjectedCrash as e:
    print(f"host crash: {e}")
print(f"newest checkpoint: step {ck.latest_step()} "
      f"({ck.manifest()['extra']})")

# ---- new process: restore and finish (no injector this time) ----
sim2 = ElasticSimulator(vol, cfg, N, CHUNK, n_lanes=1024, seed=5)
_, state = ck.restore(sim2.state_dict())
sim2.load_state_dict(state)
print(f"restored: {len(sim2.completed)} chunks done, "
      f"{len(sim2.pending)} to go")
res2 = sim2.run_to_completion()

print(f"resumed campaign: {A.energy_balance(res2)}")
assert np.array_equal(np.asarray(res2.energy), np.asarray(ref.energy))
print("OK: crash + restart reproduced the uninterrupted result bit-exactly")
