"""Fault-tolerant photon campaign: checkpoints, failure, elastic restart.

Simulates the large-run lifecycle: an ElasticSimulator campaign
checkpoints between rounds, a device "dies" mid-round (its chunk is
requeued), the process "crashes", and a fresh process resumes from the
checkpoint — producing the exact same fluence as an uninterrupted run
(counter-based RNG keys photons by global id).

  PYTHONPATH=src python examples/fault_tolerant_campaign.py
"""

import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V
from repro.core.multidevice import ElasticSimulator

vol = V.benchmark_b2((30, 30, 30))
cfg = V.b2_config()
N, CHUNK = 20_000, 2_000

# ---- uninterrupted reference ----
ref = S.simulate(vol, cfg, N, 1024, seed=5)

# ---- campaign with a failure + crash + restart ----
ck = Checkpointer("/tmp/repro_campaign", keep=2)
sim = ElasticSimulator(vol, cfg, N, CHUNK, n_lanes=1024, seed=5)

killed = [True]
sim.run_round(fail=lambda ch, dev: ch.start_id == 2 * CHUNK and killed
              and (killed.pop(), True)[1])
print(f"round 1: {len(sim.completed)} chunks done, "
      f"{len(sim.pending)} pending (1 failed + requeued)")
ck.save(1, sim.state_dict())
print("checkpoint saved; simulating process crash...")

# ---- new process: restore and finish ----
sim2 = ElasticSimulator(vol, cfg, N, CHUNK, n_lanes=1024, seed=5)
_, state = ck.restore(sim2.state_dict())
sim2.load_state_dict(state)
res = sim2.run_to_completion()

diff = np.abs(np.asarray(res.energy) - np.asarray(ref.energy)).max()
rel = diff / np.asarray(ref.energy).max()
print(f"resumed campaign: {A.energy_balance(res)}")
print(f"max voxel energy diff vs uninterrupted run: {rel:.2e} (fp-order only)")
assert rel < 1e-3
print("OK: failure + restart reproduced the uninterrupted result")
