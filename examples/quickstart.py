"""Quickstart: run the paper's B1 benchmark and validate the physics.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V

# the paper's B1 domain: 60 mm cube, mua=0.005/mm, mus=1/mm, g=0.01, n=1.37
vol = V.benchmark_b1((60, 60, 60))
cfg = V.b1_config()

print("simulating 50k photons (B1, pencil beam at (30,30,0))...")
res = S.simulate(vol, cfg, n_photons=50_000, n_lanes=4096, seed=42)
jax.block_until_ready(res)

bal = A.energy_balance(res)
print(f"energy balance: launched={bal['launched']:.0f} "
      f"absorbed={bal['absorbed']:.1f} escaped={bal['escaped']:.1f} "
      f"residue={bal['residue_frac']:.2e}")

mu_fit = A.fit_axial_decay(res, vol, (10, 35), axis_xy=(30, 30))
mu_th = A.mu_eff_theory(0.005, 1.0, 0.01)
print(f"axial decay: fitted mu_eff={mu_fit:.4f}/mm, "
      f"diffusion theory={mu_th:.4f}/mm ({mu_fit/mu_th*100:.0f}%)")

phi = np.asarray(A.fluence_cw(res, vol))
print("on-axis fluence profile (z=0..14 mm):")
line = phi[30, 30, :15]
for z, v in enumerate(line):
    bar = "#" * int(max(0, 50 + 5 * np.log10(max(v, 1e-12))))
    print(f"  z={z:2d}mm {v:9.3e} {bar}")
