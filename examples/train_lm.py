"""End-to-end LM training driver on a reduced config.

Trains a ~small llama3-family model for a few hundred steps on the
synthetic pipeline, with checkpoint/restart. Any of the 10 assigned
archs can be selected with --arch.

  PYTHONPATH=src python examples/train_lm.py [--arch mixtral-8x7b]
"""

import argparse
import sys

from repro.launch import train as T

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    T.main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq_len", "64", "--lr", "3e-3",
            "--ckpt_every", "50", "--ckpt_dir", "/tmp/repro_train_lm"])
