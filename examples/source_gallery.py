"""Source gallery: every registered illumination type on the B1 cube.

Runs each source through the same simulation, prints the energy balance
and an ASCII map of the diffuse-reflectance (exitance) image — the
spatial signature that distinguishes a pencil from a disk from a slit.

  PYTHONPATH=src python examples/source_gallery.py [--photons N] [--size S]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import sources as SRC
from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V


def ascii_map(img: np.ndarray, width: int = 32) -> str:
    """Log-scale ASCII rendering of a 2-D exitance image."""
    shades = " .:-=+*#%@"
    ds = max(1, img.shape[0] // width)
    img = img[: img.shape[0] // ds * ds, : img.shape[1] // ds * ds]
    img = img.reshape(img.shape[0] // ds, ds, img.shape[1] // ds, ds).sum((1, 3))
    lo = np.log10(np.maximum(img, 1e-12))
    lo = (lo - lo.min()) / max(lo.max() - lo.min(), 1e-9)
    idx = np.minimum((lo * len(shades)).astype(int), len(shades) - 1)
    idx[img <= 0] = 0
    return "\n".join("".join(shades[i] for i in row) for row in idx.T)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--photons", type=int, default=20_000)
    ap.add_argument("--size", type=int, default=40)
    ap.add_argument("--lanes", type=int, default=2048)
    args = ap.parse_args(argv)

    vol = V.benchmark_b1((args.size,) * 3)
    cfg = V.b1_config()
    for name, src in SRC.demo_menu(args.size).items():
        res = S.simulate(vol, cfg, args.photons, args.lanes, 42, source=src)
        jax.block_until_ready(res)
        bal = A.energy_balance(res)
        print(f"\n=== {name}  ({SRC.to_dict(src)})")
        print(f"    launched_w={bal['launched']:.1f} "
              f"absorbed={bal['absorbed']:.1f} escaped={bal['escaped']:.1f} "
              f"residue={-bal['residue_frac']:+.2e} steps={int(res.steps)}")
        print("    exitance through z=0 (log scale):")
        for line in ascii_map(np.asarray(res.exitance)).splitlines():
            print("    " + line)


if __name__ == "__main__":
    main()
