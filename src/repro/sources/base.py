"""Source subsystem core: protocol, registry, RNG streams, geometry helpers.

A *source* turns global photon ids into launch states.  Every source is a
frozen dataclass with static (Python-scalar / tuple) parameters and one
method::

    sample(photon_ids, seed) -> (pos, dir, w0, rng)

with ``pos``/``dir`` of shape (N, 3) float32 (voxel units / unit
vectors), ``w0`` the (N,) initial packet weight and ``rng`` the (N, 4)
uint32 in-flight xorshift128 state.  ``photon_ids`` is either a plain
(N,) uint32 array (legacy 32-bit ids) or an :class:`repro.core.rng.
PhotonId` two-word pair — 64-bit global ids for campaigns beyond 2**32
photons.  Sources never do id arithmetic themselves: they read
``photon_ids.shape[0]`` and hand the ids to the stream constructors
below, so every registered type is 64-bit-clean by construction.

Determinism contract (DESIGN.md §sources):

  * ``sample`` is a pure function of (photon_ids, seed) and the static
    source parameters — no hidden state, no host randomness.
  * Launch-time randomness is drawn from a dedicated *launch stream*,
    counter-seeded from ``(seed ^ LAUNCH_STREAM_SALT, photon_id)``.  The
    in-flight stream stays seeded from ``(seed, photon_id)`` exactly as
    before, so switching source type never perturbs trajectories-given-
    launch-state, and the pencil beam (zero draws) is bit-identical to
    the historical hard-coded launch.
  * Each source type consumes a fixed number of launch-stream uniforms
    per photon (``N_DRAWS``), independent of runtime values.

Together with the counter-based seeding this makes every source
bit-reproducible across single-device, shard_map multi-device
(``id_offset`` ranges), chunked, and restarted runs: photon ``k`` gets
the same launch state and trajectory no matter which lane, device, or
process simulates it.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import rng as xrng

# Domain-separation salt for the launch stream.  XORed into the master
# seed so launch-time draws are decorrelated from the in-flight stream
# (which keeps using the unsalted seed) without consuming from it.
LAUNCH_STREAM_SALT = 0xA511CE50


@runtime_checkable
class PhotonSource(Protocol):
    """Structural type every registered source satisfies."""

    def sample(self, photon_ids, seed):
        """(photon_ids, seed) -> (pos, dir, w0, rng) per-lane launch state."""
        ...


# ---------------------------------------------------------------------------
# RNG streams
# ---------------------------------------------------------------------------

def launch_stream(seed, photon_ids) -> jnp.ndarray:
    """Per-photon launch-time RNG state (salted counter seed).

    ``photon_ids`` may be a plain uint32 array or an ``rng.PhotonId``
    pair (64-bit ids); both words fold into the seeding.
    """
    seed = jnp.asarray(seed, jnp.uint32) ^ jnp.uint32(LAUNCH_STREAM_SALT)
    return xrng.seed_state(seed, photon_ids)


def flight_stream(seed, photon_ids) -> jnp.ndarray:
    """Per-photon in-flight RNG state — identical to the historical
    seeding for ids below 2**32 (plain arrays or ``PhotonId`` alike)."""
    return xrng.seed_state(jnp.asarray(seed, jnp.uint32), photon_ids)


# ---------------------------------------------------------------------------
# geometry helpers (static params -> trace-time numpy, lane math -> jnp)
# ---------------------------------------------------------------------------

def unit(v) -> jnp.ndarray:
    """Normalize a static 3-vector in float64, return float32 (matches the
    historical ``Source.dir_array`` arithmetic bit-for-bit)."""
    d = np.asarray(v, np.float64)  # reprolint: disable=REP301 - f64 normalize, f32 result
    return jnp.asarray(d / np.linalg.norm(d), jnp.float32)


def orthonormal_frame(axis) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two unit vectors spanning the plane perpendicular to a static axis."""
    a = np.asarray(axis, np.float64)  # reprolint: disable=REP301 - f64 normalize, f32 result
    a = a / np.linalg.norm(a)
    h = np.array([0.0, 0.0, 1.0]) if abs(a[2]) < 0.9 else np.array([1.0, 0.0, 0.0])
    e1 = np.cross(h, a)
    e1 = e1 / np.linalg.norm(e1)
    e2 = np.cross(a, e1)
    return jnp.asarray(e1, jnp.float32), jnp.asarray(e2, jnp.float32)


def isotropic_direction(u_cos, u_phi) -> jnp.ndarray:
    """Unit directions uniform over the sphere from two launch uniforms.

    Shared by every isotropically-emitting source so the arithmetic (and
    therefore the bit-level result for a given launch stream) is defined
    in exactly one place.
    """
    cost = 2.0 * u_cos - 1.0
    sint = jnp.sqrt(jnp.maximum(1.0 - cost * cost, 0.0))
    phi = (2.0 * np.pi) * u_phi
    return jnp.stack(
        [sint * jnp.cos(phi), sint * jnp.sin(phi), cost], axis=-1
    )


def radial_offset(pos, r, u_phi, e1, e2) -> jnp.ndarray:
    """Offset (N, 3) positions by radius ``r`` at azimuth ``2π·u_phi`` in
    the plane spanned by ``(e1, e2)``.

    Shared by every radial beam profile (disk, Gaussian) so the offset
    arithmetic — and thus the bit-level launch state for a given stream —
    is defined in exactly one place; only the r(u) formula differs.
    """
    phi = (2.0 * np.pi) * u_phi
    return (
        pos
        + (r * jnp.cos(phi))[:, None] * e1
        + (r * jnp.sin(phi))[:, None] * e2
    )


def direction_from_axis(cost, phi, axis, e1, e2) -> jnp.ndarray:
    """Unit directions at polar cosine ``cost`` / azimuth ``phi`` around
    a static ``axis`` with perpendicular frame ``(e1, e2)``."""
    cost = jnp.clip(cost, -1.0, 1.0)
    sint = jnp.sqrt(jnp.maximum(1.0 - cost * cost, 0.0))
    d = (
        (sint * jnp.cos(phi))[:, None] * e1
        + (sint * jnp.sin(phi))[:, None] * e2
        + cost[:, None] * jnp.asarray(axis, jnp.float32)
    )
    norm = jnp.sqrt(jnp.sum(d * d, axis=-1, keepdims=True))
    return d / jnp.maximum(norm, 1e-12)


# ---------------------------------------------------------------------------
# staged launch parameters (scenario batching, DESIGN.md §batching)
# ---------------------------------------------------------------------------

class StagedSource:
    """Bind a source class's jnp sampling path to *traced* launch params.

    Every registered source splits its ``sample`` into a host-side
    ``stage()`` (the f64 derivations over static fields — unit vectors,
    orthonormal frames, cos of the half angle — rounded once to f32)
    and a pure-jnp ``sample_staged(staged, photon_ids, seed)`` that
    consumes only the staged dict.  ``sample`` is the composition, so
    the static path is unchanged; a ``StagedSource`` instead feeds
    ``sample_staged`` a dict of *tracers* — per-scenario launch params
    under ``vmap`` — through the identical op sequence, which is what
    makes `simulate_many` bit-identical to per-scenario runs.

    Hashable by identity (the staged values may be tracers), so
    ``as_source`` passes instances through untouched.
    """

    __slots__ = ("source_cls", "staged")

    def __init__(self, source_cls: type, staged: dict):
        self.source_cls = source_cls
        self.staged = dict(staged)

    def sample(self, photon_ids, seed):
        return self.source_cls.sample_staged(self.staged, photon_ids, seed)


def stage_source(source) -> tuple[type, dict]:
    """Coerce + stage: returns ``(source class, staged param dict)``.

    The staged dict holds concrete f32 arrays (host-derived launch
    parameters); scenario batching stacks them along a leading axis and
    rebinds them through :class:`StagedSource`.
    """
    src = as_source(source)
    if not hasattr(src, "stage"):
        raise TypeError(
            f"source {type(src).__qualname__} does not support staged "
            f"launch parameters (needs stage()/sample_staged(); required "
            f"for simulate_many batching)")
    return type(src), src.stage()


def staged_structure(source) -> tuple:
    """Hashable structural signature of a source's staged params.

    ``(type_name, ((param, shape), ...))`` — two sources share a
    compiled `simulate_many` executable exactly when this matches: the
    *values* of staged params are traced, but their presence and shapes
    (e.g. a Planar pattern's grid, a Line's collimated-vs-isotropic
    variant) are baked into the jaxpr.
    """
    cls, staged = stage_source(source)
    return (cls.type_name,
            tuple((k, tuple(np.shape(staged[k]))) for k in sorted(staged)))


# ---------------------------------------------------------------------------
# registry + config serialization
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register(name: str):
    """Class decorator: add a source type to the registry under ``name``."""

    def deco(cls):
        cls.type_name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_sources() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_source_cls(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown source type {name!r}; registered: {available_sources()}"
        ) from None


def _jsonify(v):
    if isinstance(v, tuple):
        return [_jsonify(x) for x in v]
    return v


def _unjsonify(v):
    if isinstance(v, (list, tuple)):
        return tuple(_unjsonify(x) for x in v)
    return v


def to_dict(source) -> dict:
    """Serialize a registered source to a JSON-friendly campaign config."""
    d = dataclasses.asdict(source)
    return {"type": source.type_name, **{k: _jsonify(v) for k, v in d.items()}}


def from_dict(d: dict):
    """Rebuild a source from :func:`to_dict` output (lists become tuples
    so the instance stays frozen/hashable)."""
    d = dict(d)
    cls = get_source_cls(d.pop("type"))
    return cls(**{k: _unjsonify(v) for k, v in d.items()})


def as_source(source=None) -> PhotonSource:
    """Coerce user input to a source instance.

    Accepts ``None`` (pencil-beam default — the paper's configuration),
    a registered source instance, the legacy :class:`repro.core.volume.
    Source` pencil dataclass, or a :func:`to_dict`-style config dict.
    """
    from repro.core.volume import Source as LegacySource
    from repro.sources.types import Pencil

    if source is None:
        return Pencil()
    if isinstance(source, LegacySource):
        return Pencil(pos=tuple(source.pos), dir=tuple(source.dir))
    if isinstance(source, dict):
        return from_dict(source)
    if isinstance(source, PhotonSource):
        try:
            hash(source)
        except TypeError:
            if hasattr(source, "type_name"):
                # e.g. a registered dataclass built with list-typed fields:
                # normalize to tuples so jit caches keyed by source work
                return from_dict(to_dict(source))
            raise
        return source
    raise TypeError(f"cannot interpret {source!r} as a photon source")
