"""Pluggable photon source subsystem (DESIGN.md §sources).

Every source is a frozen dataclass with a pure, counter-seeded
``sample(photon_ids, seed) -> (pos, dir, w0, rng)`` — bit-reproducible
across single-device, sharded, chunked, and restarted runs.  The pencil
beam is the default and reproduces the historical hard-coded launch
bit-for-bit.

    from repro import sources
    res = simulate(vol, cfg, n, source=sources.Disk(pos=(30, 30, 0), radius=5))
    cfgd = sources.to_dict(src)          # JSON-friendly campaign config
    src = sources.from_dict(cfgd)        # ... and back
"""

from repro.sources.base import (
    LAUNCH_STREAM_SALT,
    PhotonSource,
    StagedSource,
    as_source,
    available_sources,
    flight_stream,
    from_dict,
    get_source_cls,
    launch_stream,
    register,
    stage_source,
    staged_structure,
    to_dict,
)
from repro.sources.types import (
    Cone,
    Disk,
    GaussianBeam,
    IsotropicPoint,
    Line,
    Pencil,
    Planar,
    demo_menu,
)

__all__ = [
    "LAUNCH_STREAM_SALT",
    "PhotonSource",
    "StagedSource",
    "as_source",
    "available_sources",
    "flight_stream",
    "from_dict",
    "get_source_cls",
    "launch_stream",
    "register",
    "stage_source",
    "staged_structure",
    "to_dict",
    "Cone",
    "Disk",
    "GaussianBeam",
    "IsotropicPoint",
    "Line",
    "Pencil",
    "Planar",
    "demo_menu",
]
