"""The source menu: MCX-style illumination patterns as frozen dataclasses.

All positions/lengths are in *voxel units* (like everything in the
engine; multiply mm by ``1/unitinmm`` to convert), directions need not
be normalized.  Sources should lie within the simulation domain; any
sampled launch position outside it is clamped onto the domain boundary
(see ``photon.launch``).  Each type documents its launch-stream draw count
(``N_DRAWS``) — part of the determinism contract in DESIGN.md §sources.

Registered types (see ``repro.sources.available_sources()``):

  pencil     zero-width collimated beam (the paper's configuration)
  isotropic  point source radiating uniformly over 4π
  cone       uniform solid-angle cone around an axis
  gaussian   collimated beam with Gaussian intensity profile
  disk       uniform-intensity flat circular beam
  planar     uniform parallelogram patch, optional intensity pattern
  line       line segment, collimated (slit) or isotropic emission
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import rng as xrng
from repro.sources import base

_TWO_PI = 2.0 * math.pi

Vec3 = tuple[float, float, float]


def _broadcast_pos(pos, n: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(pos, jnp.float32), (n, 3))


def _ones(n: int) -> jnp.ndarray:
    return jnp.ones((n,), jnp.float32)


@base.register("pencil")
@dataclasses.dataclass(frozen=True)
class Pencil:
    """Zero-width collimated beam — bit-identical to the historical
    hard-coded launch (consumes no launch-stream draws)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)

    N_DRAWS = 0

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        direc = jnp.broadcast_to(base.unit(self.dir), (n, 3))
        return (_broadcast_pos(self.pos, n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))


@base.register("isotropic")
@dataclasses.dataclass(frozen=True)
class IsotropicPoint:
    """Point source radiating uniformly over the full sphere."""

    pos: Vec3 = (30.0, 30.0, 30.0)

    N_DRAWS = 2  # u_cos, u_phi

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        direc = base.isotropic_direction(u_cos, u_phi)
        return (_broadcast_pos(self.pos, n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))


@base.register("cone")
@dataclasses.dataclass(frozen=True)
class Cone:
    """Point source emitting uniformly into a cone of ``half_angle_deg``
    around ``dir`` (an optical-fiber numerical-aperture model)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    half_angle_deg: float = 15.0

    N_DRAWS = 2  # u_cos, u_phi

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        axis = base.unit(self.dir)
        e1, e2 = base.orthonormal_frame(self.dir)
        cos_half = math.cos(math.radians(self.half_angle_deg))
        ls = base.launch_stream(seed, photon_ids)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        # uniform over the spherical cap [cos_half, 1]
        cost = 1.0 - u_cos * (1.0 - cos_half)
        direc = base.direction_from_axis(cost, _TWO_PI * u_phi, axis, e1, e2)
        return (_broadcast_pos(self.pos, n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))


@base.register("gaussian")
@dataclasses.dataclass(frozen=True)
class GaussianBeam:
    """Collimated beam with Gaussian intensity profile of 1/e² radius
    ``waist`` (voxel units), centered on ``pos`` and propagating along
    ``dir``: r = waist·sqrt(-ln u / 2)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    waist: float = 3.0

    N_DRAWS = 2  # u_r, u_phi

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        e1, e2 = base.orthonormal_frame(self.dir)
        ls = base.launch_stream(seed, photon_ids)
        ls, u_r = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        r = self.waist * jnp.sqrt(-jnp.log(u_r) * 0.5)
        pos = base.radial_offset(_broadcast_pos(self.pos, n), r, u_phi, e1, e2)
        direc = jnp.broadcast_to(base.unit(self.dir), (n, 3))
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)


@base.register("disk")
@dataclasses.dataclass(frozen=True)
class Disk:
    """Uniform-intensity collimated circular beam of ``radius`` voxels."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    radius: float = 5.0

    N_DRAWS = 2  # u_r, u_phi

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        e1, e2 = base.orthonormal_frame(self.dir)
        ls = base.launch_stream(seed, photon_ids)
        ls, u_r = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        r = self.radius * jnp.sqrt(u_r)  # uniform over the disk area
        pos = base.radial_offset(_broadcast_pos(self.pos, n), r, u_phi, e1, e2)
        direc = jnp.broadcast_to(base.unit(self.dir), (n, 3))
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)


@base.register("planar")
@dataclasses.dataclass(frozen=True)
class Planar:
    """Collimated area source over the parallelogram ``pos + a·v1 + b·v2``
    (a, b uniform in [0, 1)).

    ``pattern`` (optional, row-major tuple-of-tuples) modulates the
    initial packet weight like MCX's pattern source: the patch is split
    into len(pattern) × len(pattern[0]) cells along (v1, v2) and a photon
    launched in cell (i, j) starts with w0 = pattern[i][j].  Positions
    stay uniform; only weights vary — SDS-style structured illumination
    without rejection sampling.
    """

    pos: Vec3 = (20.0, 20.0, 0.0)
    v1: Vec3 = (20.0, 0.0, 0.0)
    v2: Vec3 = (0.0, 20.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    pattern: tuple = ()

    N_DRAWS = 2  # u_a, u_b

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_a = xrng.next_uniform(ls)
        ls, u_b = xrng.next_uniform(ls)
        v1 = jnp.asarray(self.v1, jnp.float32)
        v2 = jnp.asarray(self.v2, jnp.float32)
        pos = (
            _broadcast_pos(self.pos, n)
            + u_a[:, None] * v1
            + u_b[:, None] * v2
        )
        if self.pattern:
            pat = jnp.asarray(self.pattern, jnp.float32)
            rows, cols = pat.shape
            ia = jnp.clip((u_a * rows).astype(jnp.int32), 0, rows - 1)
            ib = jnp.clip((u_b * cols).astype(jnp.int32), 0, cols - 1)
            w0 = jnp.take(pat.reshape(-1), ia * cols + ib)
        else:
            w0 = _ones(n)
        direc = jnp.broadcast_to(base.unit(self.dir), (n, 3))
        return pos, direc, w0, base.flight_stream(seed, photon_ids)


@base.register("line")
@dataclasses.dataclass(frozen=True)
class Line:
    """Line-segment source from ``start`` to ``end``.

    With ``dir`` set this is a slit (collimated along ``dir``); with
    ``dir=None`` each photon emits isotropically from its launch point.
    Always draws 3 launch uniforms so the stream layout is identical for
    both variants.
    """

    start: Vec3 = (20.0, 30.0, 0.0)
    end: Vec3 = (40.0, 30.0, 0.0)
    dir: Vec3 | None = (0.0, 0.0, 1.0)

    N_DRAWS = 3  # u_t, u_cos, u_phi

    def sample(self, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_t = xrng.next_uniform(ls)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        start = jnp.asarray(self.start, jnp.float32)
        end = jnp.asarray(self.end, jnp.float32)
        pos = start[None, :] + u_t[:, None] * (end - start)[None, :]
        if self.dir is not None:
            direc = jnp.broadcast_to(base.unit(self.dir), (n, 3))
        else:
            direc = base.isotropic_direction(u_cos, u_phi)
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)


def demo_menu(size: int) -> dict:
    """One representative instance of every source type, scaled to a
    cubic domain of edge ``size`` voxels.  Shared by the source gallery
    example and the per-source throughput benchmark so both always
    exercise the same configurations."""
    c = size / 2.0
    q = size / 4.0
    return {
        "pencil": Pencil(pos=(c, c, 0.0)),
        "isotropic": IsotropicPoint(pos=(c, c, c)),
        "cone": Cone(pos=(c, c, 0.0), half_angle_deg=20.0),
        "gaussian": GaussianBeam(pos=(c, c, 0.0), waist=size / 12.0),
        "disk": Disk(pos=(c, c, 0.0), radius=size / 6.0),
        # checkerboard: structured illumination via launch weights
        "planar+pattern": Planar(
            pos=(q, q, 0.0), v1=(2 * q, 0.0, 0.0), v2=(0.0, 2 * q, 0.0),
            pattern=((1.0, 0.1, 1.0), (0.1, 1.0, 0.1), (1.0, 0.1, 1.0)),
        ),
        "line (slit)": Line(start=(q, c, 0.0), end=(3 * q, c, 0.0)),
        "line (isotropic)": Line(start=(q, c, c), end=(3 * q, c, c),
                                 dir=None),
    }
