"""The source menu: MCX-style illumination patterns as frozen dataclasses.

All positions/lengths are in *voxel units* (like everything in the
engine; multiply mm by ``1/unitinmm`` to convert), directions need not
be normalized.  Sources should lie within the simulation domain; any
sampled launch position outside it is clamped onto the domain boundary
(see ``photon.launch``).  Each type documents its launch-stream draw count
(``N_DRAWS``) — part of the determinism contract in DESIGN.md §sources.

Registered types (see ``repro.sources.available_sources()``):

  pencil     zero-width collimated beam (the paper's configuration)
  isotropic  point source radiating uniformly over 4π
  cone       uniform solid-angle cone around an axis
  gaussian   collimated beam with Gaussian intensity profile
  disk       uniform-intensity flat circular beam
  planar     uniform parallelogram patch, optional intensity pattern
  line       line segment, collimated (slit) or isotropic emission

Every type is split into ``stage()`` — the host-side f64 derivations
over static fields (unit vectors, frames, trig), rounded once to f32 —
and a pure-jnp ``sample_staged(staged, photon_ids, seed)`` consuming
only the staged dict; ``sample`` is their composition.  Scenario
batching (repro.scenarios, DESIGN.md §batching) stacks staged dicts
along a leading axis and traces them through the same
``sample_staged``, so batched launches are bit-identical to static
ones.  Scalar fields are staged as f32 — the value JAX's weak-typed
promotion would have rounded the Python float to anyway, so the static
path's bits are unchanged.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import rng as xrng
from repro.sources import base

_TWO_PI = 2.0 * math.pi

Vec3 = tuple[float, float, float]


def _broadcast_pos(pos, n: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(pos, jnp.float32), (n, 3))


def _ones(n: int) -> jnp.ndarray:
    return jnp.ones((n,), jnp.float32)


@base.register("pencil")
@dataclasses.dataclass(frozen=True)
class Pencil:
    """Zero-width collimated beam — bit-identical to the historical
    hard-coded launch (consumes no launch-stream draws)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)

    N_DRAWS = 0

    def stage(self):
        return {"pos": jnp.asarray(self.pos, jnp.float32),
                "dir": base.unit(self.dir)}

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        direc = jnp.broadcast_to(p["dir"], (n, 3))
        return (_broadcast_pos(p["pos"], n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("isotropic")
@dataclasses.dataclass(frozen=True)
class IsotropicPoint:
    """Point source radiating uniformly over the full sphere."""

    pos: Vec3 = (30.0, 30.0, 30.0)

    N_DRAWS = 2  # u_cos, u_phi

    def stage(self):
        return {"pos": jnp.asarray(self.pos, jnp.float32)}

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        direc = base.isotropic_direction(u_cos, u_phi)
        return (_broadcast_pos(p["pos"], n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("cone")
@dataclasses.dataclass(frozen=True)
class Cone:
    """Point source emitting uniformly into a cone of ``half_angle_deg``
    around ``dir`` (an optical-fiber numerical-aperture model)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    half_angle_deg: float = 15.0

    N_DRAWS = 2  # u_cos, u_phi

    def stage(self):
        e1, e2 = base.orthonormal_frame(self.dir)
        cos_half = math.cos(math.radians(self.half_angle_deg))
        return {"pos": jnp.asarray(self.pos, jnp.float32),
                "axis": base.unit(self.dir), "e1": e1, "e2": e2,
                # staged as the 1 - cos form the cap formula consumes, so
                # the single f64->f32 rounding matches the historical
                # weak-scalar promotion of (1.0 - cos_half)
                "one_minus_cos_half": jnp.float32(1.0 - cos_half)}

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        # uniform over the spherical cap [cos_half, 1]
        cost = 1.0 - u_cos * p["one_minus_cos_half"]
        direc = base.direction_from_axis(cost, _TWO_PI * u_phi, p["axis"],
                                         p["e1"], p["e2"])
        return (_broadcast_pos(p["pos"], n), direc, _ones(n),
                base.flight_stream(seed, photon_ids))

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("gaussian")
@dataclasses.dataclass(frozen=True)
class GaussianBeam:
    """Collimated beam with Gaussian intensity profile of 1/e² radius
    ``waist`` (voxel units), centered on ``pos`` and propagating along
    ``dir``: r = waist·sqrt(-ln u / 2)."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    waist: float = 3.0

    N_DRAWS = 2  # u_r, u_phi

    def stage(self):
        e1, e2 = base.orthonormal_frame(self.dir)
        return {"pos": jnp.asarray(self.pos, jnp.float32),
                "dir": base.unit(self.dir), "e1": e1, "e2": e2,
                "waist": jnp.float32(self.waist)}

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_r = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        r = p["waist"] * jnp.sqrt(-jnp.log(u_r) * 0.5)
        pos = base.radial_offset(_broadcast_pos(p["pos"], n), r, u_phi,
                                 p["e1"], p["e2"])
        direc = jnp.broadcast_to(p["dir"], (n, 3))
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("disk")
@dataclasses.dataclass(frozen=True)
class Disk:
    """Uniform-intensity collimated circular beam of ``radius`` voxels."""

    pos: Vec3 = (30.0, 30.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    radius: float = 5.0

    N_DRAWS = 2  # u_r, u_phi

    def stage(self):
        e1, e2 = base.orthonormal_frame(self.dir)
        return {"pos": jnp.asarray(self.pos, jnp.float32),
                "dir": base.unit(self.dir), "e1": e1, "e2": e2,
                "radius": jnp.float32(self.radius)}

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_r = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        r = p["radius"] * jnp.sqrt(u_r)  # uniform over the disk area
        pos = base.radial_offset(_broadcast_pos(p["pos"], n), r, u_phi,
                                 p["e1"], p["e2"])
        direc = jnp.broadcast_to(p["dir"], (n, 3))
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("planar")
@dataclasses.dataclass(frozen=True)
class Planar:
    """Collimated area source over the parallelogram ``pos + a·v1 + b·v2``
    (a, b uniform in [0, 1)).

    ``pattern`` (optional, row-major tuple-of-tuples) modulates the
    initial packet weight like MCX's pattern source: the patch is split
    into len(pattern) × len(pattern[0]) cells along (v1, v2) and a photon
    launched in cell (i, j) starts with w0 = pattern[i][j].  Positions
    stay uniform; only weights vary — SDS-style structured illumination
    without rejection sampling.
    """

    pos: Vec3 = (20.0, 20.0, 0.0)
    v1: Vec3 = (20.0, 0.0, 0.0)
    v2: Vec3 = (0.0, 20.0, 0.0)
    dir: Vec3 = (0.0, 0.0, 1.0)
    pattern: tuple = ()

    N_DRAWS = 2  # u_a, u_b

    def stage(self):
        p = {"pos": jnp.asarray(self.pos, jnp.float32),
             "v1": jnp.asarray(self.v1, jnp.float32),
             "v2": jnp.asarray(self.v2, jnp.float32),
             "dir": base.unit(self.dir)}
        # the pattern's *presence and grid shape* are structural (they
        # change the jaxpr); its weights are staged values
        if self.pattern:
            p["pattern"] = jnp.asarray(self.pattern, jnp.float32)
        return p

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_a = xrng.next_uniform(ls)
        ls, u_b = xrng.next_uniform(ls)
        pos = (
            _broadcast_pos(p["pos"], n)
            + u_a[:, None] * p["v1"]
            + u_b[:, None] * p["v2"]
        )
        if "pattern" in p:
            pat = p["pattern"]
            rows, cols = pat.shape
            ia = jnp.clip((u_a * rows).astype(jnp.int32), 0, rows - 1)
            ib = jnp.clip((u_b * cols).astype(jnp.int32), 0, cols - 1)
            w0 = jnp.take(pat.reshape(-1), ia * cols + ib)
        else:
            w0 = _ones(n)
        direc = jnp.broadcast_to(p["dir"], (n, 3))
        return pos, direc, w0, base.flight_stream(seed, photon_ids)

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


@base.register("line")
@dataclasses.dataclass(frozen=True)
class Line:
    """Line-segment source from ``start`` to ``end``.

    With ``dir`` set this is a slit (collimated along ``dir``); with
    ``dir=None`` each photon emits isotropically from its launch point.
    Always draws 3 launch uniforms so the stream layout is identical for
    both variants.
    """

    start: Vec3 = (20.0, 30.0, 0.0)
    end: Vec3 = (40.0, 30.0, 0.0)
    dir: Vec3 | None = (0.0, 0.0, 1.0)

    N_DRAWS = 3  # u_t, u_cos, u_phi

    def stage(self):
        p = {"start": jnp.asarray(self.start, jnp.float32),
             "end": jnp.asarray(self.end, jnp.float32)}
        # collimated-vs-isotropic is structural: the staged dict carries
        # a "dir" key exactly when the slit variant is selected
        if self.dir is not None:
            p["dir"] = base.unit(self.dir)
        return p

    @staticmethod
    def sample_staged(p, photon_ids, seed):
        n = photon_ids.shape[0]
        ls = base.launch_stream(seed, photon_ids)
        ls, u_t = xrng.next_uniform(ls)
        ls, u_cos = xrng.next_uniform(ls)
        ls, u_phi = xrng.next_uniform(ls)
        start, end = p["start"], p["end"]
        pos = start[None, :] + u_t[:, None] * (end - start)[None, :]
        if "dir" in p:
            direc = jnp.broadcast_to(p["dir"], (n, 3))
        else:
            direc = base.isotropic_direction(u_cos, u_phi)
        return pos, direc, _ones(n), base.flight_stream(seed, photon_ids)

    def sample(self, photon_ids, seed):
        return self.sample_staged(self.stage(), photon_ids, seed)


def demo_menu(size: int) -> dict:
    """One representative instance of every source type, scaled to a
    cubic domain of edge ``size`` voxels.  Shared by the source gallery
    example and the per-source throughput benchmark so both always
    exercise the same configurations."""
    c = size / 2.0
    q = size / 4.0
    return {
        "pencil": Pencil(pos=(c, c, 0.0)),
        "isotropic": IsotropicPoint(pos=(c, c, c)),
        "cone": Cone(pos=(c, c, 0.0), half_angle_deg=20.0),
        "gaussian": GaussianBeam(pos=(c, c, 0.0), waist=size / 12.0),
        "disk": Disk(pos=(c, c, 0.0), radius=size / 6.0),
        # checkerboard: structured illumination via launch weights
        "planar+pattern": Planar(
            pos=(q, q, 0.0), v1=(2 * q, 0.0, 0.0), v2=(0.0, 2 * q, 0.0),
            pattern=((1.0, 0.1, 1.0), (0.1, 1.0, 0.1), (1.0, 0.1, 1.0)),
        ),
        "line (slit)": Line(start=(q, c, 0.0), end=(3 * q, c, 0.0)),
        "line (isotropic)": Line(start=(q, c, c), end=(3 * q, c, c),
                                 dir=None),
    }
