"""Gradient compression for bandwidth-bound data-parallel reductions.

``compressed_psum`` implements int8-quantized all-reduce with error
feedback (1-bit-Adam / PowerSGD family, here symmetric per-tensor int8):
each shard quantizes (grad + error_memory), psums the int8 payload (XLA
reduces int32-accumulated), dequantizes, and keeps the quantization
residual as the next step's error memory — unbiased in the long run.

This is meaningful where the reduction is explicit (shard_map DP, e.g.
launch/train.py --dp_mode=shardmap); under plain GSPMD jit, XLA owns the
all-reduce and the compression cannot be injected (DESIGN.md §grad-comp).
The collective payload drops 4x (f32->int8), directly shrinking the
collective roofline term of DP-bound training cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jnp.ndarray, error: jnp.ndarray, axis_name: str):
    """Error-feedback int8 psum of ``grad`` over ``axis_name``.

    Returns (mean_grad_f32, new_error).  Call inside shard_map.
    """
    x = grad.astype(jnp.float32) + error
    # agree on one scale across shards (one scalar pmax) so the int8
    # payloads are directly summable; quantize against the global scale
    local_absmax = jnp.max(jnp.abs(x))
    gmax = jax.lax.pmax(local_absmax, axis_name)
    gscale = jnp.where(gmax > 0, gmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / gscale), -127, 127).astype(jnp.int8)
    new_error = x - q.astype(jnp.float32) * gscale  # error feedback memory
    total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total * gscale / n
    return mean, new_error
