"""Minimal functional AdamW with global-norm clipping (optax-style API).

Optimizer moments are fp32 regardless of param dtype; state inherits the
param sharding (sharding/partition.py), so under the fsdp axis the
12 bytes/param of Adam state spread over the full mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Any
    update: Any


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        if clip_norm:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
            vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m_new, v_new

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
