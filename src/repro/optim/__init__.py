from repro.optim.adamw import adamw, apply_updates, global_norm  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compressed_psum, dequantize_int8, quantize_int8,
)
