"""Batched multi-scenario execution: ``simulate_many`` + compile cache.

A serving-scale reproduction amortizes compilation and batches *fleets*
of (volume, source, detector) scenarios, not one config per ``sim_fn``
(ROADMAP "Batched multi-scenario execution"; DESIGN.md §batching).  This
package vmaps the round executor over a leading scenario axis:

  * per-scenario **media tables**, **source params** (staged launch
    parameters, ``repro.sources.StagedSource``), **seeds**, **photon
    budgets**, **64-bit id offsets** and **detector geometries** are all
    traced — none of their values bake into the jaxpr;
  * volume **labels** are shared (one copy, ``in_axes=None``) when every
    scenario in a group carries the same grid, stacked otherwise;
  * everything *structural* — volume dims, ``SimConfig``, lane count,
    engine, source type + staged-param shapes, detector count — forms
    the **group key**: scenarios group by it, and each group runs as one
    vmapped call.

Executables live in an explicit :class:`CompileCache` keyed by the
traced config shape (group key + batch size + labels sharing + mesh),
so new scenarios of a known shape reuse compiled code; hit/miss/eviction
counters surface through ``repro.telemetry`` (``scenarios.cache.*``
counters, ``scenarios.compile`` / ``scenarios.batch`` spans).

Bit-identity: JAX's while_loop batching rule select-freezes finished
batch elements, and the staged-source path replays the identical op
sequence as the static one, so every scenario's ``SimResult`` from
``simulate_many`` is bit-identical to its own sequential
:func:`simulate_one` run — per engine, and under a device mesh (the
scenario axis shard_maps with no collectives; zero-photon padding
rounds the batch up to the device count).

    from repro.scenarios import Scenario, simulate_many
    results = simulate_many([Scenario(vol, cfg, n_photons=10_000, seed=s)
                             for s in range(8)], engine="jnp")

CLI: ``python -m repro.launch.simulate --scenarios '[{...}, ...]'``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import volume as V
from repro.core.rng import split_id64
from repro.core.simulator import ENGINES, SimResult, build_sim_fn
from repro.core.volume import SimConfig, Volume
from repro.detectors import (as_detectors, det_geometry, validate_detectors)
from repro.sources import StagedSource, as_source, stage_source

__all__ = [
    "CompileCache",
    "Scenario",
    "default_cache",
    "group_key",
    "make_batched",
    "simulate_many",
    "simulate_one",
]


# ---------------------------------------------------------------------------
# scenario description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One (volume, physics, source, detectors, budget) work item.

    ``source`` / ``detectors`` accept anything ``sources.as_source`` /
    ``detectors.as_detectors`` accept (instances, config dicts, None).
    ``id_offset`` is the 64-bit global photon-id base — scenarios with
    disjoint id ranges simulate disjoint photon sets even at the same
    seed (DESIGN.md §determinism).
    """

    volume: Volume
    cfg: SimConfig
    n_photons: int
    seed: int = 1234
    source: object = None
    detectors: object = ()
    id_offset: int = 0

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        """Build from the CLI's ``--scenarios`` JSON entry form.

        Keys: ``bench`` (B1|B2|B2a, default B1), ``size`` (cube edge,
        default 24), ``photons`` (required), ``seed``, ``source``
        (sources.to_dict form), ``detectors`` (list of disk dicts),
        ``time_gates``, ``steps_per_round``, ``tmax_ns``,
        ``do_reflect``, ``id_offset``.
        """
        d = dict(d)
        bench = d.pop("bench", "B1")
        size = int(d.pop("size", 24))
        shape = (size, size, size)
        if bench == "B1":
            vol, do_reflect = V.benchmark_b1(shape), False
        elif bench in ("B2", "B2a"):
            vol, do_reflect = V.benchmark_b2(shape), True
        else:
            raise ValueError(f"unknown bench {bench!r} (B1|B2|B2a)")
        cfg = SimConfig(
            do_reflect=bool(d.pop("do_reflect", do_reflect)),
            steps_per_round=int(d.pop("steps_per_round", 1)),
            n_time_gates=int(d.pop("time_gates", 1)))
        if "tmax_ns" in d:
            cfg = dataclasses.replace(cfg, tmax_ns=float(d.pop("tmax_ns")))
        sc = cls(volume=vol, cfg=cfg, n_photons=int(d.pop("photons")),
                 seed=int(d.pop("seed", 1234)),
                 source=d.pop("source", None),
                 detectors=tuple(d.pop("detectors", ()) or ()),
                 id_offset=int(d.pop("id_offset", 0)))
        if d:
            raise ValueError(f"unknown scenario keys: {sorted(d)}")
        return sc


@dataclasses.dataclass
class _Prep:
    """A scenario normalized for batching: coerced source/detectors,
    staged launch params, concrete geometry, split id offset."""

    idx: int
    sc: Scenario
    src_cls: type
    staged: dict
    dets: tuple
    det_geom: np.ndarray | None
    id_lo: np.uint32
    id_hi: np.uint32


def _prepare(idx: int, sc: Scenario) -> _Prep:
    src_cls, staged = stage_source(sc.source)
    dets = as_detectors(sc.detectors)
    if dets:
        validate_detectors(dets, sc.volume.shape)
    det_geom = np.asarray(det_geometry(dets)) if dets else None
    lo, hi = split_id64(int(sc.id_offset))
    return _Prep(idx=idx, sc=sc, src_cls=src_cls, staged=staged, dets=dets,
                 det_geom=det_geom, id_lo=lo, id_hi=hi)


# ---------------------------------------------------------------------------
# grouping: the traced config shape
# ---------------------------------------------------------------------------

def group_key(sc: Scenario, n_lanes: int, mode: str = "dynamic",
              engine: str = "jnp", block_lanes: int = 256,
              interpret: bool | None = None) -> tuple:
    """Hashable structural signature of one scenario's traced shape.

    Scenarios sharing this key run in one vmapped call and compile to
    one executable: volume dims + unitinmm + media count, the full
    ``SimConfig`` (K, ntg, reflection, caps — all static), the executor
    config (lanes, mode, engine, block size, interpret), the source's
    staged structure (type + param shapes) and the detector count.
    Per-scenario *values* — media tables, source params, seeds, photon
    budgets, detector coordinates — are deliberately absent: they are
    traced.
    """
    prep = _prepare(0, sc)
    return _group_key(prep, n_lanes, mode, engine, block_lanes, interpret)


def _group_key(prep: _Prep, n_lanes, mode, engine, block_lanes, interpret):
    v = prep.sc.volume
    src_struct = (prep.src_cls.type_name,
                  tuple((k, tuple(np.shape(prep.staged[k])))
                        for k in sorted(prep.staged)))
    return (tuple(int(x) for x in v.shape), float(v.unitinmm),
            int(v.media.shape[0]), prep.sc.cfg, int(n_lanes), mode, engine,
            int(block_lanes), interpret, src_struct, len(prep.dets))


# ---------------------------------------------------------------------------
# compile cache
# ---------------------------------------------------------------------------

class CompileCache:
    """Explicit LRU executable cache for :func:`simulate_many`.

    Keys are ``(group key, padded batch size, labels shared?, mesh
    signature)`` — exactly the trace-time shape of the batched call, so
    a hit is guaranteed to reuse the compiled executable (same jitted
    callable, same input avals).  ``max_entries`` bounds the cache with
    keyed LRU eviction; hit/miss/eviction counts are plain attributes
    (surfaced as telemetry counters by ``simulate_many``).
    """

    def __init__(self, max_entries: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def get(self, key):
        """Look up an executable; counts a hit or a miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        return None

    def put(self, key, fn) -> None:
        self._entries[key] = fn
        self._entries.move_to_end(key)
        while (self.max_entries is not None
               and len(self._entries) > self.max_entries):
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        """Counters + hit rate (1.0 on an all-hit repeat-shape run)."""
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._entries),
                "hit_rate": self.hits / total if total else 0.0}


_DEFAULT_CACHE = CompileCache(max_entries=64)


def default_cache() -> CompileCache:
    """The process-wide cache ``simulate_many`` uses when none is given."""
    return _DEFAULT_CACHE


# ---------------------------------------------------------------------------
# the batched executor
# ---------------------------------------------------------------------------

def _raw_batched_fn(rep: _Prep, n_lanes, mode, engine, block_lanes,
                    interpret, share_labels: bool):
    """vmap the per-scenario closure over the leading scenario axis.

    The inner ``one`` rebuilds ``build_sim_fn`` at trace time with the
    scenario's *traced* staged source params and detector geometry —
    closures over vmap tracers, so no per-scenario value is baked in.
    """
    vol = rep.sc.volume
    shape, unitinmm, cfg = vol.shape, vol.unitinmm, rep.sc.cfg
    src_cls, dets = rep.src_cls, rep.dets
    n_det = len(dets)

    def one(labels_flat, media, staged, det_geom, n_photons, seed,
            id_lo, id_hi):
        fn = build_sim_fn(shape, unitinmm, cfg, n_lanes, mode,
                          StagedSource(src_cls, staged), engine,
                          block_lanes, interpret, dets,
                          det_geom_override=det_geom)
        return fn(labels_flat, media, n_photons, seed, id_lo, id_hi)

    in_axes = (None if share_labels else 0, 0, 0, 0 if n_det else None,
               0, 0, 0, 0)
    return jax.vmap(one, in_axes=in_axes)


def _mesh_signature(mesh):
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(int(x) for x in mesh.shape.values()),
            tuple(d.id for d in mesh.devices.flat))


def _shard_batched_fn(vmapped, mesh, share_labels: bool, n_det: int):
    """Compose the scenario axis with a device mesh: shard axis 0 of
    every stacked input across the mesh's first axis name.  Disjoint
    scenarios need no collectives — out_specs keep the scenario axis
    sharded and jit reassembles the global batch."""
    from jax.sharding import PartitionSpec as P

    from repro.core.multidevice import _shard_map

    ax = mesh.axis_names[0]
    sspec = P(ax)
    in_specs = (P() if share_labels else sspec, sspec, sspec,
                sspec if n_det else P(), sspec, sspec, sspec, sspec)
    return _shard_map(vmapped, mesh=mesh, in_specs=in_specs,
                      out_specs=sspec)


def _stack_group(members: list[_Prep], pad: int, share_labels: bool):
    """Stack the group's per-scenario traced values, zero-photon-padding
    the batch by ``pad`` copies of the first scenario (they terminate
    before the first round, so padding never perturbs real results)."""
    rows = members + [members[0]] * pad
    n_real = len(members)

    def counts(i, m):
        return np.int32(m.sc.n_photons if i < n_real else 0)

    labels0 = np.asarray(rows[0].sc.volume.labels).reshape(-1)
    if share_labels:
        labels = jnp.asarray(labels0)
    else:
        labels = jnp.asarray(np.stack(
            [np.asarray(m.sc.volume.labels).reshape(-1) for m in rows]))
    media = jnp.asarray(np.stack(
        [np.asarray(m.sc.volume.media) for m in rows]))
    staged = {k: jnp.asarray(np.stack(
        [np.asarray(m.staged[k]) for m in rows]))
        for k in rows[0].staged}
    det_geom = (jnp.asarray(np.stack([m.det_geom for m in rows]))
                if rows[0].det_geom is not None else None)
    n_photons = jnp.asarray(
        np.asarray([counts(i, m) for i, m in enumerate(rows)], np.int32))
    seeds = jnp.asarray(
        np.asarray([np.uint32(m.sc.seed) for m in rows], np.uint32))
    id_lo = jnp.asarray(np.asarray([m.id_lo for m in rows], np.uint32))
    id_hi = jnp.asarray(np.asarray([m.id_hi for m in rows], np.uint32))
    return (labels, media, staged, det_geom, n_photons, seeds, id_lo, id_hi)


def _share_labels(members: list[_Prep]) -> bool:
    first = np.asarray(members[0].sc.volume.labels)
    for m in members[1:]:
        lab = m.sc.volume.labels
        if lab is members[0].sc.volume.labels:
            continue
        if not np.array_equal(np.asarray(lab), first):
            return False
    return True


def make_batched(scenarios, *, n_lanes: int = 1024, mode: str = "dynamic",
                 engine: str = "jnp", block_lanes: int = 256,
                 interpret: bool | None = None):
    """Build the raw (unjitted) batched fn + stacked args for scenarios
    that all share one group key.

    The building block ``simulate_many`` jits and caches; exposed so
    tracelint (REP805) and tests can prove the jaxpr is value-free:
    re-tracing with a different same-shape batch must fingerprint
    byte-identically.  Raises when the scenarios span multiple groups.
    """
    preps = [_prepare(i, sc) for i, sc in enumerate(scenarios)]
    if not preps:
        raise ValueError("make_batched needs at least one scenario")
    keys = {_group_key(p, n_lanes, mode, engine, block_lanes, interpret)
            for p in preps}
    if len(keys) != 1:
        raise ValueError(
            f"make_batched needs a single scenario group, got {len(keys)} "
            f"distinct config shapes; group with group_key() first")
    share = _share_labels(preps)
    fn = _raw_batched_fn(preps[0], n_lanes, mode, engine, block_lanes,
                         interpret, share)
    args = _stack_group(preps, 0, share)
    return fn, args


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def simulate_one(sc: Scenario, *, n_lanes: int = 1024,
                 mode: str = "dynamic", engine: str = "jnp",
                 block_lanes: int = 256,
                 interpret: bool | None = None) -> SimResult:
    """The sequential reference: one scenario through the unbatched
    engine (static source path, static detector geometry).  The
    bit-identity contract — and the scenario-matrix CI lane — compare
    ``simulate_many`` against a loop of these."""
    vol = sc.volume
    fn = jax.jit(build_sim_fn(vol.shape, vol.unitinmm, sc.cfg, n_lanes,
                              mode, as_source(sc.source), engine,
                              block_lanes, interpret,
                              as_detectors(sc.detectors)))
    return fn(vol.labels.reshape(-1), vol.media, sc.n_photons, sc.seed,
              *split_id64(int(sc.id_offset)))


def simulate_many(scenarios, *, n_lanes: int = 1024, mode: str = "dynamic",
                  engine: str = "jnp", block_lanes: int = 256,
                  interpret: bool | None = None, mesh=None,
                  cache: CompileCache | None = None,
                  tracer=None) -> list[SimResult]:
    """Run many scenarios through shared vmapped executables.

    Scenarios group by :func:`group_key`; each group becomes one batched
    call whose executable comes from ``cache`` (:func:`default_cache`
    when None) — new scenario *values* of a known shape never recompile.
    ``mesh`` shards each group's scenario axis across the mesh's first
    axis (zero-photon padding rounds the batch up to the device count).
    ``tracer`` records one ``scenarios.batch`` span per group execution,
    one ``scenarios.compile`` span per cache miss, and
    ``scenarios.cache.{hit,miss,evictions,hit_rate}`` counters.

    Returns per-scenario ``SimResult``\\ s in input order, each
    bit-identical to its own :func:`simulate_one`.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (choose from {ENGINES})")
    scenarios = list(scenarios)
    if not scenarios:
        return []
    cache = default_cache() if cache is None else cache
    preps = [_prepare(i, sc) for i, sc in enumerate(scenarios)]
    groups: OrderedDict = OrderedDict()
    for p in preps:
        gkey = _group_key(p, n_lanes, mode, engine, block_lanes, interpret)
        groups.setdefault(gkey, []).append(p)
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names[:1]]))
    out: list = [None] * len(scenarios)
    evictions0 = cache.evictions
    for gkey, members in groups.items():
        share = _share_labels(members)
        pad = (-len(members)) % n_dev
        s_pad = len(members) + pad
        key = (gkey, s_pad, share, _mesh_signature(mesh))
        fn = cache.get(key)
        hit = fn is not None
        if not hit:
            raw = _raw_batched_fn(members[0], n_lanes, mode, engine,
                                  block_lanes, interpret, share)
            if mesh is not None:
                raw = _shard_batched_fn(raw, mesh, share,
                                        len(members[0].dets))
            fn = jax.jit(raw)
            cache.put(key, fn)
        args = _stack_group(members, pad, share)
        total_photons = int(sum(m.sc.n_photons for m in members))
        bspan = cspan = None
        if tracer is not None:
            tracer.counter("scenarios.cache." + ("hit" if hit else "miss"),
                           1, engine=engine, scenarios=len(members))
            bspan = tracer.span("scenarios.batch", device=(
                "mesh" if mesh is not None else None), engine=engine,
                photons=total_photons, scenarios=len(members),
                cache_hit=hit)
            if not hit:
                cspan = tracer.span("scenarios.compile", engine=engine,
                                    scenarios=s_pad)
        res = fn(*args)
        jax.block_until_ready(res)
        if cspan is not None:
            cspan.end()
        if bspan is not None:
            bspan.end()
        for j, m in enumerate(members):
            out[m.idx] = jax.tree_util.tree_map(lambda a, j=j: a[j], res)
    if tracer is not None:
        st = cache.stats()
        tracer.counter("scenarios.cache.hit_rate", st["hit_rate"],
                       engine=engine)
        tracer.counter("scenarios.cache.evictions",
                       cache.evictions - evictions0, engine=engine)
    return out
