from repro.data.pipeline import (  # noqa: F401
    ByteTokenizer, SyntheticLM, make_pipeline,
)
