"""Deterministic, checkpointable data pipeline.

Two sources:
  * :class:`SyntheticLM` — counter-seeded synthetic token stream (zipf
    marginals + a learnable-by-LM bigram structure), used by smoke tests,
    benchmarks and the quickstart so nothing depends on external data.
  * :class:`ByteTokenizer` + text files — a real (if minimal) corpus
    path for the end-to-end example.

Both expose ``state_dict()/load_state_dict()`` (a single step counter —
batches are a pure function of (seed, step)), so a restore resumes the
exact batch sequence: the data-pipeline half of fault tolerance.  In a
multi-host deployment each process draws the same global batch and
slices its per-host shard by process index (``shard`` argument).
"""

from __future__ import annotations

import dataclasses

import numpy as np


class ByteTokenizer:
    """Trivial byte-level tokenizer (vocab 256 + pad)."""

    vocab_size = 257
    pad_id = 256

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(
            np.int32)

    def decode(self, ids) -> str:
        ids = [i for i in np.asarray(ids).tolist() if i < 256]
        return bytes(ids).decode("utf-8", errors="replace")


@dataclasses.dataclass
class SyntheticLM:
    """Counter-seeded synthetic LM batches: tokens + next-token labels."""

    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0

    def next_batch(self, shard: tuple[int, int] = (0, 1)) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        # zipf-ish marginal with deterministic bigram structure the model
        # can learn: token[t+1] = (a * token[t] + noise) % vocab
        b, s = self.batch, self.seq_len
        start = rng.integers(0, self.vocab, size=(b, 1))
        mult = 31
        noise = rng.integers(0, 7, size=(b, s))
        toks = np.zeros((b, s), np.int64)
        toks[:, 0] = start[:, 0]
        for t in range(1, s):
            toks[:, t] = (toks[:, t - 1] * mult + noise[:, t]) % self.vocab
        self.step += 1
        i, n = shard
        shard_b = b // n
        sl = slice(i * shard_b, (i + 1) * shard_b)
        tokens = toks[sl].astype(np.int32)
        labels = np.roll(toks[sl], -1, axis=1).astype(np.int32)
        mask = np.ones_like(tokens, np.float32)
        mask[:, -1] = 0.0
        return {"tokens": tokens, "labels": labels, "mask": mask}

    def state_dict(self) -> dict:
        return {"step": np.int64(self.step), "seed": np.int64(self.seed)}

    def load_state_dict(self, state: dict):
        assert int(state["seed"]) == self.seed, "seed mismatch on restore"
        self.step = int(state["step"])


class TextFileLM:
    """Packed next-token batches from a byte-tokenized text file."""

    def __init__(self, path: str, batch: int, seq_len: int, seed: int = 0):
        self.tok = ByteTokenizer()
        with open(path, "r", encoding="utf-8", errors="replace") as f:
            self.data = self.tok.encode(f.read())
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0

    def next_batch(self, shard: tuple[int, int] = (0, 1)) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        b, s = self.batch, self.seq_len
        n = len(self.data) - s - 1
        starts = rng.integers(0, max(n, 1), size=(b,))
        toks = np.stack([self.data[st : st + s] for st in starts])
        labels = np.stack([self.data[st + 1 : st + s + 1] for st in starts])
        self.step += 1
        i, k = shard
        shard_b = b // k
        sl = slice(i * shard_b, (i + 1) * shard_b)
        return {
            "tokens": toks[sl].astype(np.int32),
            "labels": labels[sl].astype(np.int32),
            "mask": np.ones((shard_b, s), np.float32),
        }

    def state_dict(self) -> dict:
        return {"step": np.int64(self.step), "seed": np.int64(self.seed)}

    def load_state_dict(self, state: dict):
        self.step = int(state["step"])


def make_pipeline(vocab: int, batch: int, seq_len: int, seed: int = 0,
                  path: str | None = None):
    if path:
        return TextFileLM(path, batch, seq_len, seed)
    return SyntheticLM(vocab=vocab, batch=batch, seq_len=seq_len, seed=seed)
