"""Counter-seeded xorshift128 RNG used by the photon transport engine.

MCX / MCX-CL use xorshift128+ operating on 64-bit words.  TPUs have no
64-bit integer vector units, so we adapt the paper's RNG choice to the
hardware: Marsaglia xorshift128 with four 32-bit words of state per
photon lane.  The identical bit-level algorithm is implemented both here
(pure jnp, the oracle) and inside the Pallas kernel, so kernel-vs-ref
comparisons are bit-exact.

Seeding is *counter based*: the state for photon ``photon_id`` under a
master ``seed`` is derived with splitmix32 rounds of ``seed ^ photon_id``.
This gives every photon an independent, reproducible stream regardless of
which lane / device / restart simulates it — the property that makes
checkpoint/restart and elastic re-partitioning deterministic (§DESIGN.md
fault tolerance).
"""

from __future__ import annotations

import jax.numpy as jnp

_U32 = jnp.uint32
# splitmix32 constants (Steele et al., "Fast splittable PRNGs")
_GOLDEN = jnp.uint32(0x9E3779B9)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)


def splitmix32(x: jnp.ndarray) -> jnp.ndarray:
    """One splitmix32 output step; ``x`` is the uint32 counter."""
    z = (x + _GOLDEN).astype(_U32)
    z = (z ^ (z >> 16)) * _MIX1
    z = (z ^ (z >> 13)) * _MIX2
    z = z ^ (z >> 16)
    return z.astype(_U32)


def seed_state(seed, photon_id) -> jnp.ndarray:
    """Derive a (..., 4) uint32 xorshift128 state from (seed, photon_id).

    Zero states are fixed up (xorshift must never be seeded all-zero).
    """
    seed = jnp.asarray(seed, _U32)
    pid = jnp.asarray(photon_id, _U32)
    base = (seed ^ (pid * jnp.uint32(0x9E3779B1))).astype(_U32)
    words = []
    x = base
    for k in range(4):
        x = splitmix32(x + jnp.uint32(k) * _GOLDEN)
        words.append(x)
    state = jnp.stack(words, axis=-1)
    # guarantee non-zero state per lane
    allzero = jnp.all(state == 0, axis=-1, keepdims=True)
    return jnp.where(allzero, jnp.uint32(0xDEADBEEF), state)


def next_u32(state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Marsaglia xorshift128 step. state: (..., 4) uint32 -> (new_state, u32)."""
    x = state[..., 0]
    y = state[..., 1]
    z = state[..., 2]
    w = state[..., 3]
    t = x ^ (x << 11)
    t = t ^ (t >> 8)
    neww = (w ^ (w >> 19)) ^ t
    new_state = jnp.stack([y, z, w, neww], axis=-1)
    return new_state, neww


def next_uniform(state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Uniform in the open interval (0, 1) with 24-bit resolution.

    Uses the top 24 bits; result is (r + 0.5) * 2^-24 so it can never be
    exactly 0 or 1 — safe to feed into log() for free-path sampling.
    """
    state, bits = next_u32(state)
    r = (bits >> 8).astype(jnp.float32)  # [0, 2^24)
    u = (r + jnp.float32(0.5)) * jnp.float32(2.0**-24)
    return state, u
