"""Counter-seeded xorshift128 RNG used by the photon transport engine.

MCX / MCX-CL use xorshift128+ operating on 64-bit words.  TPUs have no
64-bit integer vector units, so we adapt the paper's RNG choice to the
hardware: Marsaglia xorshift128 with four 32-bit words of state per
photon lane.  The identical bit-level algorithm is implemented both here
(pure jnp, the oracle) and inside the Pallas kernel, so kernel-vs-ref
comparisons are bit-exact.

Seeding is *counter based*: the state for photon ``photon_id`` under a
master ``seed`` is derived with splitmix32 rounds of ``seed ^ photon_id``.
This gives every photon an independent, reproducible stream regardless of
which lane / device / restart simulates it — the property that makes
checkpoint/restart and elastic re-partitioning deterministic (§DESIGN.md
fault tolerance).

Photon ids are 64-bit, carried as a :class:`PhotonId` two-word
``(lo, hi)`` uint32 pair (TPUs have no 64-bit integer vector units, and
JAX disables x64 by default).  Both words fold into the seeding: the low
word XORs into the splitmix base exactly as the historical 32-bit id
did, the high word adds a per-round offset to the splitmix chain.  A
zero high word contributes nothing, so every id below 2**32 produces a
bit-identical state to the legacy single-word seeding — and campaigns
beyond 2**32 photons get distinct streams instead of silently wrapping
and re-simulating the first photons' trajectories (DESIGN.md §replay).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

_U32 = jnp.uint32
# splitmix32 constants (Steele et al., "Fast splittable PRNGs")
_GOLDEN = jnp.uint32(0x9E3779B9)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)
# odd multiplier folding the high id word into the chain (any odd
# constant is a bijection on uint32, so distinct high words can never
# cancel; 0 maps to 0, keeping sub-2**32 ids bit-identical to the
# legacy single-word seeding)
_HI_MULT = jnp.uint32(0x85EBCA77)


class PhotonId(NamedTuple):
    """A 64-bit global photon id as a two-word uint32 pair.

    ``lo``/``hi`` are arrays (or scalars) of identical shape; arithmetic
    on ids is done word-wise with explicit carries (see
    ``simulator._regenerate``).  Anywhere a photon id is accepted, a
    plain uint32 array is still allowed and means ``hi == 0``.
    """

    lo: jnp.ndarray
    hi: jnp.ndarray

    @property
    def shape(self):
        return jnp.shape(self.lo)


def as_photon_id(ids) -> PhotonId:
    """Coerce a plain uint32 id array (hi=0) or PhotonId to PhotonId."""
    if isinstance(ids, PhotonId):
        return ids
    lo = jnp.asarray(ids, _U32)
    return PhotonId(lo=lo, hi=jnp.zeros_like(lo))


def split_id64(start_id: int):
    """Split a host-side Python int id into (lo, hi) uint32 words.

    Returned as ``np.uint32`` scalars: jit canonicalizes bare Python
    ints to int32 *before* the traced function can widen them, so a
    plain int above 2**31 - 1 would overflow at the call boundary.
    """
    import numpy as np

    start_id = int(start_id)
    if start_id < 0 or start_id >= 1 << 64:
        raise ValueError(f"photon id out of uint64 range: {start_id}")
    return np.uint32(start_id & 0xFFFFFFFF), np.uint32(start_id >> 32)


def splitmix32(x: jnp.ndarray) -> jnp.ndarray:
    """One splitmix32 output step; ``x`` is the uint32 counter."""
    z = (x + _GOLDEN).astype(_U32)
    z = (z ^ (z >> 16)) * _MIX1
    z = (z ^ (z >> 13)) * _MIX2
    z = z ^ (z >> 16)
    return z.astype(_U32)


def seed_state(seed, photon_id) -> jnp.ndarray:
    """Derive a (..., 4) uint32 xorshift128 state from (seed, photon_id).

    ``photon_id`` is a plain uint32 array (legacy 32-bit ids) or a
    :class:`PhotonId` pair.  The high word perturbs every round of the
    splitmix chain, so two ids that differ in *either* word always
    yield distinct 128-bit states (the low word makes the bases
    distinct; for equal bases the high word makes each chain step
    distinct, and splitmix32 is a bijection).  ``hi == 0`` is
    bit-identical to the legacy single-word seeding.

    Zero states are fixed up (xorshift must never be seeded all-zero).
    """
    seed = jnp.asarray(seed, _U32)
    if isinstance(photon_id, PhotonId):
        pid = jnp.asarray(photon_id.lo, _U32)
        hmix = (jnp.asarray(photon_id.hi, _U32) * _HI_MULT).astype(_U32)
    else:
        pid = jnp.asarray(photon_id, _U32)
        hmix = jnp.uint32(0)
    base = (seed ^ (pid * jnp.uint32(0x9E3779B1))).astype(_U32)
    words = []
    x = base
    for k in range(4):
        x = splitmix32((x + jnp.uint32(k) * _GOLDEN + hmix).astype(_U32))
        words.append(x)
    state = jnp.stack(words, axis=-1)
    # guarantee non-zero state per lane
    allzero = jnp.all(state == 0, axis=-1, keepdims=True)
    return jnp.where(allzero, jnp.uint32(0xDEADBEEF), state)


def next_u32(state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Marsaglia xorshift128 step. state: (..., 4) uint32 -> (new_state, u32)."""
    x = state[..., 0]
    y = state[..., 1]
    z = state[..., 2]
    w = state[..., 3]
    t = x ^ (x << 11)
    t = t ^ (t >> 8)
    neww = (w ^ (w >> 19)) ^ t
    new_state = jnp.stack([y, z, w, neww], axis=-1)
    return new_state, neww


def next_uniform(state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Uniform in the open interval (0, 1) with 24-bit resolution.

    Uses the top 24 bits; result is (r + 0.5) * 2^-24 so it can never be
    exactly 0 or 1 — safe to feed into log() for free-path sampling.
    """
    state, bits = next_u32(state)
    r = (bits >> 8).astype(jnp.float32)  # [0, 2^24)
    u = (r + jnp.float32(0.5)) * jnp.float32(2.0**-24)
    return state, u
