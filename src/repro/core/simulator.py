"""Lock-step vectorized photon simulation engine.

Implements the paper's two thread-level workload strategies:

  * ``mode="dynamic"`` — the workgroup-level dynamic load balancing of
    the paper (Fig. 3a): all lanes draw photons from a shared remaining
    counter; a lane whose photon terminates immediately *regenerates* a
    new one.  On a GPU this needed a local-memory atomic counter; in the
    lock-step TPU/JAX formulation it is a masked prefix-sum over dead
    lanes — race-free by construction.
  * ``mode="static"`` — the thread-level baseline: every lane is
    pre-assigned ``n_photons / n_lanes`` photons and idles once its
    quota is done (the divergence-waste case the paper measures).

The outer loop is organized in **fused rounds** of
``K = cfg.steps_per_round`` transport segments (DESIGN.md §rounds):
regeneration runs once per round and the global fluence / exitance /
escape accumulators are flushed once per round, amortizing the
bookkeeping the paper amortizes by keeping its OpenCL kernel resident
over many steps.  The round executor is pluggable:
``engine="jnp"`` runs the segments in an in-graph ``fori_loop``;
``engine="pallas"`` dispatches the Pallas photon-step kernel
(repro.kernels.photon_step), which accumulates all three quantities
in-kernel.  Trajectories and RNG streams are bit-identical across K and
engines (DESIGN.md §determinism); only fp accumulation order differs,
and K=1 with the jnp engine reproduces the unfused engine exactly.

The engine is shape-polymorphic in the photon count (traced int32), so
pilot runs for the device-level load balancer (loadbalance.py) reuse the
same compiled executable.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photon as ph
from repro.core.volume import SimConfig, Source, Volume
from repro.detectors import (Detector, accumulate_capture, as_detectors,
                             det_geometry)
from repro.sources import PhotonSource, as_source

ENGINES = ("jnp", "pallas")


class SimResult(NamedTuple):
    energy: jnp.ndarray     # (nx, ny, nz) float32 deposited energy for the
    #                          CW case (cfg.n_time_gates == 1), else
    #                          (nx, ny, nz, ntg) binned over time gates
    exitance: jnp.ndarray   # (nx, ny) float32 weight escaping the z=0 face
    escaped_w: jnp.ndarray  # () float32 total escaped weight
    n_launched: jnp.ndarray  # () int32 photons actually launched
    launched_w: jnp.ndarray  # () float32 total initial weight launched
    #                          (== n_launched for unit-weight sources; differs
    #                          for weighted launches, e.g. Planar patterns)
    steps: jnp.ndarray      # () int32 lock-step iterations executed
    # -- accounting / detector fields (defaulted so legacy constructors,
    #    e.g. the verbatim seed-engine copy in tests, keep working; the
    #    defaults are numpy, not jnp, so importing this module does not
    #    initialize the JAX backend as a side effect) --
    timed_out_w: jnp.ndarray = np.float32(0.0)  # () weight retired by the
    #                          tmax_ns gate or the max_steps cap —
    #                          deterministic loss, excluded from the
    #                          roulette residue (analysis.energy_balance)
    det_w: jnp.ndarray = np.zeros((0, 1), np.float32)  # (n_det, ntg)
    #                          detected-weight TPSF histogram per detector
    det_ppath: jnp.ndarray = np.zeros((0, 0), np.float32)  # (n_det,
    #                          n_media) weight-weighted partial pathlength
    #                          sums (mm) of detected photons


class _Carry(NamedTuple):
    state: ph.PhotonState
    energy: jnp.ndarray      # (nvox * ntg,) flat gate-major deposited energy
    exitance: jnp.ndarray    # (nx*ny,) flat z=0-face exitance image
    escaped_w: jnp.ndarray
    timed_out_w: jnp.ndarray  # weight retired by the tmax_ns gate so far
    ppath: jnp.ndarray       # (n_lanes, n_media) per-medium partial path-
    #                          lengths (mm) of the in-flight photon; width 0
    #                          when no detectors are configured
    det_w: jnp.ndarray       # (n_det * ntg,) flat detected-weight TPSF
    det_ppath: jnp.ndarray   # (n_det, n_media) detected ppath sums
    remaining: jnp.ndarray   # dynamic mode: shared photon counter
    launched_per_lane: jnp.ndarray  # static mode: per-lane launch count
    next_id: jnp.ndarray     # global photon id counter (RNG seeding)
    launched_w: jnp.ndarray  # total initial weight launched so far
    steps: jnp.ndarray


def _regenerate(state, remaining, launched_per_lane, next_id, quota,
                source, seed, mode, shape, ppath=None):
    """Relaunch photons in dead lanes according to the workload mode.

    ``ppath`` (detector runs only) is the per-lane partial-pathlength
    accumulator; relaunched lanes start their new photon with zeroed
    pathlengths.  It is threaded through (and returned as a trailing
    element) only when given, so detector-free engines keep the
    historical 5-tuple contract.
    """
    dead = ~state.alive
    if mode == "dynamic":
        order = jnp.cumsum(dead.astype(jnp.int32))  # 1-based rank among dead
        relaunch = dead & (order <= remaining)
    else:  # static pre-assigned quota per lane
        relaunch = dead & (launched_per_lane < quota)
    n_relaunch = jnp.sum(relaunch.astype(jnp.int32))
    rank = jnp.cumsum(relaunch.astype(jnp.int32)) - 1  # 0-based among relaunched
    ids = (next_id + rank).astype(jnp.uint32)
    pos, direc, w0, rng = source.sample(ids, seed)
    fresh = ph.launch(pos, direc, w0, rng, relaunch, shape)

    def merge(new, old):
        mask = relaunch
        if new.ndim > 1:
            mask = relaunch[:, None]
        return jnp.where(mask, new, old)

    merged = ph.PhotonState(*(merge(n, o) for n, o in zip(fresh, state)))
    merged = merged._replace(alive=state.alive | relaunch)
    out = (
        merged,
        remaining - n_relaunch,
        launched_per_lane + relaunch.astype(jnp.int32),
        next_id + n_relaunch,
        jnp.sum(jnp.where(relaunch, w0, 0.0)),
    )
    if ppath is not None:
        out = out + (jnp.where(relaunch[:, None], 0.0, ppath),)
    return out


def _maybe_regenerate(state, remaining, launched_per_lane, next_id, quota,
                      source, seed, mode, shape, ppath=None):
    """Regenerate only when some lane will actually relaunch.

    The full regeneration path costs two prefix-sums plus a
    ``source.sample`` over *all* lanes; rounds in which every lane is
    still in flight (the common case for K>1 between termination
    bursts) skip it entirely via ``lax.cond``.  The predicates are
    exact: in dynamic mode the first dead lane has rank 1 <= remaining,
    so ``any(dead) & (remaining > 0)`` relaunches at least one photon;
    in static mode the mask is the relaunch mask itself.  Skipping is
    bit-identical to running ``_regenerate`` with an all-False mask.
    """
    dead = ~state.alive
    if mode == "dynamic":
        any_relaunch = jnp.any(dead) & (remaining > 0)
    else:
        any_relaunch = jnp.any(dead & (launched_per_lane < quota))

    def do(_):
        return _regenerate(state, remaining, launched_per_lane, next_id,
                           quota, source, seed, mode, shape, ppath)

    def skip(_):
        out = (state, remaining, launched_per_lane, next_id,
               jnp.float32(0.0))
        if ppath is not None:
            out = out + (ppath,)
        return out

    return jax.lax.cond(any_relaunch, do, skip, None)


def build_sim_fn(shape: tuple[int, int, int], unitinmm: float,
                 cfg: SimConfig, n_lanes: int, mode: str = "dynamic",
                 source: PhotonSource | None = None,
                 engine: str = "jnp", block_lanes: int = 256,
                 interpret: bool | None = None,
                 detectors: tuple[Detector, ...] | None = None):
    """Build the raw (unjitted) simulation function.

    Returns ``sim_fn(labels_flat, media, n_photons, seed, id_offset=0)
    -> SimResult``; ``n_photons``, ``seed`` and ``id_offset`` are
    traced, so one executable serves pilot runs and production runs.
    ``source`` is any registered photon source (repro.sources; pencil
    beam by default) and is baked in at trace time — its parameters are
    static, its randomness counter-seeded per photon id.  ``id_offset``
    gives this shard a disjoint global photon-id range — the
    counter-based RNG (both the source's launch stream and the in-flight
    stream) then makes multi-device / elastic / restarted runs simulate
    *exactly* the same photon set as a single-device run
    (DESIGN.md §determinism, §sources).

    ``engine`` selects the round executor (DESIGN.md §rounds):
    ``"jnp"`` advances ``cfg.steps_per_round`` segments in an in-graph
    ``fori_loop`` and flushes batched deposition/exitance scatters once
    per round; ``"pallas"`` dispatches the Pallas photon-step kernel
    per round (``block_lanes`` lanes per grid step; ``interpret=None``
    auto-detects the backend).  Both engines simulate bit-identical
    trajectories; accumulated grids agree to fp-accumulation order.

    ``cfg.n_time_gates`` widens the energy accumulator to a gate-major
    flat ``(nvox * ntg,)`` grid (DESIGN.md §time-resolved); the gate
    index is computed at deposit time from the photon's time-of-flight.
    ``detectors`` (repro.detectors) enables TPSF recording: escapes
    through the z=0 face inside a detector disk are histogrammed per
    (detector, time gate), with weight-weighted per-medium partial
    pathlengths tracked per lane.  Both are static trace-time config;
    the default (CW, no detectors) is bit-identical to the ungated
    engine.

    The raw function is shard_map-composable; ``make_simulator`` wraps
    it in jit for single-device use.
    """
    if mode not in ("dynamic", "static"):
        raise ValueError(f"unknown workload mode: {mode}")
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (choose from {ENGINES})")
    source = as_source(source)
    detectors = as_detectors(detectors)
    n_det = len(detectors)
    det_geom = det_geometry(detectors) if n_det else None
    nx, ny, nz = shape
    nvox = nx * ny * nz
    nxy = nx * ny
    K = int(cfg.steps_per_round)
    if K < 1:
        raise ValueError(f"cfg.steps_per_round must be >= 1, got {K}")
    ntg = int(cfg.n_time_gates)
    if ntg < 1:
        raise ValueError(f"cfg.n_time_gates must be >= 1, got {ntg}")
    if engine == "pallas":
        from repro.kernels.photon_step.photon_step import (default_interpret,
                                                           photon_step_pallas)

        # the kernel grid needs block_lanes | n_lanes; fall back to the
        # largest divisor <= the requested block so any lane count works
        # through the public APIs (schedulers don't expose block_lanes)
        requested = block_lanes = min(block_lanes, n_lanes)
        while n_lanes % block_lanes:
            block_lanes -= 1
        if block_lanes < requested:
            warnings.warn(
                f"n_lanes={n_lanes} is not divisible by "
                f"block_lanes={requested}; falling back to "
                f"block_lanes={block_lanes} — small blocks serialize the "
                f"Pallas grid (prefer a lane count with a divisor near "
                f"{requested})", stacklevel=2)
        if interpret is None:
            interpret = default_interpret()

    def sim_fn(labels_flat, media, n_photons, seed, id_offset=0):
        n_photons = jnp.asarray(n_photons, jnp.int32)
        seed = jnp.asarray(seed, jnp.uint32)
        id_offset = jnp.asarray(id_offset, jnp.int32)
        # static mode: equal distribution with the remainder spread over the
        # first (n_photons mod n_lanes) lanes, so exactly n_photons launch
        lane_idx = jnp.arange(n_lanes, dtype=jnp.int32)
        quota = n_photons // n_lanes + (lane_idx < n_photons % n_lanes)
        n_media = media.shape[0]
        # partial pathlengths are only tracked when a detector can consume
        # them; width-0 otherwise so the carry structure stays fixed
        ppath_w = n_media if n_det else 0

        state0 = ph.PhotonState(
            pos=jnp.zeros((n_lanes, 3), jnp.float32),
            dir=jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32), (n_lanes, 1)),
            ivox=jnp.zeros((n_lanes, 3), jnp.int32),
            w=jnp.zeros((n_lanes,), jnp.float32),
            s_left=jnp.zeros((n_lanes,), jnp.float32),
            t=jnp.zeros((n_lanes,), jnp.float32),
            rng=jnp.zeros((n_lanes, 4), jnp.uint32),
            alive=jnp.zeros((n_lanes,), bool),
        )
        carry0 = _Carry(
            state=state0,
            energy=jnp.zeros((nvox * ntg,), jnp.float32),
            exitance=jnp.zeros((nxy,), jnp.float32),
            escaped_w=jnp.float32(0.0),
            timed_out_w=jnp.float32(0.0),
            ppath=jnp.zeros((n_lanes, ppath_w), jnp.float32),
            det_w=jnp.zeros((n_det * ntg,), jnp.float32),
            det_ppath=jnp.zeros((n_det, n_media), jnp.float32),
            remaining=n_photons,
            launched_per_lane=jnp.zeros((n_lanes,), jnp.int32),
            next_id=id_offset,
            launched_w=jnp.float32(0.0),
            steps=jnp.int32(0),
        )

        def cond(c: _Carry):
            has_work = jnp.any(c.state.alive)
            if mode == "dynamic":
                has_work = has_work | (c.remaining > 0)
            else:
                has_work = has_work | jnp.any(c.launched_per_lane < quota)
            return has_work & (c.steps < cfg.max_steps)

        def round_jnp(state, ppath):
            """Advance K segments in-graph; returns the new state plus
            round-local (K, n_lanes) deposition/exitance buffers (the
            deposition index is gate-major: voxel * ntg + gate) and the
            round's escaped / timed-out weights — flushed by the caller
            in ONE scatter per grid instead of one per segment.
            Detector capture scatters into round-local (n_det * ntg,)
            and (n_det, n_media) accumulators per segment (they are
            tiny, unlike the fluence volume)."""
            def seg(k, rc):
                st, pp, dep_i, dep_w, ex_i, ex_w, esc, timed, dw, dp = rc
                res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
                gate = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
                dep_i = dep_i.at[k].set(res.dep_idx * ntg + gate)
                dep_w = dep_w.at[k].set(res.dep_w)
                xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
                ex_i = ex_i.at[k].set(xy)
                ex_w = ex_w.at[k].set(xw)
                esc = esc + jnp.sum(res.esc_w)
                timed = timed + jnp.sum(res.timed_w)
                if n_det:
                    pp, dw, dp = accumulate_capture(pp, dw, dp, res, gate,
                                                    det_geom, ntg)
                return (res.state, pp, dep_i, dep_w, ex_i, ex_w, esc,
                        timed, dw, dp)

            init = (
                state,
                ppath,
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.float32(0.0),
                jnp.float32(0.0),
                jnp.zeros((n_det * ntg,), jnp.float32),
                jnp.zeros((n_det, n_media), jnp.float32),
            )
            return jax.lax.fori_loop(0, K, seg, init)

        def body(c: _Carry):
            if n_det:
                (state, remaining, launched, next_id, w_new,
                 ppath) = _maybe_regenerate(
                    c.state, c.remaining, c.launched_per_lane, c.next_id,
                    quota, source, seed, mode, shape, c.ppath)
            else:
                state, remaining, launched, next_id, w_new = _maybe_regenerate(
                    c.state, c.remaining, c.launched_per_lane, c.next_id,
                    quota, source, seed, mode, shape)
                ppath = c.ppath
            if engine == "pallas":
                outs = photon_step_pallas(
                    labels_flat, media, state, shape, unitinmm, cfg, K,
                    block_lanes, interpret,
                    ppath=ppath if n_det else None, det_geom=det_geom)
                state, flu, exi, esc, timed = outs[:5]
                energy = c.energy + flu
                exitance = c.exitance + exi
                escaped_w = c.escaped_w + jnp.sum(esc)
                timed_out_w = c.timed_out_w + jnp.sum(timed)
                if n_det:
                    ppath, dw, dp = outs[5:]
                    det_w = c.det_w + dw
                    det_ppath = c.det_ppath + dp
                else:
                    det_w, det_ppath = c.det_w, c.det_ppath
            else:
                (state, ppath, dep_i, dep_w, ex_i, ex_w, esc, timed,
                 dw, dp) = round_jnp(state, ppath)
                energy = c.energy.at[dep_i.reshape(-1)].add(dep_w.reshape(-1))
                exitance = c.exitance.at[ex_i.reshape(-1)].add(
                    ex_w.reshape(-1))
                escaped_w = c.escaped_w + esc
                timed_out_w = c.timed_out_w + timed
                det_w = c.det_w + dw
                det_ppath = c.det_ppath + dp
            return _Carry(
                state=state,
                energy=energy,
                exitance=exitance,
                escaped_w=escaped_w,
                timed_out_w=timed_out_w,
                ppath=ppath,
                det_w=det_w,
                det_ppath=det_ppath,
                remaining=remaining,
                launched_per_lane=launched,
                next_id=next_id,
                launched_w=c.launched_w + w_new,
                steps=c.steps + K,
            )

        final = jax.lax.while_loop(cond, body, carry0)
        # weight still in flight when the max_steps cap fires is retired
        # deterministically, like the time gate — account it there so the
        # energy-balance residue only measures roulette statistics
        capped_w = jnp.sum(jnp.where(final.state.alive, final.state.w, 0.0))
        energy = final.energy
        energy = (energy.reshape(shape + (ntg,)) if ntg > 1
                  else energy.reshape(shape))
        return SimResult(
            energy=energy,
            exitance=final.exitance.reshape((nx, ny)),
            escaped_w=final.escaped_w,
            timed_out_w=final.timed_out_w + capped_w,
            det_w=final.det_w.reshape((n_det, ntg)),
            det_ppath=final.det_ppath,
            n_launched=final.next_id - id_offset,
            launched_w=final.launched_w,
            steps=final.steps,
        )

    return sim_fn


def make_simulator(volume: Volume, cfg: SimConfig, n_lanes: int,
                   mode: str = "dynamic",
                   source: PhotonSource | Source | None = None,
                   engine: str = "jnp", block_lanes: int = 256,
                   interpret: bool | None = None,
                   detectors=None):
    """Jitted single-device simulator for a fixed (volume, cfg, lanes,
    source, engine, detectors)."""
    raw = build_sim_fn(volume.shape, volume.unitinmm, cfg, n_lanes, mode,
                       source, engine, block_lanes, interpret, detectors)
    return jax.jit(raw)


def simulate(volume: Volume, cfg: SimConfig, n_photons: int,
             n_lanes: int = 4096, seed: int = 1234,
             source: PhotonSource | Source | None = None,
             mode: str = "dynamic", engine: str = "jnp",
             block_lanes: int = 256,
             interpret: bool | None = None,
             detectors=None) -> SimResult:
    """Convenience one-shot simulation on the current default device.

    ``source`` accepts any registered source type (repro.sources), the
    legacy pencil :class:`Source`, or a ``sources.to_dict``-style config
    dict; ``None`` is the paper's pencil beam.  ``engine`` selects the
    round executor (``"jnp"`` | ``"pallas"``, DESIGN.md §rounds);
    ``block_lanes`` / ``interpret`` tune the Pallas executor only.
    ``detectors`` (repro.detectors spec) enables TPSF recording on the
    z=0 face (DESIGN.md §time-resolved).
    """
    sim_fn = make_simulator(volume, cfg, n_lanes, mode, source, engine,
                            block_lanes, interpret, detectors)
    return sim_fn(
        volume.labels.reshape(-1),
        volume.media,
        n_photons,
        seed,
    )


# ---------------------------------------------------------------------------
# Opt2: (lane count x steps-per-round) autotuning
# ---------------------------------------------------------------------------

def autotune_rounds(volume: Volume, cfg: SimConfig, n_pilot: int = 20_000,
                    lane_candidates=(1024, 2048, 4096, 8192, 16384),
                    round_candidates=(1, 4, 8, 16, 32),
                    seed: int = 7,
                    source: PhotonSource | Source | None = None,
                    repeats: int = 2, mode: str = "dynamic",
                    engine: str = "jnp",
                    ) -> tuple[tuple[int, int], dict[tuple[int, int], float]]:
    """2-D pilot sweep over (n_lanes, steps_per_round).

    The paper's Opt2 computes the balanced thread number from hardware
    occupancy; lacking introspectable occupancy on this runtime, we
    measure it — and the fused-round depth K trades regeneration /
    flush amortization against masked-lane waste (DESIGN.md §rounds),
    so the two knobs are tuned jointly.  Returns
    ``((best_lanes, best_k), timings_s)`` with timings keyed by
    ``(lanes, k)``.
    """
    labels_flat = volume.labels.reshape(-1)
    timings: dict[tuple[int, int], float] = {}
    for lanes in lane_candidates:
        for k in round_candidates:
            kcfg = dataclasses.replace(cfg, steps_per_round=int(k))
            sim_fn = make_simulator(volume, kcfg, lanes, mode, source, engine)
            args = (labels_flat, volume.media, n_pilot, seed)
            jax.block_until_ready(sim_fn(*args))  # compile + warm up
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(sim_fn(*args))
                best = min(best, time.perf_counter() - t0)
            timings[(lanes, k)] = best
    best_cfg = min(timings, key=timings.get)
    return best_cfg, timings


def autotune_lanes(volume: Volume, cfg: SimConfig, n_pilot: int = 20_000,
                   candidates=(1024, 2048, 4096, 8192, 16384),
                   seed: int = 7,
                   source: PhotonSource | Source | None = None,
                   repeats: int = 2, mode: str = "dynamic",
                   engine: str = "jnp") -> tuple[int, dict[int, float]]:
    """Pick the lane count with the highest pilot throughput.

    1-D slice of :func:`autotune_rounds` at the config's own
    ``steps_per_round`` — kept as the paper's original Opt2 interface.
    Tune with the same ``engine`` the production run will use: the
    throughput-vs-lane-count curve differs between executors.
    Returns (best_lane_count, timings_s).
    """
    (best_lanes, _), timings = autotune_rounds(
        volume, cfg, n_pilot, candidates,
        round_candidates=(int(cfg.steps_per_round),),
        seed=seed, source=source, repeats=repeats, mode=mode, engine=engine)
    return best_lanes, {lanes: t for (lanes, _), t in timings.items()}
