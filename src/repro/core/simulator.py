"""Lock-step vectorized photon simulation engine.

Implements the paper's two thread-level workload strategies:

  * ``mode="dynamic"`` — the workgroup-level dynamic load balancing of
    the paper (Fig. 3a): all lanes draw photons from a shared remaining
    counter; a lane whose photon terminates immediately *regenerates* a
    new one.  On a GPU this needed a local-memory atomic counter; in the
    lock-step TPU/JAX formulation it is a masked prefix-sum over dead
    lanes — race-free by construction.
  * ``mode="static"`` — the thread-level baseline: every lane is
    pre-assigned ``n_photons / n_lanes`` photons and idles once its
    quota is done (the divergence-waste case the paper measures).

The engine is shape-polymorphic in the photon count (traced int32), so
pilot runs for the device-level load balancer (loadbalance.py) reuse the
same compiled executable.
"""

from __future__ import annotations

import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import photon as ph
from repro.core.volume import SimConfig, Source, Volume
from repro.sources import PhotonSource, as_source


class SimResult(NamedTuple):
    energy: jnp.ndarray     # (nx, ny, nz) float32 deposited energy
    exitance: jnp.ndarray   # (nx, ny) float32 weight escaping the z=0 face
    escaped_w: jnp.ndarray  # () float32 total escaped weight
    n_launched: jnp.ndarray  # () int32 photons actually launched
    launched_w: jnp.ndarray  # () float32 total initial weight launched
    #                          (== n_launched for unit-weight sources; differs
    #                          for weighted launches, e.g. Planar patterns)
    steps: jnp.ndarray      # () int32 lock-step iterations executed


class _Carry(NamedTuple):
    state: ph.PhotonState
    energy: jnp.ndarray
    exitance: jnp.ndarray
    escaped_w: jnp.ndarray
    remaining: jnp.ndarray   # dynamic mode: shared photon counter
    launched_per_lane: jnp.ndarray  # static mode: per-lane launch count
    next_id: jnp.ndarray     # global photon id counter (RNG seeding)
    launched_w: jnp.ndarray  # total initial weight launched so far
    steps: jnp.ndarray


def _regenerate(state, remaining, launched_per_lane, next_id, quota,
                source, seed, mode, shape):
    """Relaunch photons in dead lanes according to the workload mode."""
    dead = ~state.alive
    if mode == "dynamic":
        order = jnp.cumsum(dead.astype(jnp.int32))  # 1-based rank among dead
        relaunch = dead & (order <= remaining)
    else:  # static pre-assigned quota per lane
        relaunch = dead & (launched_per_lane < quota)
    n_relaunch = jnp.sum(relaunch.astype(jnp.int32))
    rank = jnp.cumsum(relaunch.astype(jnp.int32)) - 1  # 0-based among relaunched
    ids = (next_id + rank).astype(jnp.uint32)
    pos, direc, w0, rng = source.sample(ids, seed)
    fresh = ph.launch(pos, direc, w0, rng, relaunch, shape)

    def merge(new, old):
        mask = relaunch
        if new.ndim > 1:
            mask = relaunch[:, None]
        return jnp.where(mask, new, old)

    merged = ph.PhotonState(*(merge(n, o) for n, o in zip(fresh, state)))
    merged = merged._replace(alive=state.alive | relaunch)
    return (
        merged,
        remaining - n_relaunch,
        launched_per_lane + relaunch.astype(jnp.int32),
        next_id + n_relaunch,
        jnp.sum(jnp.where(relaunch, w0, 0.0)),
    )


def build_sim_fn(shape: tuple[int, int, int], unitinmm: float,
                 cfg: SimConfig, n_lanes: int, mode: str = "dynamic",
                 source: PhotonSource | None = None):
    """Build the raw (unjitted) simulation function.

    Returns ``sim_fn(labels_flat, media, n_photons, seed, id_offset=0)
    -> SimResult``; ``n_photons``, ``seed`` and ``id_offset`` are
    traced, so one executable serves pilot runs and production runs.
    ``source`` is any registered photon source (repro.sources; pencil
    beam by default) and is baked in at trace time — its parameters are
    static, its randomness counter-seeded per photon id.  ``id_offset``
    gives this shard a disjoint global photon-id range — the
    counter-based RNG (both the source's launch stream and the in-flight
    stream) then makes multi-device / elastic / restarted runs simulate
    *exactly* the same photon set as a single-device run
    (DESIGN.md §determinism, §sources).

    The raw function is shard_map-composable; ``make_simulator`` wraps
    it in jit for single-device use.
    """
    if mode not in ("dynamic", "static"):
        raise ValueError(f"unknown workload mode: {mode}")
    source = as_source(source)
    nx, ny, nz = shape
    nvox = nx * ny * nz

    def sim_fn(labels_flat, media, n_photons, seed, id_offset=0):
        n_photons = jnp.asarray(n_photons, jnp.int32)
        seed = jnp.asarray(seed, jnp.uint32)
        id_offset = jnp.asarray(id_offset, jnp.int32)
        # static mode: equal distribution with the remainder spread over the
        # first (n_photons mod n_lanes) lanes, so exactly n_photons launch
        lane_idx = jnp.arange(n_lanes, dtype=jnp.int32)
        quota = n_photons // n_lanes + (lane_idx < n_photons % n_lanes)

        state0 = ph.PhotonState(
            pos=jnp.zeros((n_lanes, 3), jnp.float32),
            dir=jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32), (n_lanes, 1)),
            ivox=jnp.zeros((n_lanes, 3), jnp.int32),
            w=jnp.zeros((n_lanes,), jnp.float32),
            s_left=jnp.zeros((n_lanes,), jnp.float32),
            t=jnp.zeros((n_lanes,), jnp.float32),
            rng=jnp.zeros((n_lanes, 4), jnp.uint32),
            alive=jnp.zeros((n_lanes,), bool),
        )
        carry0 = _Carry(
            state=state0,
            energy=jnp.zeros((nvox,), jnp.float32),
            exitance=jnp.zeros((nx, ny), jnp.float32),
            escaped_w=jnp.float32(0.0),
            remaining=n_photons,
            launched_per_lane=jnp.zeros((n_lanes,), jnp.int32),
            next_id=id_offset,
            launched_w=jnp.float32(0.0),
            steps=jnp.int32(0),
        )

        def cond(c: _Carry):
            has_work = jnp.any(c.state.alive)
            if mode == "dynamic":
                has_work = has_work | (c.remaining > 0)
            else:
                has_work = has_work | jnp.any(c.launched_per_lane < quota)
            return has_work & (c.steps < cfg.max_steps)

        def body(c: _Carry):
            state, remaining, launched, next_id, w_new = _regenerate(
                c.state, c.remaining, c.launched_per_lane, c.next_id,
                quota, source, seed, mode, shape,
            )
            res = ph.step(state, labels_flat, media, shape, unitinmm, cfg)
            energy = c.energy.at[res.dep_idx].add(res.dep_w)
            escaped_w = c.escaped_w + jnp.sum(res.esc_w)
            # bin exits through the z=0 face into the exitance image
            z_exit = res.esc_pos[:, 2] < ph.Z_EXIT_FACE_VOX
            hit = (res.esc_w > 0) & z_exit
            ex = jnp.clip(jnp.floor(res.esc_pos[:, 0]).astype(jnp.int32), 0, nx - 1)
            ey = jnp.clip(jnp.floor(res.esc_pos[:, 1]).astype(jnp.int32), 0, ny - 1)
            exitance = c.exitance.at[ex, ey].add(
                jnp.where(hit, res.esc_w, 0.0)
            )
            return _Carry(
                state=res.state,
                energy=energy,
                exitance=exitance,
                escaped_w=escaped_w,
                remaining=remaining,
                launched_per_lane=launched,
                next_id=next_id,
                launched_w=c.launched_w + w_new,
                steps=c.steps + 1,
            )

        final = jax.lax.while_loop(cond, body, carry0)
        return SimResult(
            energy=final.energy.reshape(shape),
            exitance=final.exitance,
            escaped_w=final.escaped_w,
            n_launched=final.next_id - id_offset,
            launched_w=final.launched_w,
            steps=final.steps,
        )

    return sim_fn


def make_simulator(volume: Volume, cfg: SimConfig, n_lanes: int,
                   mode: str = "dynamic",
                   source: PhotonSource | Source | None = None):
    """Jitted single-device simulator for a fixed (volume, cfg, lanes,
    source)."""
    raw = build_sim_fn(volume.shape, volume.unitinmm, cfg, n_lanes, mode,
                       source)
    return jax.jit(raw)


def simulate(volume: Volume, cfg: SimConfig, n_photons: int,
             n_lanes: int = 4096, seed: int = 1234,
             source: PhotonSource | Source | None = None,
             mode: str = "dynamic") -> SimResult:
    """Convenience one-shot simulation on the current default device.

    ``source`` accepts any registered source type (repro.sources), the
    legacy pencil :class:`Source`, or a ``sources.to_dict``-style config
    dict; ``None`` is the paper's pencil beam.
    """
    sim_fn = make_simulator(volume, cfg, n_lanes, mode, source)
    return sim_fn(
        volume.labels.reshape(-1),
        volume.media,
        n_photons,
        seed,
    )


# ---------------------------------------------------------------------------
# Opt2: lane-count autotuning (the paper's "balanced thread number")
# ---------------------------------------------------------------------------

def autotune_lanes(volume: Volume, cfg: SimConfig, n_pilot: int = 20_000,
                   candidates=(1024, 2048, 4096, 8192, 16384),
                   seed: int = 7,
                   source: PhotonSource | Source | None = None,
                   repeats: int = 2) -> tuple[int, dict[int, float]]:
    """Pick the lane count with the highest pilot throughput.

    The paper computes the balanced thread number from hardware occupancy
    (registers x compute units); lacking introspectable occupancy on this
    runtime, we measure it — a pilot sweep, exactly how the device-level
    balancer estimates throughput.  Returns (best_lane_count, timings_s).
    """
    labels_flat = volume.labels.reshape(-1)
    timings: dict[int, float] = {}
    for lanes in candidates:
        sim_fn = make_simulator(volume, cfg, lanes, "dynamic", source)
        args = (labels_flat, volume.media, n_pilot, seed)
        jax.block_until_ready(sim_fn(*args))  # compile + warm up
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(sim_fn(*args))
            best = min(best, time.perf_counter() - t0)
        timings[lanes] = best
    best_lanes = min(timings, key=timings.get)
    return best_lanes, timings
