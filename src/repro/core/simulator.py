"""Lock-step vectorized photon simulation engine.

Implements the paper's two thread-level workload strategies:

  * ``mode="dynamic"`` — the workgroup-level dynamic load balancing of
    the paper (Fig. 3a): all lanes draw photons from a shared remaining
    counter; a lane whose photon terminates immediately *regenerates* a
    new one.  On a GPU this needed a local-memory atomic counter; in the
    lock-step TPU/JAX formulation it is a masked prefix-sum over dead
    lanes — race-free by construction.
  * ``mode="static"`` — the thread-level baseline: every lane is
    pre-assigned ``n_photons / n_lanes`` photons and idles once its
    quota is done (the divergence-waste case the paper measures).

The outer loop is organized in **fused rounds** of
``K = cfg.steps_per_round`` transport segments (DESIGN.md §rounds):
regeneration runs once per round and the global fluence / exitance /
escape accumulators are flushed once per round, amortizing the
bookkeeping the paper amortizes by keeping its OpenCL kernel resident
over many steps.  The round executor is pluggable:
``engine="jnp"`` runs the segments in an in-graph ``fori_loop``;
``engine="pallas"`` dispatches the Pallas photon-step kernel
(repro.kernels.photon_step), which accumulates all three quantities
in-kernel.  Trajectories and RNG streams are bit-identical across K and
engines (DESIGN.md §determinism); only fp accumulation order differs,
and K=1 with the jnp engine reproduces the unfused engine exactly.

The engine is shape-polymorphic in the photon count (traced int32), so
pilot runs for the device-level load balancer (loadbalance.py) reuse the
same compiled executable.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photon as ph
from repro.core import rng as xrng
from repro.core.volume import SimConfig, Source, Volume
from repro.detectors import (Detector, accumulate_capture, as_detectors,
                             det_geometry, update_capture,
                             validate_detectors)
from repro.sources import PhotonSource, as_source
from repro.telemetry.stats import RoundStats

ENGINES = ("jnp", "pallas")


class SimResult(NamedTuple):
    energy: jnp.ndarray     # (nx, ny, nz) float32 deposited energy for the
    #                          CW case (cfg.n_time_gates == 1), else
    #                          (nx, ny, nz, ntg) binned over time gates
    exitance: jnp.ndarray   # (nx, ny) float32 weight escaping the z=0 face
    escaped_w: jnp.ndarray  # () float32 total escaped weight
    n_launched: jnp.ndarray  # () int32 photons actually launched
    launched_w: jnp.ndarray  # () float32 total initial weight launched
    #                          (== n_launched for unit-weight sources; differs
    #                          for weighted launches, e.g. Planar patterns)
    steps: jnp.ndarray      # () int32 lock-step iterations executed
    # -- accounting / detector fields (defaulted so legacy constructors,
    #    e.g. the verbatim seed-engine copy in tests, keep working; the
    #    defaults are numpy, not jnp, so importing this module does not
    #    initialize the JAX backend as a side effect) --
    timed_out_w: jnp.ndarray = np.float32(0.0)  # () weight retired by the
    #                          tmax_ns gate or the max_steps cap —
    #                          deterministic loss, excluded from the
    #                          roulette residue (analysis.energy_balance)
    det_w: jnp.ndarray = np.zeros((0, 1), np.float32)  # (n_det, ntg)
    #                          detected-weight TPSF histogram per detector
    det_ppath: jnp.ndarray = np.zeros((0, 0), np.float32)  # (n_det,
    #                          n_media) weight-weighted partial pathlength
    #                          sums (mm) of detected photons
    # -- detected-photon id records (DESIGN.md §replay; populated when
    #    build_sim_fn(record_detected=capacity) is set) --
    det_rec: jnp.ndarray = np.zeros((0, 4), np.uint32)  # (capacity, 4)
    #                          rows of [id_lo, id_hi, det, gate]: the
    #                          64-bit global photon id (two uint32
    #                          words), detector index and exit time gate
    #                          of each capture, in capture order.  Only
    #                          the first det_rec_n rows are valid.
    det_rec_n: jnp.ndarray = np.int32(0)  # () valid record count
    det_rec_overflow: jnp.ndarray = np.int32(0)  # () captures dropped
    #                          once the buffer filled (det_w still
    #                          counts them; only the id record is lost)
    stats: RoundStats | None = None  # round-level telemetry counters
    #                          (telemetry.RoundStats) when
    #                          cfg.collect_stats is set; None otherwise
    #                          (an empty pytree node, so jit/shard_map
    #                          signatures stay stable either way)


class _Carry(NamedTuple):
    state: ph.PhotonState
    energy: jnp.ndarray      # (nvox * ntg,) flat gate-major deposited energy
    exitance: jnp.ndarray    # (nx*ny,) flat z=0-face exitance image
    escaped_w: jnp.ndarray
    timed_out_w: jnp.ndarray  # weight retired by the tmax_ns gate so far
    ppath: jnp.ndarray       # (n_lanes, n_media) per-medium partial path-
    #                          lengths (mm) of the in-flight photon; width 0
    #                          when no detectors are configured
    det_w: jnp.ndarray       # (n_det * ntg,) flat detected-weight TPSF
    det_ppath: jnp.ndarray   # (n_det, n_media) detected ppath sums
    rec: jnp.ndarray         # (capacity + 1, 4) uint32 detected-photon id
    #                          records [id_lo, id_hi, det, gate]; the last
    #                          row is a write-off slot for masked /
    #                          overflowing scatters ((0, 4) when recording
    #                          is off)
    rec_n: jnp.ndarray       # () int32 record cursor
    rec_overflow: jnp.ndarray  # () int32 captures dropped at capacity
    lane_ids: jnp.ndarray    # (n_lanes, 2) uint32 [lo, hi] global photon
    #                          id of each lane's in-flight photon ((0, 2)
    #                          when recording is off)
    remaining: jnp.ndarray   # dynamic mode: shared photon counter
    launched_per_lane: jnp.ndarray  # static mode: per-lane launch count
    next_id_lo: jnp.ndarray  # global 64-bit photon id counter (RNG
    next_id_hi: jnp.ndarray  #   seeding), as a uint32 (lo, hi) pair
    launched_w: jnp.ndarray  # total initial weight launched so far
    steps: jnp.ndarray
    stats: tuple | RoundStats = ()  # RoundStats of jnp scalars when
    #                          cfg.collect_stats, else () — an empty
    #                          pytree, so the loop structure is
    #                          identical with collection off


def _as_id_pair(next_id):
    """Coerce a legacy scalar id counter to a (lo, hi) uint32 pair."""
    if isinstance(next_id, tuple):
        lo, hi = next_id
        return jnp.asarray(lo).astype(jnp.uint32), \
            jnp.asarray(hi).astype(jnp.uint32)
    return jnp.asarray(next_id).astype(jnp.uint32), jnp.uint32(0)


def _regenerate(state, remaining, launched_per_lane, next_id, quota,
                source, seed, mode, shape, ppath=None, lane_ids=None):
    """Relaunch photons in dead lanes according to the workload mode.

    ``next_id`` is the 64-bit global photon id counter as a ``(lo, hi)``
    uint32 scalar pair (a legacy plain scalar is accepted and means
    ``hi = 0``); it is returned advanced, as a pair, with the low-word
    carry propagated so campaigns beyond 2**32 photons keep distinct
    RNG streams instead of wrapping (DESIGN.md §replay).  Ids below
    2**32 produce bit-identical launch states to the historical 32-bit
    counter.

    ``ppath`` (detector runs only) is the per-lane partial-pathlength
    accumulator; relaunched lanes start their new photon with zeroed
    pathlengths.  ``lane_ids`` (detected-photon recording only) is the
    (n_lanes, 2) uint32 [lo, hi] id of each lane's in-flight photon,
    updated on relaunch.  Each is threaded through (and returned as a
    trailing element) only when given, so detector-free engines keep
    the historical tuple contract.
    """
    dead = ~state.alive
    if mode == "dynamic":
        order = jnp.cumsum(dead.astype(jnp.int32))  # 1-based rank among dead
        relaunch = dead & (order <= remaining)
    else:  # static pre-assigned quota per lane
        relaunch = dead & (launched_per_lane < quota)
    n_relaunch = jnp.sum(relaunch.astype(jnp.int32))
    rank = jnp.cumsum(relaunch.astype(jnp.int32)) - 1  # 0-based among relaunched
    next_lo, next_hi = _as_id_pair(next_id)
    ids_lo = (next_lo + rank.astype(jnp.uint32)).astype(jnp.uint32)
    # low-word wraparound carries into the high word (only meaningful on
    # relaunch lanes, whose rank is >= 0; masked lanes may compute a
    # garbage id but their sample is discarded by the merge below)
    ids_hi = (next_hi + (ids_lo < next_lo).astype(jnp.uint32)).astype(
        jnp.uint32)
    ids = xrng.PhotonId(lo=ids_lo, hi=ids_hi)
    pos, direc, w0, rng = source.sample(ids, seed)
    fresh = ph.launch(pos, direc, w0, rng, relaunch, shape)

    def merge(new, old):
        mask = relaunch
        if new.ndim > 1:
            mask = relaunch[:, None]
        return jnp.where(mask, new, old)

    merged = ph.PhotonState(*(merge(n, o) for n, o in zip(fresh, state)))
    merged = merged._replace(alive=state.alive | relaunch)
    new_lo = (next_lo + n_relaunch.astype(jnp.uint32)).astype(jnp.uint32)
    new_hi = (next_hi + (new_lo < next_lo).astype(jnp.uint32)).astype(
        jnp.uint32)
    out = (
        merged,
        remaining - n_relaunch,
        launched_per_lane + relaunch.astype(jnp.int32),
        (new_lo, new_hi),
        jnp.sum(jnp.where(relaunch, w0, 0.0)),
    )
    if ppath is not None:
        out = out + (jnp.where(relaunch[:, None], 0.0, ppath),)
    if lane_ids is not None:
        fresh_ids = jnp.stack([ids_lo, ids_hi], axis=1)
        out = out + (jnp.where(relaunch[:, None], fresh_ids, lane_ids),)
    return out


def _maybe_regenerate(state, remaining, launched_per_lane, next_id, quota,
                      source, seed, mode, shape, ppath=None, lane_ids=None):
    """Regenerate only when some lane will actually relaunch.

    The full regeneration path costs two prefix-sums plus a
    ``source.sample`` over *all* lanes; rounds in which every lane is
    still in flight (the common case for K>1 between termination
    bursts) skip it entirely via ``lax.cond``.  The predicates are
    exact: in dynamic mode the first dead lane has rank 1 <= remaining,
    so ``any(dead) & (remaining > 0)`` relaunches at least one photon;
    in static mode the mask is the relaunch mask itself.  Skipping is
    bit-identical to running ``_regenerate`` with an all-False mask.
    """
    dead = ~state.alive
    if mode == "dynamic":
        any_relaunch = jnp.any(dead) & (remaining > 0)
    else:
        any_relaunch = jnp.any(dead & (launched_per_lane < quota))
    next_pair = _as_id_pair(next_id)

    def do(_):
        return _regenerate(state, remaining, launched_per_lane, next_pair,
                           quota, source, seed, mode, shape, ppath,
                           lane_ids)

    def skip(_):
        out = (state, remaining, launched_per_lane, next_pair,
               jnp.float32(0.0))
        if ppath is not None:
            out = out + (ppath,)
        if lane_ids is not None:
            out = out + (lane_ids,)
        return out

    return jax.lax.cond(any_relaunch, do, skip, None)


def build_sim_fn(shape: tuple[int, int, int], unitinmm: float,
                 cfg: SimConfig, n_lanes: int, mode: str = "dynamic",
                 source: PhotonSource | None = None,
                 engine: str = "jnp", block_lanes: int = 256,
                 interpret: bool | None = None,
                 detectors: tuple[Detector, ...] | None = None,
                 record_detected: int = 0,
                 det_geom_override=None):
    """Build the raw (unjitted) simulation function.

    Returns ``sim_fn(labels_flat, media, n_photons, seed, id_offset=0,
    id_offset_hi=0) -> SimResult``; ``n_photons``, ``seed`` and the id
    offset are traced, so one executable serves pilot runs and
    production runs.
    ``source`` is any registered photon source (repro.sources; pencil
    beam by default) and is baked in at trace time — its parameters are
    static, its randomness counter-seeded per photon id.  ``id_offset``
    (with ``id_offset_hi`` the high uint32 word of the 64-bit offset)
    gives this shard a disjoint global photon-id range — the
    counter-based RNG (both the source's launch stream and the in-flight
    stream) then makes multi-device / elastic / restarted runs simulate
    *exactly* the same photon set as a single-device run
    (DESIGN.md §determinism, §sources).  Ids are carried as two-word
    uint32 pairs end-to-end, so campaigns beyond 2**32 photons never
    wrap onto already-simulated RNG streams (DESIGN.md §replay).

    ``record_detected`` > 0 additionally records the global photon id,
    detector index and exit time gate of up to that many detector
    captures into the fixed-capacity ``SimResult.det_rec`` buffer
    (requires ``detectors``; DESIGN.md §replay).  Once full, further
    captures still accumulate into ``det_w``/``det_ppath`` but their id
    records are dropped and counted in ``det_rec_overflow``.

    ``det_geom_override`` (scenario batching, DESIGN.md §batching)
    substitutes a traced ``(n_det, 3)`` array of (x, y, radius²) rows
    for the statically-derived detector geometry; ``detectors`` still
    fixes the detector *count* and validates the concrete set.

    ``engine`` selects the round executor (DESIGN.md §rounds):
    ``"jnp"`` advances ``cfg.steps_per_round`` segments in an in-graph
    ``fori_loop`` and flushes batched deposition/exitance scatters once
    per round; ``"pallas"`` dispatches the Pallas photon-step kernel
    per round (``block_lanes`` lanes per grid step; ``interpret=None``
    auto-detects the backend).  Both engines simulate bit-identical
    trajectories; accumulated grids agree to fp-accumulation order.

    ``cfg.n_time_gates`` widens the energy accumulator to a gate-major
    flat ``(nvox * ntg,)`` grid (DESIGN.md §time-resolved); the gate
    index is computed at deposit time from the photon's time-of-flight.
    ``detectors`` (repro.detectors) enables TPSF recording: escapes
    through the z=0 face inside a detector disk are histogrammed per
    (detector, time gate), with weight-weighted per-medium partial
    pathlengths tracked per lane.  Both are static trace-time config;
    the default (CW, no detectors) is bit-identical to the ungated
    engine.

    The raw function is shard_map-composable; ``make_simulator`` wraps
    it in jit for single-device use.
    """
    if mode not in ("dynamic", "static"):
        raise ValueError(f"unknown workload mode: {mode}")
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (choose from {ENGINES})")
    source = as_source(source)
    detectors = as_detectors(detectors)
    n_det = len(detectors)
    if n_det:
        validate_detectors(detectors, shape)
    det_geom = det_geometry(detectors) if n_det else None
    if det_geom_override is not None:
        # scenario batching (repro.scenarios): the capture geometry is a
        # *traced* (n_det, 3) array — ``detectors`` still fixes n_det and
        # carries the host-side validation, but the coordinates flow
        # through the graph so one executable serves many detector sets
        if not n_det:
            raise ValueError("det_geom_override requires detectors: the "
                             "override replaces their traced geometry, "
                             "not their count")
        if tuple(det_geom_override.shape) != (n_det, 3):
            raise ValueError(
                f"det_geom_override shape {tuple(det_geom_override.shape)} "
                f"!= ({n_det}, 3) from the detectors tuple")
        det_geom = jnp.asarray(det_geom_override, jnp.float32)
    capacity = int(record_detected)
    if capacity < 0:
        raise ValueError(f"record_detected must be >= 0, got {capacity}")
    record = capacity > 0
    if record and not n_det:
        raise ValueError(
            "record_detected > 0 requires detectors: the id buffer records "
            "detector captures (DESIGN.md §replay)")
    nx, ny, nz = shape
    nvox = nx * ny * nz
    nxy = nx * ny
    K = int(cfg.steps_per_round)
    if K < 1:
        raise ValueError(f"cfg.steps_per_round must be >= 1, got {K}")
    ntg = int(cfg.n_time_gates)
    if ntg < 1:
        raise ValueError(f"cfg.n_time_gates must be >= 1, got {ntg}")
    collect = bool(cfg.collect_stats)
    if engine == "pallas":
        from repro.kernels.photon_step.photon_step import (
            default_interpret, photon_step_pallas, resolve_block_lanes)

        block_lanes = resolve_block_lanes(n_lanes, block_lanes)
        if interpret is None:
            interpret = default_interpret()

    def sim_fn(labels_flat, media, n_photons, seed, id_offset=0,
               id_offset_hi=0):
        n_photons = jnp.asarray(n_photons, jnp.int32)
        seed = jnp.asarray(seed, jnp.uint32)
        id_lo = jnp.asarray(id_offset, jnp.uint32)
        id_hi = jnp.asarray(id_offset_hi, jnp.uint32)
        # static mode: equal distribution with the remainder spread over the
        # first (n_photons mod n_lanes) lanes, so exactly n_photons launch
        lane_idx = jnp.arange(n_lanes, dtype=jnp.int32)
        quota = n_photons // n_lanes + (lane_idx < n_photons % n_lanes)
        n_media = media.shape[0]
        # partial pathlengths are only tracked when a detector can consume
        # them; width-0 otherwise so the carry structure stays fixed
        ppath_w = n_media if n_det else 0

        state0 = ph.PhotonState(
            pos=jnp.zeros((n_lanes, 3), jnp.float32),
            dir=jnp.tile(jnp.asarray([0.0, 0.0, 1.0], jnp.float32), (n_lanes, 1)),
            ivox=jnp.zeros((n_lanes, 3), jnp.int32),
            w=jnp.zeros((n_lanes,), jnp.float32),
            s_left=jnp.zeros((n_lanes,), jnp.float32),
            t=jnp.zeros((n_lanes,), jnp.float32),
            rng=jnp.zeros((n_lanes, 4), jnp.uint32),
            alive=jnp.zeros((n_lanes,), bool),
        )
        carry0 = _Carry(
            state=state0,
            energy=jnp.zeros((nvox * ntg,), jnp.float32),
            exitance=jnp.zeros((nxy,), jnp.float32),
            escaped_w=jnp.float32(0.0),
            timed_out_w=jnp.float32(0.0),
            ppath=jnp.zeros((n_lanes, ppath_w), jnp.float32),
            det_w=jnp.zeros((n_det * ntg,), jnp.float32),
            det_ppath=jnp.zeros((n_det, n_media), jnp.float32),
            # one write-off row past the capacity absorbs masked and
            # overflowing record scatters (lock-step-safe: slots come
            # from a prefix sum, so live writes never collide)
            rec=jnp.zeros((capacity + 1 if record else 0, 4), jnp.uint32),
            rec_n=jnp.int32(0),
            rec_overflow=jnp.int32(0),
            lane_ids=jnp.zeros((n_lanes if record else 0, 2), jnp.uint32),
            remaining=n_photons,
            launched_per_lane=jnp.zeros((n_lanes,), jnp.int32),
            next_id_lo=id_lo,
            next_id_hi=id_hi,
            launched_w=jnp.float32(0.0),
            steps=jnp.int32(0),
            stats=(RoundStats(
                rounds=jnp.int32(0), regen_rounds=jnp.int32(0),
                relaunched=jnp.int32(0), live_segments=jnp.float32(0.0),
                lane_segments=jnp.float32(0.0),
                deposited_w=jnp.float32(0.0), escaped_w=jnp.float32(0.0),
                timed_out_w=jnp.float32(0.0), detected_w=jnp.float32(0.0),
            ) if collect else ()),
        )

        def cond(c: _Carry):
            has_work = jnp.any(c.state.alive)
            if mode == "dynamic":
                has_work = has_work | (c.remaining > 0)
            else:
                has_work = has_work | jnp.any(c.launched_per_lane < quota)
            return has_work & (c.steps < cfg.max_steps)

        def round_jnp(state, ppath):
            """Advance K segments in-graph; returns the new state plus
            round-local (K, n_lanes) deposition/exitance buffers (the
            deposition index is gate-major: voxel * ntg + gate) and the
            round's escaped / timed-out weights — flushed by the caller
            in ONE scatter per grid instead of one per segment.
            Detector capture scatters into round-local (n_det * ntg,)
            and (n_det, n_media) accumulators per segment (they are
            tiny, unlike the fluence volume).  With recording on, the
            trailing (cap_det, cap_gate) carry tracks the round's
            per-lane capture (at most one: escape kills the lane).
            With ``cfg.collect_stats``, the final ``live`` element
            counts lane-segments entered alive — a reduction over the
            mask the step already computes, never fed back into any
            physics value."""
            def seg(k, rc):
                (st, pp, dep_i, dep_w, ex_i, ex_w, esc, timed, dw, dp,
                 capd, capg, live) = rc
                if collect:
                    live = live + jnp.sum(st.alive, dtype=jnp.float32)
                res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
                gate = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
                dep_i = dep_i.at[k].set(res.dep_idx * ntg + gate)
                dep_w = dep_w.at[k].set(res.dep_w)
                xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
                ex_i = ex_i.at[k].set(xy)
                ex_w = ex_w.at[k].set(xw)
                esc = esc + jnp.sum(res.esc_w)
                timed = timed + jnp.sum(res.timed_w)
                if n_det:
                    pp, dw, dp = accumulate_capture(pp, dw, dp, res, gate,
                                                    det_geom, ntg)
                if record:
                    capd, capg = update_capture(capd, capg, res, gate,
                                                det_geom)
                return (res.state, pp, dep_i, dep_w, ex_i, ex_w, esc,
                        timed, dw, dp, capd, capg, live)

            cap_w = n_lanes if record else 0
            init = (
                state,
                ppath,
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.zeros((K, n_lanes), jnp.int32),
                jnp.zeros((K, n_lanes), jnp.float32),
                jnp.float32(0.0),
                jnp.float32(0.0),
                jnp.zeros((n_det * ntg,), jnp.float32),
                jnp.zeros((n_det, n_media), jnp.float32),
                jnp.full((cap_w,), -1, jnp.int32),
                jnp.zeros((cap_w,), jnp.int32),
                jnp.float32(0.0),
            )
            return jax.lax.fori_loop(0, K, seg, init)

        def append_records(c: _Carry, lane_ids, capd, capg):
            """Append this round's captures to the fixed-capacity id
            buffer: slots come from a prefix sum over captured lanes
            (lock-step-safe, like the dynamic-mode regeneration), and
            masked / over-capacity writes land in the write-off row."""
            captured = capd >= 0
            cap_i = captured.astype(jnp.int32)
            slot = c.rec_n + jnp.cumsum(cap_i) - 1
            ok = captured & (slot < capacity)
            slot = jnp.where(ok, slot, capacity)
            vals = jnp.stack([lane_ids[:, 0], lane_ids[:, 1],
                              capd.astype(jnp.uint32),
                              capg.astype(jnp.uint32)], axis=1)
            rec = c.rec.at[slot].set(vals)
            n_cap = jnp.sum(cap_i)
            rec_n = jnp.minimum(c.rec_n + n_cap, capacity)
            overflow = c.rec_overflow + (c.rec_n + n_cap - rec_n)
            return rec, rec_n, overflow

        def body(c: _Carry):
            next_pair = (c.next_id_lo, c.next_id_hi)
            lane_ids = c.lane_ids
            if record:
                (state, remaining, launched, next_id, w_new, ppath,
                 lane_ids) = _maybe_regenerate(
                    c.state, c.remaining, c.launched_per_lane, next_pair,
                    quota, source, seed, mode, shape, c.ppath, c.lane_ids)
            elif n_det:
                (state, remaining, launched, next_id, w_new,
                 ppath) = _maybe_regenerate(
                    c.state, c.remaining, c.launched_per_lane, next_pair,
                    quota, source, seed, mode, shape, c.ppath)
            else:
                state, remaining, launched, next_id, w_new = _maybe_regenerate(
                    c.state, c.remaining, c.launched_per_lane, next_pair,
                    quota, source, seed, mode, shape)
                ppath = c.ppath
            capd = capg = None
            live = dep_sum = det_new = None
            if engine == "pallas":
                outs = photon_step_pallas(
                    labels_flat, media, state, shape, unitinmm, cfg, K,
                    block_lanes, interpret,
                    ppath=ppath if n_det else None, det_geom=det_geom,
                    record=record, stats=collect)
                state, flu, exi, esc, timed = outs[:5]
                energy = c.energy + flu
                exitance = c.exitance + exi
                escaped_w = c.escaped_w + jnp.sum(esc)
                timed_out_w = c.timed_out_w + jnp.sum(timed)
                cur = 5
                if n_det:
                    ppath, dw, dp = outs[cur:cur + 3]
                    cur += 3
                    det_w = c.det_w + dw
                    det_ppath = c.det_ppath + dp
                else:
                    det_w, det_ppath = c.det_w, c.det_ppath
                if record:
                    capd, capg = outs[cur:cur + 2]
                    cur += 2
                if collect:
                    # the kernel's (n_lanes, 2) stats block: col 0 counts
                    # segments entered alive, col 1 sums deposited weight
                    st_block = outs[cur]
                    live = jnp.sum(st_block[:, 0])
                    dep_sum = jnp.sum(st_block[:, 1])
                    det_new = (jnp.sum(dw) if n_det
                               else jnp.float32(0.0))
            else:
                (state, ppath, dep_i, dep_w, ex_i, ex_w, esc, timed,
                 dw, dp, capd, capg, live) = round_jnp(state, ppath)
                energy = c.energy.at[dep_i.reshape(-1)].add(dep_w.reshape(-1))
                exitance = c.exitance.at[ex_i.reshape(-1)].add(
                    ex_w.reshape(-1))
                escaped_w = c.escaped_w + esc
                timed_out_w = c.timed_out_w + timed
                det_w = c.det_w + dw
                det_ppath = c.det_ppath + dp
                if collect:
                    dep_sum = jnp.sum(dep_w)
                    det_new = jnp.sum(dw)
            if collect:
                # uint32 difference is exact across a low-word wrap, and
                # per-round relaunch counts stay far below 2**31
                rel = (next_id[0] - c.next_id_lo).astype(jnp.int32)
                s = c.stats
                stats = RoundStats(
                    rounds=s.rounds + 1,
                    regen_rounds=s.regen_rounds + (rel > 0).astype(
                        jnp.int32),
                    relaunched=s.relaunched + rel,
                    live_segments=s.live_segments + live,
                    lane_segments=s.lane_segments,  # derived at the end
                    deposited_w=s.deposited_w + dep_sum,
                    # escaped/timed totals mirror the main carry's exact
                    # accumulation, so they stay bit-equal to SimResult
                    escaped_w=escaped_w,
                    timed_out_w=timed_out_w,
                    detected_w=s.detected_w + det_new,
                )
            else:
                stats = ()
            if record:
                rec, rec_n, rec_overflow = append_records(
                    c, lane_ids, capd, capg)
            else:
                rec, rec_n, rec_overflow = c.rec, c.rec_n, c.rec_overflow
            return _Carry(
                state=state,
                energy=energy,
                exitance=exitance,
                escaped_w=escaped_w,
                timed_out_w=timed_out_w,
                ppath=ppath,
                det_w=det_w,
                det_ppath=det_ppath,
                rec=rec,
                rec_n=rec_n,
                rec_overflow=rec_overflow,
                lane_ids=lane_ids,
                remaining=remaining,
                launched_per_lane=launched,
                next_id_lo=next_id[0],
                next_id_hi=next_id[1],
                launched_w=c.launched_w + w_new,
                steps=c.steps + K,
                stats=stats,
            )

        final = jax.lax.while_loop(cond, body, carry0)
        # weight still in flight when the max_steps cap fires is retired
        # deterministically, like the time gate — account it there so the
        # energy-balance residue only measures roulette statistics
        capped_w = jnp.sum(jnp.where(final.state.alive, final.state.w, 0.0))
        if collect:
            # mirror the SimResult timed_out_w accounting (capped weight
            # retires deterministically) and fill the occupancy
            # denominator; float avoids int32 overflow at large
            # steps * n_lanes products
            stats_out = final.stats._replace(
                timed_out_w=final.stats.timed_out_w + capped_w,
                lane_segments=final.steps.astype(jnp.float32) * n_lanes)
        else:
            stats_out = None
        energy = final.energy
        energy = (energy.reshape(shape + (ntg,)) if ntg > 1
                  else energy.reshape(shape))
        return SimResult(
            energy=energy,
            exitance=final.exitance.reshape((nx, ny)),
            escaped_w=final.escaped_w,
            timed_out_w=final.timed_out_w + capped_w,
            det_w=final.det_w.reshape((n_det, ntg)),
            det_ppath=final.det_ppath,
            det_rec=final.rec[:capacity],
            det_rec_n=final.rec_n,
            det_rec_overflow=final.rec_overflow,
            # launches per run stay < 2**31, so the uint32 low-word
            # difference is the exact count even across a 2**32 boundary
            n_launched=(final.next_id_lo - id_lo).astype(jnp.int32),
            launched_w=final.launched_w,
            steps=final.steps,
            stats=stats_out,
        )

    return sim_fn


def make_simulator(volume: Volume, cfg: SimConfig, n_lanes: int,
                   mode: str = "dynamic",
                   source: PhotonSource | Source | None = None,
                   engine: str = "jnp", block_lanes: int = 256,
                   interpret: bool | None = None,
                   detectors=None, record_detected: int = 0):
    """Jitted single-device simulator for a fixed (volume, cfg, lanes,
    source, engine, detectors).  Detector geometry is validated here
    against the volume footprint (a disk that misses the z=0 face can
    never capture)."""
    raw = build_sim_fn(volume.shape, volume.unitinmm, cfg, n_lanes, mode,
                       source, engine, block_lanes, interpret, detectors,
                       record_detected)
    return jax.jit(raw)


def simulate(volume: Volume, cfg: SimConfig, n_photons: int,
             n_lanes: int = 4096, seed: int = 1234,
             source: PhotonSource | Source | None = None,
             mode: str = "dynamic", engine: str = "jnp",
             block_lanes: int = 256,
             interpret: bool | None = None,
             detectors=None, record_detected: int = 0) -> SimResult:
    """Convenience one-shot simulation on the current default device.

    ``source`` accepts any registered source type (repro.sources), the
    legacy pencil :class:`Source`, or a ``sources.to_dict``-style config
    dict; ``None`` is the paper's pencil beam.  ``engine`` selects the
    round executor (``"jnp"`` | ``"pallas"``, DESIGN.md §rounds);
    ``block_lanes`` / ``interpret`` tune the Pallas executor only.
    ``detectors`` (repro.detectors spec) enables TPSF recording on the
    z=0 face (DESIGN.md §time-resolved); ``record_detected`` sets the
    detected-photon id buffer capacity for replay (DESIGN.md §replay).
    """
    sim_fn = make_simulator(volume, cfg, n_lanes, mode, source, engine,
                            block_lanes, interpret, detectors,
                            record_detected)
    return sim_fn(
        volume.labels.reshape(-1),
        volume.media,
        n_photons,
        seed,
    )


# ---------------------------------------------------------------------------
# Opt2: (lane count x steps-per-round) autotuning
# ---------------------------------------------------------------------------

def autotune_rounds(volume: Volume, cfg: SimConfig, n_pilot: int = 20_000,
                    lane_candidates=(1024, 2048, 4096, 8192, 16384),
                    round_candidates=(1, 4, 8, 16, 32),
                    seed: int = 7,
                    source: PhotonSource | Source | None = None,
                    repeats: int = 2, mode: str = "dynamic",
                    engine: str = "jnp",
                    ) -> tuple[tuple[int, int], dict[tuple[int, int], float]]:
    """2-D pilot sweep over (n_lanes, steps_per_round).

    The paper's Opt2 computes the balanced thread number from hardware
    occupancy; lacking introspectable occupancy on this runtime, we
    measure it — and the fused-round depth K trades regeneration /
    flush amortization against masked-lane waste (DESIGN.md §rounds),
    so the two knobs are tuned jointly.  Returns
    ``((best_lanes, best_k), timings_s)`` with timings keyed by
    ``(lanes, k)``.
    """
    labels_flat = volume.labels.reshape(-1)
    timings: dict[tuple[int, int], float] = {}
    for lanes in lane_candidates:
        for k in round_candidates:
            kcfg = dataclasses.replace(cfg, steps_per_round=int(k))
            sim_fn = make_simulator(volume, kcfg, lanes, mode, source, engine)
            args = (labels_flat, volume.media, n_pilot, seed)
            jax.block_until_ready(sim_fn(*args))  # compile + warm up
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()  # reprolint: disable=REP201 - autotune timing, host only
                jax.block_until_ready(sim_fn(*args))
                best = min(best, time.perf_counter() - t0)  # reprolint: disable=REP201 - autotune timing, host only
            timings[(lanes, k)] = best
    best_cfg = min(timings, key=timings.get)
    return best_cfg, timings


def autotune_lanes(volume: Volume, cfg: SimConfig, n_pilot: int = 20_000,
                   candidates=(1024, 2048, 4096, 8192, 16384),
                   seed: int = 7,
                   source: PhotonSource | Source | None = None,
                   repeats: int = 2, mode: str = "dynamic",
                   engine: str = "jnp") -> tuple[int, dict[int, float]]:
    """Pick the lane count with the highest pilot throughput.

    1-D slice of :func:`autotune_rounds` at the config's own
    ``steps_per_round`` — kept as the paper's original Opt2 interface.
    Tune with the same ``engine`` the production run will use: the
    throughput-vs-lane-count curve differs between executors.
    Returns (best_lane_count, timings_s).
    """
    (best_lanes, _), timings = autotune_rounds(
        volume, cfg, n_pilot, candidates,
        round_candidates=(int(cfg.steps_per_round),),
        seed=seed, source=source, repeats=repeats, mode=mode, engine=engine)
    return best_lanes, {lanes: t for (lanes, _), t in timings.items()}
