"""Multi-device / multi-pod distributed photon simulation.

Maps the paper's heterogeneous multi-device execution (Fig. 1, Fig. 3b/c)
onto JAX-native constructs:

  * :func:`simulate_sharded` — shard_map over the mesh's photon axes.
    Each device simulates a (possibly unequal) slice of the photon
    budget (the device-level load-balancing partition) and the fluence
    volume is combined with a single ``psum`` — the only collective in
    the whole simulation, which is why MC scales near-linearly
    (paper Fig. 3c).
  * :class:`ChunkScheduler` — dynamic work-stealing over photon chunks
    using JAX's async dispatch; the runtime analogue of the paper's
    "host waits for all devices" barrier, but without the straggler
    penalty: fast devices pull more chunks.
  * :class:`ElasticSimulator` — fault-tolerant chunk accounting.  The
    counter-based RNG keys photons by *global id*, so a chunk lost to a
    device failure is re-simulated bit-identically elsewhere, and a
    checkpoint is just (accumulated grids + chunk cursor).
"""

from __future__ import annotations

import dataclasses
import json
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.loadbalance import DeviceModel
from repro.core.rng import split_id64
from repro.core.simulator import SimResult, build_sim_fn
from repro.core.volume import SimConfig, Source, Volume
from repro.detectors import as_detectors
from repro.resilience import (DevicePool, DeviceSpec, FaultInjector,
                              InjectedFault, RetryPolicy, corrupt_harvest,
                              harvest_result, validate_chunk)
from repro.sources import PhotonSource, as_source
from repro.telemetry.stats import RoundStats
from repro.telemetry.trace import device_label

# jax >= 0.6 exposes shard_map at the top level (vma type check); older
# releases keep it in jax.experimental (replication rule check).  Either
# check must be off: the while_loop carry mixes shard-varying (photon
# counts) and replicated (volume) values.
if hasattr(jax, "shard_map"):
    _shard_map = partial(jax.shard_map, check_vma=False)
else:  # pragma: no cover - exercised on jax < 0.6 only
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    _shard_map = partial(_exp_shard_map, check_rep=False)


# ---------------------------------------------------------------------------
# shard_map distribution (single-pod and multi-pod meshes)
# ---------------------------------------------------------------------------

def sharded_sim_fn(volume: Volume, cfg: SimConfig, n_lanes: int,
                   mesh: Mesh, axis_names: tuple[str, ...] = ("data",),
                   mode: str = "dynamic",
                   source: PhotonSource | Source | None = None,
                   engine: str = "jnp", detectors=None,
                   record_detected: int = 0):
    """Build a shard_map'd simulator over ``axis_names`` of ``mesh``.

    The returned fn takes per-device photon counts and 64-bit id offsets
    (as uint32 lo/hi words, one entry per device on the sharded axes)
    and returns a globally-reduced SimResult.  Volume data is replicated
    and the source / detector configs are baked in statically; the
    fluence volume (time-gated when ``cfg.n_time_gates > 1``), the
    detector TPSF histograms and the scalar accounting are psum'd.
    ``engine`` selects the per-shard round executor (``"jnp"`` |
    ``"pallas"``, DESIGN.md §rounds) — each shard runs the fused
    ``cfg.steps_per_round`` rounds locally, so the collective structure
    (one psum per grid) is engine- and gate-independent.

    ``record_detected`` gives every shard its own ``record_detected``-row
    detected-photon id buffer (DESIGN.md §replay); the per-shard buffers
    are concatenated over the mesh (``det_rec`` becomes
    ``(n_shards * capacity, 4)`` with per-shard valid counts in the
    rank-1 ``det_rec_n``) and the overflow counters are psum'd —
    ``repro.replay.detected_records`` reassembles the global record
    list.
    """
    raw = build_sim_fn(volume.shape, volume.unitinmm, cfg, n_lanes, mode,
                       source, engine, detectors=detectors,
                       record_detected=record_detected)
    ax = axis_names
    collect = bool(cfg.collect_stats)

    def worker(labels_flat, media, counts, offsets_lo, offsets_hi, seed):
        res = raw(labels_flat, media, counts[0], seed, offsets_lo[0],
                  offsets_hi[0])
        summed = {
            "energy": res.energy,
            "exitance": res.exitance,
            "escaped_w": res.escaped_w,
            "timed_out_w": res.timed_out_w,
            "det_w": res.det_w,
            "det_ppath": res.det_ppath,
            "det_rec_overflow": res.det_rec_overflow,
            "n_launched": res.n_launched,
            "launched_w": res.launched_w,
        }
        if collect:
            # RoundStats totals are additive over disjoint photon
            # subsets, so the cross-shard reduction is the same psum as
            # every other accumulator
            summed["stats"] = res.stats
        for a in ax:
            summed = {k: jax.lax.psum(v, a) for k, v in summed.items()}
        # steps and the record buffer/cursor stay per-shard (rank-1 /
        # row-blocked so they concatenate over the mesh)
        return SimResult(steps=res.steps[None], det_rec=res.det_rec,
                         det_rec_n=res.det_rec_n[None], **summed)

    pspec = P(ax)  # counts/offsets sharded across the photon axes
    stats_spec = (RoundStats(*([P()] * len(RoundStats._fields)))
                  if collect else None)
    mapped = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(), P(), pspec, pspec, pspec, P()),
        out_specs=SimResult(energy=P(), exitance=P(), escaped_w=P(),
                            timed_out_w=P(), det_w=P(), det_ppath=P(),
                            det_rec=P(ax), det_rec_n=P(ax),
                            det_rec_overflow=P(),
                            n_launched=P(), launched_w=P(), steps=P(ax),
                            stats=stats_spec),
    )
    return jax.jit(mapped)


def simulate_sharded(volume: Volume, cfg: SimConfig, n_photons: int,
                     mesh: Mesh, axis_names: tuple[str, ...] = ("data",),
                     partition: Sequence[int] | None = None,
                     n_lanes: int = 1024, seed: int = 1234,
                     source: PhotonSource | Source | None = None,
                     mode: str = "dynamic", engine: str = "jnp",
                     detectors=None, record_detected: int = 0,
                     id_offset: int = 0) -> SimResult:
    """Run one distributed simulation over the mesh's photon axes.

    ``id_offset`` shifts the whole campaign's global photon-id range (a
    host-side Python int, 64-bit: chunked mega-campaigns pass their
    chunk start here); per-shard offsets are split into uint32 lo/hi
    words so shards beyond the 2**32 boundary keep disjoint RNG
    streams.
    """
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    if partition is None:
        base = n_photons // n_shards
        counts = np.full((n_shards,), base, np.int32)
        counts[: n_photons - base * n_shards] += 1
    else:
        counts = np.asarray(partition, np.int32)
        if counts.shape != (n_shards,) or counts.sum() != n_photons:
            raise ValueError("partition must have one entry per shard and "
                             "sum to n_photons")
    offsets = int(id_offset) + np.concatenate(
        [[0], np.cumsum(counts.astype(np.uint64))[:-1]]).astype(np.uint64)
    offsets_lo = (offsets & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    offsets_hi = (offsets >> np.uint64(32)).astype(np.uint32)

    fn = sharded_sim_fn(volume, cfg, n_lanes, mesh, axis_names, mode, source,
                        engine, detectors, record_detected)
    shard_sharding = NamedSharding(mesh, P(axis_names))
    repl = NamedSharding(mesh, P())
    dev_counts = jax.device_put(jnp.asarray(counts), shard_sharding)
    dev_off_lo = jax.device_put(jnp.asarray(offsets_lo), shard_sharding)
    dev_off_hi = jax.device_put(jnp.asarray(offsets_hi), shard_sharding)
    return fn(
        jax.device_put(volume.labels.reshape(-1), repl),
        jax.device_put(volume.media, repl),
        dev_counts,
        dev_off_lo,
        dev_off_hi,
        jnp.uint32(seed),
    )


def sharded_replay_fn(volume: Volume, cfg: SimConfig, detectors, mesh: Mesh,
                      axis_names: tuple[str, ...] = ("data",),
                      n_lanes: int = 1024,
                      source: PhotonSource | Source | None = None,
                      engine: str = "jnp", gate_resolved: bool = False,
                      block_lanes: int = 256,
                      interpret: bool | None = None):
    """Build a shard_map'd two-pass replay executor over ``axis_names``.

    The device-parallel half of ``repro.replay.replay_jacobian``
    (DESIGN.md §replay): every device replays its own ``n_lanes``-lane
    slice of a record batch through the selected round executor
    (``engine="jnp"`` | ``"pallas"``), and the flat Jacobian
    accumulator is combined with one ``psum`` per batch — the same
    single-collective structure as :func:`sharded_sim_fn`, so replay
    scales like the forward pass.  The per-record outputs
    (``w_exit``/``gate``/``replayed_det``) stay sharded over the mesh
    in batch order.

    Returns the jitted ``fn(labels_flat, media, id_lo, id_hi, jac_col,
    active, seed) -> (jac_flat, w_exit, gate, replayed_det)`` taking
    ``n_shards * n_lanes`` global lane arrays.
    """
    # imported lazily: repro.replay imports this module for mesh runs
    from repro.detectors import det_geometry, validate_detectors
    from repro.replay import _build_replay_fn

    dets = as_detectors(detectors)
    n_det = len(dets)
    if n_det == 0:
        raise ValueError("sharded_replay_fn needs the forward run's "
                         "detectors")
    validate_detectors(dets, volume.shape)
    jac_cols = n_det * int(cfg.n_time_gates) if gate_resolved else n_det
    raw = _build_replay_fn(volume.shape, volume.unitinmm, cfg, n_lanes,
                           n_det, source, det_geometry(dets), jac_cols,
                           engine, block_lanes, interpret)
    ax = axis_names

    def worker(labels_flat, media, id_lo, id_hi, jac_col, active, seed):
        jac, w_exit, gate, rdet = raw(labels_flat, media, id_lo, id_hi,
                                      jac_col, active, seed)
        for a in ax:
            jac = jax.lax.psum(jac, a)
        return jac, w_exit, gate, rdet

    pspec = P(ax)
    mapped = _shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(), P(), pspec, pspec, pspec, pspec, P()),
        out_specs=(P(), pspec, pspec, pspec),
    )
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# chunked work queue: straggler mitigation + heterogeneous devices
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Chunk:
    start_id: int
    count: int


def _accumulator_shapes(volume: Volume, cfg: SimConfig, detectors):
    """Host-side numpy accumulator shapes for the gate/detector-aware
    merge: (energy, det_w, det_ppath) shapes matching SimResult."""
    nx, ny, nz = volume.shape
    ntg = int(cfg.n_time_gates)
    n_det = len(as_detectors(detectors))
    n_media = volume.media.shape[0]
    eshape = (nx, ny, nz) if ntg == 1 else (nx, ny, nz, ntg)
    return eshape, (n_det, ntg), (n_det, n_media)


class ChunkScheduler:
    """Greedy dynamic chunk dispatch across devices via async dispatch.

    The device-level generalization of the paper's *workgroup* dynamic
    load balancing: instead of fixing each device's share up front (S1-S3),
    devices pull fixed-size chunks from a shared queue as they finish.
    JAX dispatch is asynchronous, so while a device crunches chunk k the
    host can already enqueue k+1 elsewhere; `jax.Array` readiness is the
    completion signal.

    Since PR 7 this is a front-end over ``repro.resilience.DevicePool``
    (DESIGN.md §resilience): a dispatch that raises requeues the chunk
    through ``RetryPolicy`` instead of losing it, results pass the
    ``validate_chunk`` merge guard, stragglers past their
    ``DeviceModel`` deadline re-dispatch speculatively, ``deadline_s``
    bounds the whole run, and merges happen in chunk-id order so the
    result is bit-independent of completion order.  Heterogeneous
    fleets pass ``specs`` (a list of ``resilience.DeviceSpec``) instead
    of ``devices``; ``fault_injector`` enables the chaos drill.

    ``tracer`` (a ``repro.telemetry.Tracer``) records one span per chunk
    dispatch — opened when the chunk is enqueued, closed when its result
    is ready — tagged with device, engine and photon count, so the run's
    timeline exports to Chrome tracing and its per-device photons/s feed
    ``telemetry.fit_device_models`` (DESIGN.md §observability).
    """

    def __init__(self, volume: Volume, cfg: SimConfig, n_lanes: int = 1024,
                 devices: Sequence[jax.Device] | None = None,
                 mode: str = "dynamic",
                 source: PhotonSource | Source | None = None,
                 engine: str = "jnp", detectors=None,
                 record_detected: int = 0, tracer=None,
                 specs: Sequence[DeviceSpec] | None = None,
                 retry_policy: RetryPolicy | None = None,
                 fault_injector: FaultInjector | None = None,
                 validate: bool = True, max_residue_frac: float = 5e-3,
                 chunk_timeout_s: float | None = None,
                 checkpointer=None, checkpoint_every: int = 0,
                 bind_engines: bool = True,
                 raise_on_quarantine: bool = True):
        self.volume = volume
        self.cfg = cfg
        if specs is None:
            self.devices = list(devices or jax.devices())
            specs = [DeviceSpec(device=d, engine=engine, n_lanes=n_lanes,
                                mode=mode) for d in self.devices]
        else:
            if devices is not None:
                raise ValueError("pass either devices or specs, not both")
            self.devices = [s.device if s.device is not None
                            else jax.devices()[0] for s in specs]
        self.tracer = tracer
        self.pool = DevicePool(
            volume, cfg, specs, source=source, detectors=detectors,
            record_detected=record_detected, retry_policy=retry_policy,
            fault_injector=fault_injector, validate=validate,
            max_residue_frac=max_residue_frac,
            chunk_timeout_s=chunk_timeout_s, bind_engines=bind_engines,
            raise_on_quarantine=raise_on_quarantine,
            checkpointer=checkpointer, checkpoint_every=checkpoint_every,
            tracer=tracer)
        self.last_report = None

    def run(self, n_photons: int, chunk_size: int, seed: int = 1234,
            source: PhotonSource | Source | None = None,
            deadline_s: float | None = None, resume: bool = False
            ) -> tuple[SimResult, dict]:
        """Returns ``(SimResult, {device.id: photons merged})``; the full
        resilience accounting lands on ``self.last_report``."""
        res, report = self.pool.run(n_photons, chunk_size, seed=seed,
                                    source=source, deadline_s=deadline_s,
                                    resume=resume)
        self.last_report = report
        stats = {d.id: 0 for d in self.devices}
        for did, n in report.per_device_photons.items():
            stats[did] = stats.get(did, 0) + n
        return res, stats


# ---------------------------------------------------------------------------
# elastic, fault-tolerant execution
# ---------------------------------------------------------------------------

class ElasticSimulator:
    """Chunk-level fault tolerance + elastic scaling for long campaigns.

    Photons are keyed by global id, so work is an immutable set of
    chunks.  Devices may join/leave between rounds; a failed round's
    chunks are simply re-queued and *re-simulated bit-identically*.
    ``state_dict``/``load_state_dict`` give checkpoint/restart: the
    checkpoint stores only the accumulated grids and the completed-chunk
    cursor — O(volume), independent of photon count.

    Failure handling routes through ``repro.resilience`` (DESIGN.md
    §resilience): a failed chunk requeues at the *back* of ``pending``
    (a deterministic poison chunk can no longer starve the campaign)
    and is quarantined onto ``self.skipped`` once it exhausts
    ``retry_policy.max_attempts``; ``fault_injector`` drives seeded
    chaos drills (dispatch faults, delays, NaN corruption — rejected by
    the ``validate_chunk`` merge guard — and ``kill_after_merges`` host
    crashes); ``checkpointer``/``checkpoint_every`` auto-save the
    campaign state every N merged chunks through the atomic
    ``checkpoint.Checkpointer``.

    ``tracer`` (a ``repro.telemetry.Tracer``) records one span per chunk
    (synchronous: the chunk is blocked on inside the span, so durations
    are true device times), tagged with device, engine and photon count
    (DESIGN.md §observability).
    """

    def __init__(self, volume: Volume, cfg: SimConfig, n_photons: int,
                 chunk_size: int, n_lanes: int = 1024, seed: int = 1234,
                 source: PhotonSource | Source | None = None,
                 engine: str = "jnp", detectors=None,
                 record_detected: int = 0, tracer=None,
                 retry_policy: RetryPolicy | None = None,
                 fault_injector: FaultInjector | None = None,
                 validate: bool = True, max_residue_frac: float = 5e-3,
                 checkpointer=None, checkpoint_every: int = 0):
        self.volume = volume
        self.cfg = cfg
        self.seed = seed
        self.engine = engine
        self.tracer = tracer
        self.source = as_source(source)
        self.detectors = as_detectors(detectors)
        self.chunk_size = chunk_size
        self.n_photons = n_photons
        self.record_detected = int(record_detected)
        self.policy = retry_policy or RetryPolicy()
        self.injector = fault_injector
        self.validate = bool(validate)
        self.max_residue_frac = float(max_residue_frac)
        self.checkpointer = checkpointer
        self.checkpoint_every = int(checkpoint_every)
        self.pending: list[Chunk] = [
            Chunk(s, min(chunk_size, n_photons - s))
            for s in range(0, n_photons, chunk_size)
        ]
        self.completed: list[Chunk] = []
        self.skipped: list[Chunk] = []   # chunks quarantined by the cap
        self.failures: dict[int, int] = {}   # chunk start_id -> attempts
        self.n_retries = 0
        nx, ny = volume.shape[:2]
        eshape, dw_shape, dp_shape = _accumulator_shapes(
            volume, cfg, self.detectors)
        self.energy = np.zeros(eshape, np.float32)
        self.exitance = np.zeros((nx, ny), np.float32)
        self.escaped_w = 0.0
        self.timed_out_w = 0.0
        self.det_w = np.zeros(dw_shape, np.float32)
        self.det_ppath = np.zeros(dp_shape, np.float32)
        # per-chunk record slices, concatenated lazily by the det_rec
        # property — appending per merge keeps many-chunk campaigns
        # linear instead of re-copying the whole buffer every chunk
        self._det_rec_parts: list[np.ndarray] = []
        self.det_rec_overflow = 0
        self.n_launched = 0
        self.launched_w = 0.0
        self.stats = (RoundStats.zeros() if cfg.collect_stats else None)
        self._raw = build_sim_fn(volume.shape, volume.unitinmm, cfg, n_lanes,
                                 source=self.source, engine=engine,
                                 detectors=self.detectors,
                                 record_detected=self.record_detected)
        self._jit = jax.jit(self._raw)

    # -- execution ---------------------------------------------------------

    def run_round(self, devices: Sequence[jax.Device] | None = None,
                  fail: Callable[[Chunk, jax.Device], bool] | None = None,
                  max_chunks: int | None = None) -> int:
        """Assign up to one chunk per device; returns #chunks completed.

        ``fail(chunk, device)`` simulates a device failure: the chunk is
        re-queued instead of merged (used by tests + chaos drills).
        Failed and rejected chunks requeue at the *back* of ``pending``
        (RetryPolicy-capped, then quarantined to ``self.skipped``) so a
        poison chunk cannot starve the rest of the campaign.
        """
        devices = list(devices or jax.devices())
        n_done = 0
        batch = []
        while self.pending and len(batch) < (max_chunks or len(devices)):
            batch.append(self.pending.pop(0))
        requeue = []
        for i, ch in enumerate(batch):
            dev = devices[i % len(devices)]
            attempt = self.failures.get(ch.start_id, 0)
            try:
                if fail is not None and fail(ch, dev):
                    raise InjectedFault(
                        f"fail callback killed chunk {ch.start_id} on "
                        f"{device_label(dev)}")
                if self.injector is not None:
                    self.injector.check_dispatch(ch.start_id, attempt,
                                                 device_label(dev))
                    delay = self.injector.delay_for(ch.start_id, attempt)
                    if delay > 0:
                        # the synchronous simulator has no speculation to
                        # overlap with: a straggler simply takes longer
                        time.sleep(delay)
                harvest = harvest_result(self._run_chunk(ch, dev))
                if self.injector is not None and \
                        self.injector.corrupts(ch.start_id, attempt):
                    harvest = corrupt_harvest(harvest)
                if self.validate:
                    errs = validate_chunk(harvest, ch.count,
                                          self.max_residue_frac)
                    if errs:
                        raise InjectedFault(
                            f"chunk {ch.start_id} rejected by merge "
                            f"guard: {errs}")
            except InjectedFault as e:
                self._record_failure(ch, requeue, e)
                continue
            self._merge(ch, harvest)
            n_done += 1
        self.pending = self.pending + requeue
        return n_done

    def _record_failure(self, ch: Chunk, requeue: list,
                        err: BaseException) -> None:
        n = self.failures.get(ch.start_id, 0) + 1
        self.failures[ch.start_id] = n
        if self.policy.exhausted(n):
            self.skipped.append(ch)
            if self.tracer is not None:
                self.tracer.counter("resilience.chunk_quarantined", 1,
                                    chunk_start=ch.start_id,
                                    reason=str(err))
        else:
            self.n_retries += 1
            requeue.append(ch)
            if self.tracer is not None:
                self.tracer.counter("resilience.retries", 1,
                                    chunk_start=ch.start_id)

    def run_to_completion(self, devices=None) -> SimResult:
        while self.pending:
            self.run_round(devices)
        return self.result()

    def _run_chunk(self, ch: Chunk, dev: jax.Device) -> SimResult:
        vol = self.volume
        lo, hi = split_id64(ch.start_id)
        span = None
        if self.tracer is not None:
            span = self.tracer.span("chunk", device=dev, engine=self.engine,
                                    photons=ch.count,
                                    chunk_start=ch.start_id)
        res = self._jit(
            jax.device_put(vol.labels.reshape(-1), dev),
            jax.device_put(vol.media, dev),
            ch.count, self.seed, lo, hi,
        )
        if span is not None:
            # block inside the span so the duration is the true chunk
            # time, not just the async dispatch
            jax.block_until_ready(res)
            span.end()
        return res

    def _merge(self, ch: Chunk, harvest: dict):
        """Merge one validated host-side harvest (resilience.validate),
        then auto-checkpoint and honor any injected host crash (the
        crash fires *after* the checkpoint, mimicking a host that dies
        between campaigns rather than mid-write — the atomic
        Checkpointer already covers torn writes)."""
        self.energy += harvest["energy"]
        self.exitance += harvest["exitance"]
        self.escaped_w += harvest["escaped_w"]
        self.timed_out_w += harvest["timed_out_w"]
        self.det_w += harvest["det_w"]
        self.det_ppath += harvest["det_ppath"]
        if harvest["det_rec"].size:
            self._det_rec_parts.append(harvest["det_rec"])
        self.det_rec_overflow += harvest["det_rec_overflow"]
        self.n_launched += harvest["n_launched"]
        self.launched_w += harvest["launched_w"]
        if self.stats is not None and harvest["stats"] is not None:
            self.stats = self.stats.add(harvest["stats"])
        self.completed.append(ch)
        n_merged = len(self.completed)
        if (self.checkpointer is not None and self.checkpoint_every
                and n_merged % self.checkpoint_every == 0):
            self.checkpointer.save(n_merged, self.state_dict(),
                                   extra={"kind": "elastic",
                                          "merged": n_merged})
            if self.tracer is not None:
                self.tracer.counter("resilience.checkpoint", n_merged)
        if self.injector is not None:
            self.injector.maybe_kill(n_merged)

    @property
    def det_rec(self) -> np.ndarray:
        """Accumulated (n, 4) uint32 detected-photon id records."""
        if len(self._det_rec_parts) != 1:
            merged = (np.concatenate(self._det_rec_parts, axis=0)
                      if self._det_rec_parts
                      else np.zeros((0, 4), np.uint32))
            self._det_rec_parts = [merged]
        return self._det_rec_parts[0]

    @det_rec.setter
    def det_rec(self, value):
        self._det_rec_parts = [np.asarray(value, np.uint32).reshape(-1, 4)]

    def result(self) -> SimResult:
        return SimResult(
            energy=jnp.asarray(self.energy),
            exitance=jnp.asarray(self.exitance),
            escaped_w=jnp.float32(self.escaped_w),
            timed_out_w=jnp.float32(self.timed_out_w),
            det_w=jnp.asarray(self.det_w),
            det_ppath=jnp.asarray(self.det_ppath),
            det_rec=jnp.asarray(self.det_rec),
            det_rec_n=jnp.int32(self.det_rec.shape[0]),
            det_rec_overflow=jnp.int32(self.det_rec_overflow),
            n_launched=jnp.int32(self.n_launched),
            launched_w=jnp.float32(self.launched_w),
            steps=jnp.int32(0),
            stats=self.stats,
        )

    # -- checkpoint / restart ------------------------------------------------

    def _source_key(self) -> str:
        """Canonical string for the source config.  Registered sources
        serialize via to_dict; custom protocol sources get a class-name
        sentinel (stable across process restarts, unlike repr/id) — it
        catches switching source *types* but not reparameterizing the
        same custom class."""
        from repro.sources import to_dict as _source_to_dict

        if hasattr(self.source, "type_name"):
            return json.dumps(_source_to_dict(self.source), sort_keys=True)
        return f"<custom:{type(self.source).__qualname__}>"

    def _detector_key(self) -> str:
        """Canonical string for the detector config (see DESIGN.md
        §time-resolved checkpoint notes): the TPSF histograms are only
        mergeable with chunks captured by the same detector set."""
        from repro.detectors import to_dicts

        return json.dumps(to_dicts(self.detectors), sort_keys=True)

    def state_dict(self) -> dict:
        extra = {}
        if self.stats is not None:
            # RoundStats totals checkpoint as one float64 vector in field
            # order (only present when cfg.collect_stats, so templates of
            # non-collecting runs are unchanged)
            extra["stats"] = np.asarray([float(v) for v in self.stats],
                                        np.float64)  # reprolint: disable=REP301 - checkpoint payload is f64
        return {
            **extra,
            "energy": self.energy.copy(),
            "exitance": self.exitance.copy(),
            "escaped_w": np.float64(self.escaped_w),  # reprolint: disable=REP301 - checkpoint payload is f64
            "timed_out_w": np.float64(self.timed_out_w),  # reprolint: disable=REP301 - checkpoint payload is f64
            "det_w": self.det_w.copy(),
            "det_ppath": self.det_ppath.copy(),
            "det_rec": self.det_rec.copy(),
            "det_rec_overflow": np.int64(self.det_rec_overflow),
            "n_launched": np.int64(self.n_launched),
            "launched_w": np.float64(self.launched_w),  # reprolint: disable=REP301 - checkpoint payload is f64
            "pending": np.asarray(
                [(c.start_id, c.count) for c in self.pending], np.int64
            ).reshape(-1, 2),
            "completed": np.asarray(
                [(c.start_id, c.count) for c in self.completed], np.int64
            ).reshape(-1, 2),
            "skipped": np.asarray(
                [(c.start_id, c.count) for c in self.skipped], np.int64
            ).reshape(-1, 2),
            "seed": np.int64(self.seed),
            "n_photons": np.int64(self.n_photons),
            # the grids are only mergeable with chunks from the same source /
            # detector set; stored as uint8-encoded strings so every leaf
            # stays a numeric array the Checkpointer can write to npz
            "source": np.frombuffer(self._source_key().encode(), np.uint8),
            "detectors": np.frombuffer(self._detector_key().encode(),
                                       np.uint8),
        }

    @staticmethod
    def _decode_key(raw) -> str:
        return (bytes(np.asarray(raw, np.uint8)).decode()
                if not isinstance(raw, str) else raw)

    def load_state_dict(self, state: dict):
        assert int(state["n_photons"]) == self.n_photons, "photon budget mismatch"
        assert int(state["seed"]) == self.seed, "seed mismatch"
        # "source"/"launched_w"/the PR-3 time-resolved keys may be absent
        # only in state dicts handed over directly (not via Checkpointer,
        # whose restore template requires every current key)
        if "source" in state:
            key = self._decode_key(state["source"])
            assert key == self._source_key(), (
                f"source mismatch: checkpoint {key} vs "
                f"simulator {self._source_key()}"
            )
        if "detectors" in state:
            key = self._decode_key(state["detectors"])
            assert key == self._detector_key(), (
                f"detector mismatch: checkpoint {key} vs "
                f"simulator {self._detector_key()}"
            )
        energy = np.asarray(state["energy"], np.float32)
        assert energy.shape == self.energy.shape, (
            f"energy grid mismatch (time gates?): checkpoint "
            f"{energy.shape} vs simulator {self.energy.shape}"
        )
        self.energy = energy.copy()
        self.exitance = np.asarray(state["exitance"], np.float32).copy()
        self.escaped_w = float(state["escaped_w"])
        self.timed_out_w = float(state.get("timed_out_w", 0.0))
        if "det_w" in state:
            self.det_w = np.asarray(state["det_w"], np.float32).copy()
            self.det_ppath = np.asarray(state["det_ppath"],
                                        np.float32).copy()
        if "det_rec" in state:
            self.det_rec = np.asarray(state["det_rec"],
                                      np.uint32).reshape(-1, 4).copy()
            self.det_rec_overflow = int(state.get("det_rec_overflow", 0))
        self.n_launched = int(state["n_launched"])
        self.launched_w = float(state.get("launched_w", state["n_launched"]))
        if self.stats is not None and "stats" in state:
            self.stats = RoundStats.from_vector(
                np.asarray(state["stats"], np.float64))  # reprolint: disable=REP301 - checkpoint payload is f64
        self.pending = [Chunk(int(s), int(c)) for s, c in state["pending"]]
        self.completed = [Chunk(int(s), int(c)) for s, c in state["completed"]]
        # pre-PR-7 state dicts have no skipped list; attempt counters
        # deliberately reset on restart (a restarted host gets a fresh
        # retry budget for transient faults)
        self.skipped = [Chunk(int(s), int(c))
                        for s, c in state.get("skipped", [])]
        self.failures = {}


def heterogeneous_partition(n_photons: int, models: Sequence[DeviceModel],
                            strategy: str = "S2") -> list[int]:
    """Convenience: partition a photon budget with a paper strategy."""
    from repro.core.loadbalance import PARTITIONERS

    return PARTITIONERS[strategy](n_photons, models)
