"""Voxelized heterogeneous media for photon transport.

A :class:`Volume` is a uint8 label grid plus a small table of optical
properties per label, exactly mirroring MCX's representation.  Label 0 is
*exterior* (air outside the simulation domain): photons that transmit
into label-0 voxels escape.

The three paper benchmarks (B1, B2, B2a) are provided as builders with
the published optical properties:

  * B1  — 60x60x60 mm homogeneous cube, mua=0.005/mm, mus=1.0/mm,
          g=0.01, n=1.37; photons terminate on the cube surface
          (no boundary reflection).
  * B2  — same cube with a radius-15 mm spherical inclusion at the
          center (mua=0.002, mus=5.0, g=0.9, n=1.0); Snell/Fresnel
          reflection at both the sphere and cube boundaries.
  * B2a — identical physics to B2; in the paper it differs only by using
          atomic fluence accumulation.  On TPU/JAX the scatter-add is
          race-free by construction, so B2a differs from B2 only in the
          accumulation *strategy* benchmarked (see DESIGN.md §atomics).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

C_MM_PER_NS = 299.792458  # speed of light in vacuum, mm/ns


@dataclasses.dataclass(frozen=True)
class Medium:
    """Optical properties of one tissue type."""

    mua: float  # absorption coefficient, 1/mm
    mus: float  # scattering coefficient, 1/mm
    g: float    # Henyey-Greenstein anisotropy
    n: float    # refractive index


AIR = Medium(mua=0.0, mus=0.0, g=1.0, n=1.0)


@dataclasses.dataclass(frozen=True)
class Volume:
    """Label grid + per-label optical property table.

    labels: (nx, ny, nz) uint8; media: (n_media, 4) float32 rows of
    (mua, mus, g, n).  ``unitinmm`` is the voxel edge length.
    """

    labels: jnp.ndarray
    media: jnp.ndarray
    unitinmm: float = 1.0

    @property
    def shape(self) -> tuple[int, int, int]:
        return tuple(self.labels.shape)

    @property
    def extent_mm(self) -> tuple[float, float, float]:
        return tuple(s * self.unitinmm for s in self.labels.shape)

    def with_media(self, media_list: list[Medium]) -> "Volume":
        return dataclasses.replace(self, media=pack_media(media_list))


def pack_media(media_list: list[Medium]) -> jnp.ndarray:
    rows = [[m.mua, m.mus, m.g, m.n] for m in media_list]
    return jnp.asarray(rows, dtype=jnp.float32)


def homogeneous_cube(
    shape: tuple[int, int, int],
    medium: Medium,
    unitinmm: float = 1.0,
) -> Volume:
    labels = jnp.ones(shape, dtype=jnp.uint8)
    return Volume(labels=labels, media=pack_media([AIR, medium]), unitinmm=unitinmm)


def cube_with_sphere(
    shape: tuple[int, int, int],
    background: Medium,
    inclusion: Medium,
    center_mm: tuple[float, float, float],
    radius_mm: float,
    unitinmm: float = 1.0,
) -> Volume:
    nx, ny, nz = shape
    # voxel centers in mm
    xs = (np.arange(nx) + 0.5) * unitinmm
    ys = (np.arange(ny) + 0.5) * unitinmm
    zs = (np.arange(nz) + 0.5) * unitinmm
    gx, gy, gz = np.meshgrid(xs, ys, zs, indexing="ij")
    r2 = (
        (gx - center_mm[0]) ** 2
        + (gy - center_mm[1]) ** 2
        + (gz - center_mm[2]) ** 2
    )
    labels = np.where(r2 <= radius_mm**2, 2, 1).astype(np.uint8)
    return Volume(
        labels=jnp.asarray(labels),
        media=pack_media([AIR, background, inclusion]),
        unitinmm=unitinmm,
    )


# ---------------------------------------------------------------------------
# Paper benchmark domains (Fig. 2 of Yu et al. 2017)
# ---------------------------------------------------------------------------

B1_MEDIUM = Medium(mua=0.005, mus=1.0, g=0.01, n=1.37)
B2_INCLUSION = Medium(mua=0.002, mus=5.0, g=0.9, n=1.0)


def benchmark_b1(shape: tuple[int, int, int] = (60, 60, 60)) -> Volume:
    """B1: homogeneous cube, photon terminates at the boundary."""
    return homogeneous_cube(shape, B1_MEDIUM)


def benchmark_b2(shape: tuple[int, int, int] = (60, 60, 60)) -> Volume:
    """B2/B2a: cube with centered spherical inclusion, boundary reflection."""
    center = tuple(s / 2.0 for s in shape)
    radius = shape[0] / 4.0  # 15 mm for the 60 mm cube of the paper
    return cube_with_sphere(shape, B1_MEDIUM, B2_INCLUSION, center, radius)


@dataclasses.dataclass(frozen=True)
class Source:
    """Legacy pencil-beam source (the paper's configuration).

    Kept for backward compatibility; anywhere a source is accepted this
    is coerced to ``repro.sources.Pencil`` (bit-identical results).
    Prefer the registered source types in ``repro.sources``.
    """

    pos: tuple[float, float, float] = (30.0, 30.0, 0.0)
    dir: tuple[float, float, float] = (0.0, 0.0, 1.0)

    def pos_array(self) -> jnp.ndarray:
        return jnp.asarray(self.pos, dtype=jnp.float32)

    def dir_array(self) -> jnp.ndarray:
        d = np.asarray(self.dir, dtype=np.float64)  # reprolint: disable=REP301 - f64 normalize, f32 result
        d = d / np.linalg.norm(d)
        return jnp.asarray(d, dtype=jnp.float32)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Physics / termination configuration for a simulation run.

    ``do_reflect`` toggles Snell/Fresnel handling at refractive-index
    mismatches (False for B1, True for B2/B2a).  ``deposit_mode``
    selects exact Beer-Lambert deposition (``"exact"``) or the
    first-order native-math variant (``"taylor"``, the Opt1 analogue).

    ``steps_per_round`` (K) fuses K transport segments into one outer
    loop iteration (DESIGN.md §rounds): photon regeneration runs once
    per round and deposition / exitance / escape are flushed to the
    global grids once per round instead of per segment.  Trajectories
    are bit-identical for any K (only fp accumulation order changes);
    K=1 reproduces the unfused engine exactly.

    ``n_time_gates`` bins deposited energy over time-of-flight into
    equal gates of width ``tmax_ns / n_time_gates`` (DESIGN.md
    §time-resolved).  The default 1 is the continuous-wave special case
    and is bit-identical to the ungated engine; any larger value only
    widens the accumulator — trajectories never depend on it.

    ``collect_stats`` threads a ``telemetry.RoundStats`` accumulator
    through the round loop (DESIGN.md §observability): per-round
    live-lane counts, relaunch counts, and deposited/escaped/timed-out/
    detected weight, returned on ``SimResult.stats``.  The counters are
    pure extra reductions over values the engines already compute —
    every physics output stays bit-identical (asserted in tests) and
    the overhead is budgeted in BENCH_fused.json.
    """

    do_reflect: bool = False
    tmax_ns: float = 5.0
    w_threshold: float = 1e-4
    roulette_m: float = 10.0
    deposit_mode: str = "exact"  # "exact" | "taylor" (Opt1 analogue)
    specialize: bool = True      # Opt3 analogue: trace-time kernel specialization
    max_steps: int = 500_000     # hard cap on lock-step iterations
    steps_per_round: int = 1     # K: fused segments per outer iteration
    n_time_gates: int = 1        # time-resolved fluence gates over [0, tmax_ns]
    collect_stats: bool = False  # accumulate RoundStats onto SimResult.stats

    @property
    def gate_width_ns(self) -> float:
        """Width of one time gate: the CW case is a single all-covering gate."""
        return self.tmax_ns / self.n_time_gates


def b1_config() -> SimConfig:
    return SimConfig(do_reflect=False)


def b2_config() -> SimConfig:
    return SimConfig(do_reflect=True)
