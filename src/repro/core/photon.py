"""Hop-drop-spin photon transport physics, vectorized over lanes.

This is the JAX port of the MCX-CL simulation kernel (Fig. 1 of the
paper; the per-photon loop of Fang & Boas 2009).  One call to
:func:`step` advances every lane by one *segment*: the photon moves to
either its next scattering site or the next voxel boundary, whichever
comes first, deposits absorbed energy along the way, and then scatters
(Henyey-Greenstein) or crosses the boundary (Snell/Fresnel or escape).

GPU -> TPU adaptation notes (see DESIGN.md):
  * The OpenCL kernel's per-thread while-loop with divergent branches
    becomes a lock-step masked step over N lanes.  Thread divergence
    (62% in the paper's profile) turns into masked-lane waste; we reduce
    it with photon *regeneration* (simulator.py) — the paper's
    workgroup-level dynamic load balancing, moved into the vector lanes.
  * Every step draws a FIXED number of uniforms (5) regardless of the
    path taken, so trajectories are bit-reproducible across the pure-jnp
    oracle, the specialized step, and the Pallas kernel.
  * The paper's optimizations map as follows:
      Opt1 (native math)      -> cfg.deposit_mode == "taylor" (first-order
                                 Beer-Lambert, one fewer transcendental per
                                 segment) — hardware-dependent-accuracy math.
      Opt2 (thread config)    -> lane-count autotuning (simulator.py).
      Opt3 (control-flow
            simplification)   -> cfg.specialize: trace-time specialization
                                 of the kernel to the benchmark config.  The
                                 unspecialized baseline keeps the *general*
                                 kernel alive in the graph via traced flags
                                 (reflection/refraction math always present),
                                 mirroring the paper's "complex kernel"
                                 baseline that the JIT compiler struggles
                                 to optimize.

Positions are kept in *voxel units* (as MCX does); optical coefficients
are scaled by ``unitinmm`` on entry.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import rng as xrng
from repro.core.volume import C_MM_PER_NS, SimConfig

# plain Python floats (not jnp scalars): the Pallas kernel traces
# photon.step inside its body, and captured jnp constants are rejected
_EPS_STEP = 1e-4   # minimum-progress guard, voxel units
_INF = 1e30
_DIR_EPS = 1e-9

# Escape positions within this distance (voxel units) of the z=0 plane
# count as exits through the illuminated face and are binned into the 2-D
# exitance (diffuse reflectance) image.  Exit positions land exactly on a
# voxel wall up to fp32 rounding, so any value in (0, 1) separates the
# z=0 face from the z=1 wall; a quarter voxel leaves slack on both sides.
# Shared with the exitance binning in simulator.py.
Z_EXIT_FACE_VOX = 0.25


class PhotonState(NamedTuple):
    pos: jnp.ndarray     # (N, 3) float32, voxel units
    dir: jnp.ndarray     # (N, 3) float32, unit vectors
    ivox: jnp.ndarray    # (N, 3) int32 — authoritative voxel index.  Carried
    #                      explicitly (as MCX does) instead of floor(pos):
    #                      grazing rays can land on a wall where the crossing-
    #                      axis nudge is below fp32 resolution, freezing
    #                      floor(pos) and the photon with it.
    w: jnp.ndarray       # (N,)  float32 packet weight
    s_left: jnp.ndarray  # (N,)  float32 remaining dimensionless scat. length
    t: jnp.ndarray       # (N,)  float32 elapsed time, ns
    rng: jnp.ndarray     # (N, 4) uint32 xorshift128 state
    alive: jnp.ndarray   # (N,)  bool


class StepResult(NamedTuple):
    state: PhotonState
    dep_idx: jnp.ndarray  # (N,) int32 flat voxel index of deposition
    dep_w: jnp.ndarray    # (N,) float32 deposited weight (0 for dead lanes)
    esc_w: jnp.ndarray    # (N,) float32 weight escaping the domain this step
    esc_pos: jnp.ndarray  # (N, 3) float32 exit position (voxel units)
    dep_t: jnp.ndarray    # (N,) float32 photon time at deposit (end of the
    #                       segment, ns) — the time the gate index is
    #                       computed from (DESIGN.md §time-resolved)
    seg_med: jnp.ndarray  # (N,) int32 medium label of the segment's voxel
    seg_len: jnp.ndarray  # (N,) float32 segment length in mm (0: dead lanes)
    timed_w: jnp.ndarray  # (N,) float32 weight retired by the tmax_ns gate
    #                       this step (deterministic loss, tracked apart
    #                       from the statistical roulette residue)


def launch(pos, direc, w0, rng, active, shape) -> PhotonState:
    """Assemble fresh photons from per-lane source samples.

    ``pos``/``direc``/``w0``/``rng`` come from a source's
    ``sample(photon_ids, seed)`` (repro.sources): per-lane positions and
    unit directions, initial packet weights, and the counter-seeded
    in-flight RNG state.  ``active`` masks lanes that have no photon to
    simulate.

    Sources are expected to lie within the domain; a sampled position
    outside ``[0, shape]`` (e.g. the tail of a wide Gaussian beam, or a
    disk overhanging a face) is clamped onto the domain boundary so
    ``pos`` and the voxel index stay geometrically consistent — without
    the position clamp a lane could carry an in-bounds ``ivox`` with an
    exterior ``pos`` and mis-deposit along a wall it never crossed.  For
    in-domain sources (including ones sitting exactly on a face, like
    the default pencil) both clamps are no-ops.
    """
    pos = jnp.clip(jnp.asarray(pos, jnp.float32), 0.0,
                   jnp.asarray(shape, jnp.float32))
    direc = jnp.asarray(direc, jnp.float32)
    n = pos.shape[0]
    bounds = jnp.asarray(shape, jnp.int32) - 1
    ivox = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, bounds)
    return PhotonState(
        pos=pos,
        dir=direc,
        ivox=ivox,
        w=jnp.where(active, w0, 0.0).astype(jnp.float32),
        s_left=jnp.zeros((n,), jnp.float32),
        t=jnp.zeros((n,), jnp.float32),
        rng=rng,
        alive=active,
    )


def exitance_bins(esc_pos, esc_w, shape):
    """Bin z=0-face escapes into the flat (nx*ny) exitance image.

    Returns ``(flat_xy, w)``: a flat 2-D bin index per lane and the
    weight to deposit there (0 for lanes that did not exit through the
    illuminated face).  Shared by the engine, the pure-jnp oracle and
    the Pallas kernel so all three bin identically.
    """
    nx, ny, _ = shape
    z_exit = esc_pos[:, 2] < Z_EXIT_FACE_VOX
    hit = (esc_w > 0) & z_exit
    ex = jnp.clip(jnp.floor(esc_pos[:, 0]).astype(jnp.int32), 0, nx - 1)
    ey = jnp.clip(jnp.floor(esc_pos[:, 1]).astype(jnp.int32), 0, ny - 1)
    return ex * ny + ey, jnp.where(hit, esc_w, 0.0)


def time_gate_bins(dep_t, tmax_ns, n_time_gates):
    """Time-gate index for a deposit at photon time ``dep_t`` (ns).

    Gates split ``[0, tmax_ns]`` into ``n_time_gates`` equal bins; the
    index is computed *at deposit time* from the photon's elapsed
    time-of-flight at the end of the segment, so the 4-D accumulator can
    be scattered in the same pass as the CW grid (DESIGN.md
    §time-resolved).  Deposits from the partial segment that crosses
    ``tmax_ns`` clip into the last gate (the ungated engine keeps that
    energy, and ``n_time_gates=1`` must stay bit-identical to it).

    Shared by the engine, the pure-jnp oracle and the Pallas kernel so
    all three bin identically.
    """
    inv_gate = float(n_time_gates) / float(tmax_ns)
    g = jnp.floor(dep_t * jnp.float32(inv_gate)).astype(jnp.int32)
    return jnp.clip(g, 0, n_time_gates - 1)


def _lookup_label(labels_flat, shape, ivox):
    nx, ny, nz = shape
    ix = jnp.clip(ivox[..., 0], 0, nx - 1)
    iy = jnp.clip(ivox[..., 1], 0, ny - 1)
    iz = jnp.clip(ivox[..., 2], 0, nz - 1)
    flat = (ix * ny + iy) * nz + iz
    return jnp.take(labels_flat, flat, axis=0), flat


def _boundary_distance(pos, direc, ivox):
    """Distance (voxel units) to the voxel wall along each axis + crossing axis."""
    fvox = ivox.astype(jnp.float32)
    d_pos = (fvox + 1.0 - pos) / jnp.where(direc > _DIR_EPS, direc, 1.0)
    d_neg = (fvox - pos) / jnp.where(direc < -_DIR_EPS, direc, 1.0)
    dists = jnp.where(
        direc > _DIR_EPS, d_pos, jnp.where(direc < -_DIR_EPS, d_neg, _INF)
    )
    dists = jnp.maximum(dists, 0.0)
    d_min = jnp.min(dists, axis=-1)
    axis = jnp.argmin(dists, axis=-1).astype(jnp.int32)
    return d_min, axis


def _hg_scatter(direc, g, u_cos, u_phi):
    """Henyey-Greenstein direction update (MCML rotation formulas)."""
    g = g.astype(jnp.float32)
    small_g = jnp.abs(g) < 1e-5
    g_safe = jnp.where(small_g, 1.0, g)
    frac = (1.0 - g_safe * g_safe) / (1.0 - g_safe + 2.0 * g_safe * u_cos)
    cost_hg = (1.0 + g_safe * g_safe - frac * frac) / (2.0 * g_safe)
    cost = jnp.where(small_g, 2.0 * u_cos - 1.0, cost_hg)
    cost = jnp.clip(cost, -1.0, 1.0)
    sint = jnp.sqrt(jnp.maximum(1.0 - cost * cost, 0.0))
    phi = (2.0 * jnp.pi) * u_phi
    cosp = jnp.cos(phi)
    sinp = jnp.sin(phi)

    ux, uy, uz = direc[..., 0], direc[..., 1], direc[..., 2]
    near_pole = jnp.abs(uz) > 0.99999
    # general rotation
    tmp = jnp.sqrt(jnp.maximum(1.0 - uz * uz, 1e-12))
    nx = sint * (ux * uz * cosp - uy * sinp) / tmp + ux * cost
    ny = sint * (uy * uz * cosp + ux * sinp) / tmp + uy * cost
    nz = -sint * cosp * tmp + uz * cost
    # polar special case
    px = sint * cosp
    py = sint * sinp
    pz = cost * jnp.sign(uz)
    out = jnp.stack(
        [
            jnp.where(near_pole, px, nx),
            jnp.where(near_pole, py, ny),
            jnp.where(near_pole, pz, nz),
        ],
        axis=-1,
    )
    # renormalize to fight fp drift
    norm = jnp.sqrt(jnp.sum(out * out, axis=-1, keepdims=True))
    return out / jnp.maximum(norm, 1e-12)


def _fresnel(n_i, n_t, cos_i):
    """Unpolarized Fresnel reflectance + transmitted cosine.

    Returns (R, cos_t, tir_mask).  cos_i must be in [0, 1].
    """
    cos_i = jnp.clip(cos_i, 0.0, 1.0)
    eta = n_i / jnp.maximum(n_t, 1e-6)
    sin2_t = eta * eta * jnp.maximum(1.0 - cos_i * cos_i, 0.0)
    tir = sin2_t >= 1.0
    cos_t = jnp.sqrt(jnp.maximum(1.0 - sin2_t, 0.0))
    rs_num = n_i * cos_i - n_t * cos_t
    rs_den = n_i * cos_i + n_t * cos_t
    rp_num = n_i * cos_t - n_t * cos_i
    rp_den = n_i * cos_t + n_t * cos_i
    rs = (rs_num / jnp.where(jnp.abs(rs_den) < 1e-12, 1.0, rs_den)) ** 2
    rp = (rp_num / jnp.where(jnp.abs(rp_den) < 1e-12, 1.0, rp_den)) ** 2
    r = jnp.where(tir, 1.0, 0.5 * (rs + rp))
    return jnp.clip(r, 0.0, 1.0), cos_t, tir


def step(state, labels_flat, media, shape, unitinmm, cfg: SimConfig) -> StepResult:
    """Advance every lane by one segment.

    With ``cfg.specialize`` (Opt3) the kernel is specialized at trace
    time to the benchmark's physics config; otherwise the general kernel
    (reflection machinery always live, driven by traced flags) is
    compiled, mirroring the paper's unsimplified baseline kernel.
    """
    pos, direc, ivox, w, s_left, t, rstate, alive = state
    unitinmm = jnp.float32(unitinmm)
    nx, ny, nz = shape

    label, _ = _lookup_label(labels_flat, shape, ivox)
    props = jnp.take(media, label.astype(jnp.int32), axis=0)  # (N, 4)
    mua = props[:, 0] * unitinmm
    mus = props[:, 1] * unitinmm
    g = props[:, 2]
    n_cur = props[:, 3]

    # --- draw the per-step uniforms (fixed count: reproducibility) ---
    rstate, u_path = xrng.next_uniform(rstate)
    rstate, u_cos = xrng.next_uniform(rstate)
    rstate, u_phi = xrng.next_uniform(rstate)
    rstate, u_fres = xrng.next_uniform(rstate)
    rstate, u_roul = xrng.next_uniform(rstate)

    # --- HOP: distance to scattering site vs voxel wall ---
    need_draw = s_left <= 0.0
    s_left = jnp.where(need_draw, -jnp.log(u_path), s_left)

    d_wall, cross_axis = _boundary_distance(pos, direc, ivox)
    mus_safe = jnp.maximum(mus, 1e-9)
    d_scat = s_left / mus_safe
    ballistic = mus <= 1e-9  # non-scattering medium: fly to the wall
    d_scat = jnp.where(ballistic, _INF, d_scat)

    hits_wall = d_wall < d_scat
    seg = jnp.where(hits_wall, d_wall, d_scat)
    seg = jnp.maximum(seg, _EPS_STEP * 0.01)

    new_pos = pos + direc * seg[:, None]
    s_left = jnp.where(hits_wall, s_left - seg * mus, 0.0)
    t_new = t + seg * unitinmm * n_cur / C_MM_PER_NS

    # --- DROP: Beer-Lambert deposition into the current voxel ---
    tau = mua * seg
    if cfg.specialize:
        # Opt3: trace-time choice — only one math path in the graph.
        if cfg.deposit_mode == "taylor":
            dep = w * jnp.minimum(tau, 1.0)   # Opt1: first-order, no exp()
            w_after = w - dep
        else:
            w_after = w * jnp.exp(-tau)
            dep = w - w_after
    else:
        # General kernel: both paths compiled, selected by a traced flag.
        use_taylor = jnp.bool_(cfg.deposit_mode == "taylor")
        dep_taylor = w * jnp.minimum(tau, 1.0)
        w_exact = w * jnp.exp(-tau)
        dep = jnp.where(use_taylor, dep_taylor, w - w_exact)
        w_after = w - dep

    dep_flat = (
        jnp.clip(ivox[:, 0], 0, nx - 1) * ny + jnp.clip(ivox[:, 1], 0, ny - 1)
    ) * nz + jnp.clip(ivox[:, 2], 0, nz - 1)
    dep_w = jnp.where(alive, dep, 0.0)

    # --- SPIN: HG scatter for lanes that reached their scattering site ---
    scat_dir = _hg_scatter(direc, g, u_cos, u_phi)
    is_scatter = alive & ~hits_wall

    # --- BOUNDARY: next voxel, Fresnel, escape ---
    axis_onehot = jnp.eye(3, dtype=jnp.int32)[cross_axis]  # (N, 3)
    axis_f = axis_onehot.astype(jnp.float32)
    dir_axis = jnp.sum(direc * axis_f, axis=-1)
    sgn = jnp.sign(dir_axis).astype(jnp.int32)
    next_vox = ivox + axis_onehot * sgn[:, None]
    oob = (
        (next_vox[:, 0] < 0) | (next_vox[:, 0] >= nx)
        | (next_vox[:, 1] < 0) | (next_vox[:, 1] >= ny)
        | (next_vox[:, 2] < 0) | (next_vox[:, 2] >= nz)
    )
    next_label, _ = _lookup_label(labels_flat, shape, next_vox)
    next_label = jnp.where(oob, 0, next_label)
    n_next = jnp.take(media, next_label.astype(jnp.int32), axis=0)[:, 3]
    mismatch = jnp.abs(n_next - n_cur) > 1e-6
    cos_i = jnp.abs(dir_axis)

    if cfg.specialize and not cfg.do_reflect:
        # B1-style specialized kernel: no Fresnel/refraction in the graph.
        reflects = jnp.zeros_like(hits_wall)
        new_dir_boundary = direc
    else:
        refl_r, cos_t, _tir = _fresnel(n_cur, n_next, cos_i)
        do_reflect_flag = (
            True if (cfg.specialize and cfg.do_reflect)
            else jnp.bool_(cfg.do_reflect)
        )
        reflects = hits_wall & mismatch & (u_fres < refl_r) & do_reflect_flag
        # reflected direction: flip the crossing-axis component
        refl_dir = direc * (1.0 - 2.0 * axis_f)
        # transmitted (refracted): scale tangentials, set normal cosine
        eta = n_cur / jnp.maximum(n_next, 1e-6)
        trans_tan = direc * (1.0 - axis_f) * eta[:, None]
        trans_nrm = axis_f * (sgn.astype(jnp.float32) * cos_t)[:, None]
        trans_dir = trans_tan + trans_nrm
        tnorm = jnp.sqrt(jnp.sum(trans_dir * trans_dir, axis=-1, keepdims=True))
        trans_dir = trans_dir / jnp.maximum(tnorm, 1e-12)
        bend = mismatch & do_reflect_flag
        trans_dir = jnp.where(bend[:, None], trans_dir, direc)
        new_dir_boundary = jnp.where(reflects[:, None], refl_dir, trans_dir)

    crossing = alive & hits_wall
    new_dir = jnp.where(
        is_scatter[:, None],
        scat_dir,
        jnp.where(crossing[:, None], new_dir_boundary, direc),
    )

    escapes = crossing & ~reflects & (oob | (next_label == 0))
    esc_w = jnp.where(escapes, w_after, 0.0)
    esc_pos = new_pos

    # advance the authoritative voxel index on transmitting crossings
    advances = crossing & ~reflects & ~escapes
    new_ivox = jnp.where(advances[:, None], next_vox, ivox)

    # --- ROULETTE + time gate ---
    alive_after = alive & ~escapes
    low_w = alive_after & (w_after < cfg.w_threshold)
    survives = u_roul < (1.0 / cfg.roulette_m)
    w_final = jnp.where(
        low_w, jnp.where(survives, w_after * cfg.roulette_m, 0.0), w_after
    )
    alive_after = alive_after & ~(low_w & ~survives)
    # weight retired by the tmax_ns gate is a deterministic loss, not a
    # statistical roulette residue — report it separately so the energy
    # balance can distinguish the two (analysis.energy_balance)
    gate_kill = alive_after & (t_new > cfg.tmax_ns)
    alive_after = alive_after & ~gate_kill
    timed_w = jnp.where(gate_kill, w_final, 0.0)
    w_final = jnp.where(escapes, 0.0, w_final)

    new_state = PhotonState(
        pos=jnp.where(alive[:, None], new_pos, pos),
        dir=jnp.where(alive[:, None], new_dir, direc),
        ivox=jnp.where(alive[:, None], new_ivox, ivox),
        w=jnp.where(alive, w_final, w),
        s_left=jnp.where(alive, s_left, state.s_left),
        t=jnp.where(alive, t_new, t),
        rng=rstate,
        alive=alive_after,
    )
    return StepResult(
        state=new_state,
        dep_idx=dep_flat.astype(jnp.int32),
        dep_w=dep_w,
        esc_w=jnp.where(alive, esc_w, 0.0),
        esc_pos=esc_pos,
        dep_t=t_new,
        seg_med=label.astype(jnp.int32),
        seg_len=jnp.where(alive, seg * unitinmm, 0.0),
        timed_w=jnp.where(alive, timed_w, 0.0),
    )
