"""Solution post-processing + physics validation helpers.

``fluence_cw`` reproduces MCX's normalization: the continuous-wave
fluence distribution is the deposited energy divided by
(mua * voxel volume * photons launched).  The validation helpers are
used both by tests and by EXPERIMENTS.md to check the reproduction
against physics ground truth (energy conservation; effective
attenuation mu_eff = sqrt(3 mua (mua + mus'))) rather than against
vendor-specific wall-clock numbers, which do not transfer across
hardware.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.simulator import SimResult
from repro.core.volume import Volume


def fluence_cw(result: SimResult, volume: Volume) -> jnp.ndarray:
    """CW fluence (1/mm^2 per unit launched weight) from deposited energy.

    Normalizes by ``launched_w`` rather than the photon count so weighted
    launches (e.g. Planar pattern sources, w0 != 1) stay correctly scaled;
    the two coincide for unit-weight sources.
    """
    labels = volume.labels.astype(jnp.int32)
    mua = volume.media[:, 0][labels]  # (nx, ny, nz), 1/mm
    vvox = volume.unitinmm**3
    denom = jnp.maximum(mua * vvox * result.launched_w, 1e-20)
    return jnp.where(mua > 0, result.energy / denom, 0.0)


def energy_balance(result: SimResult) -> dict[str, float]:
    """Launched = absorbed + escaped (+ roulette/time-gate residue).

    Russian roulette is unbiased in expectation, so the balance holds
    statistically; the residue reported here quantifies it.
    """
    absorbed = float(jnp.sum(result.energy))
    escaped = float(result.escaped_w)
    launched = float(result.launched_w)
    return {
        "launched": launched,
        "absorbed": absorbed,
        "escaped": escaped,
        "residue": launched - absorbed - escaped,
        "residue_frac": (launched - absorbed - escaped) / max(launched, 1.0),
    }


def mu_eff_theory(mua: float, mus: float, g: float) -> float:
    """Diffusion-theory effective attenuation coefficient, 1/mm."""
    musp = mus * (1.0 - g)
    return float(np.sqrt(3.0 * mua * (mua + musp)))


def fit_axial_decay(result: SimResult, volume: Volume,
                    z_range: tuple[int, int],
                    axis_xy: tuple[int, int] | None = None) -> float:
    """Fit exp-decay slope of on-axis fluence vs depth; returns mu_fit (1/mm).

    For a pencil beam into a scattering half-space, diffusion theory gives
    Phi(z) ~ exp(-mu_eff r) / r with r = z + z0 (z0 ~ one transport mean
    free path, the equivalent isotropic source depth).  We therefore fit
    ln(Phi * r) vs z; without the 1/r correction the slope is inflated by
    ~1/z.  ``axis_xy`` is the beam axis in voxel coordinates (defaults to
    the volume center).
    """
    phi = np.asarray(fluence_cw(result, volume))
    nx, ny, _ = volume.shape
    # average a small on-axis neighborhood to reduce variance
    cx, cy = axis_xy if axis_xy is not None else (nx // 2, ny // 2)
    line = phi[cx - 2 : cx + 3, cy - 2 : cy + 3, :].mean(axis=(0, 1))
    z0, z1 = z_range
    zs = (np.arange(z0, z1) + 0.5) * volume.unitinmm
    labels = np.asarray(volume.labels)
    props = np.asarray(volume.media)[labels[cx, cy, (z0 + z1) // 2]]
    musp = props[1] * (1.0 - props[2])
    src_depth = 1.0 / max(musp, 1e-6)  # transport mfp, mm
    vals = line[z0:z1] * (zs + src_depth)
    good = vals > 0
    if good.sum() < 3:
        raise ValueError("not enough nonzero fluence samples to fit decay")
    slope, _ = np.polyfit(zs[good], np.log(vals[good]), 1)
    return float(-slope)
