"""Solution post-processing + physics validation helpers.

``fluence_cw`` reproduces MCX's normalization: the continuous-wave
fluence distribution is the deposited energy divided by
(mua * voxel volume * photons launched); for a time-resolved run it is
the gate-sum of ``fluence_td``.  ``tpsf`` extracts detector
time-point-spread functions from the capture histograms
(DESIGN.md §time-resolved).  The validation helpers are used both by
tests and by EXPERIMENTS.md to check the reproduction against physics
ground truth (energy conservation; effective attenuation
mu_eff = sqrt(3 mua (mua + mus'))) rather than against vendor-specific
wall-clock numbers, which do not transfer across hardware.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.simulator import SimResult
from repro.core.volume import SimConfig, Volume


def fluence_td(result: SimResult, volume: Volume) -> jnp.ndarray:
    """Time-resolved fluence per gate (1/mm^2 per unit launched weight).

    Returns ``(nx, ny, nz, ntg)``; a CW result (3-D ``energy``) is
    treated as a single all-covering gate, so ``fluence_td(...).sum(-1)``
    is ``fluence_cw`` for every gate count.  The per-gate normalization
    is the same as CW (the gate axis partitions deposition, it does not
    rescale it); divide by ``cfg.gate_width_ns`` for a fluence *rate*.
    """
    energy = result.energy
    if energy.ndim == 3:
        energy = energy[..., None]
    labels = volume.labels.astype(jnp.int32)
    mua = volume.media[:, 0][labels]  # (nx, ny, nz), 1/mm
    vvox = volume.unitinmm**3
    denom = jnp.maximum(mua * vvox * result.launched_w, 1e-20)
    return jnp.where((mua > 0)[..., None], energy / denom[..., None], 0.0)


def fluence_cw(result: SimResult, volume: Volume) -> jnp.ndarray:
    """CW fluence (1/mm^2 per unit launched weight) from deposited energy.

    The gate-sum of :func:`fluence_td` (bit-equal by construction, so
    time-resolved and CW runs share one normalization path).  Normalizes
    by ``launched_w`` rather than the photon count so weighted launches
    (e.g. Planar pattern sources, w0 != 1) stay correctly scaled; the
    two coincide for unit-weight sources.
    """
    return fluence_td(result, volume).sum(axis=-1)


def gate_times_ns(cfg: SimConfig) -> np.ndarray:
    """Gate-center times (ns) of the ``cfg.n_time_gates`` TPSF bins."""
    gw = cfg.gate_width_ns
    return (np.arange(cfg.n_time_gates) + 0.5) * gw


def tpsf(result: SimResult, cfg: SimConfig) -> tuple[np.ndarray, np.ndarray]:
    """Detector time-point-spread functions from the capture histogram.

    Returns ``(times_ns, tpsf)`` with ``times_ns`` the (ntg,)
    gate-center times and ``tpsf`` the (n_det, ntg) detected weight per
    unit launched weight per ns — the quantity diffuse-optics fits
    compare against analytic TPSF models.
    """
    det_w = np.asarray(result.det_w, np.float64)  # reprolint: disable=REP301 - host-side detector reduction
    if det_w.size and det_w.shape[1] != cfg.n_time_gates:
        raise ValueError(
            f"result has {det_w.shape[1]} gates but cfg.n_time_gates="
            f"{cfg.n_time_gates}")
    norm = max(float(result.launched_w), 1e-20) * cfg.gate_width_ns
    return gate_times_ns(cfg), det_w / norm


def detector_mean_ppath(result: SimResult) -> np.ndarray:
    """Mean per-medium partial pathlength (mm) of detected photons.

    (n_det, n_media); weight-weighted mean (MCX's convention for
    detected-photon statistics).  Rows of detectors that caught nothing
    are zero.
    """
    det_ppath = np.asarray(result.det_ppath, np.float64)  # reprolint: disable=REP301 - host-side detector reduction
    tot_w = np.asarray(result.det_w, np.float64).sum(axis=1, keepdims=True)  # reprolint: disable=REP301 - host-side detector reduction
    return np.where(tot_w > 0, det_ppath / np.maximum(tot_w, 1e-20), 0.0)


def rescale_detected(result: SimResult, volume: Volume,
                     new_mua: np.ndarray) -> np.ndarray:
    """First-order absorption re-scaling of detected weight.

    Given per-medium absorption coefficients ``new_mua`` (1/mm, one per
    media-table row), estimates each detector's total detected weight
    under the perturbed absorption without re-simulating, using the
    mean partial pathlengths:  w' = w * exp(-sum_m dmua_m * <L_m>).
    Exact for a single detected path; first-order in the path spread
    otherwise (the classic white-Monte-Carlo rescaling).
    Returns (n_det,) rescaled detected weight.
    """
    new_mua = np.asarray(new_mua, np.float64)  # reprolint: disable=REP301 - host-side rescaling math
    old_mua = np.asarray(volume.media, np.float64)[:, 0]  # reprolint: disable=REP301 - host-side rescaling math
    if new_mua.shape != old_mua.shape:
        raise ValueError(f"new_mua must have shape {old_mua.shape}")
    mean_l = detector_mean_ppath(result)            # (n_det, n_media)
    tot_w = np.asarray(result.det_w, np.float64).sum(axis=1)  # reprolint: disable=REP301 - host-side rescaling math
    return tot_w * np.exp(-mean_l @ (new_mua - old_mua))


def jacobian_medium_sums(jacobian, volume: Volume,
                         per_gate: bool = False) -> np.ndarray:
    """Aggregate a replay Jacobian over the voxels of each medium label.

    ``jacobian`` is the ``(nx, ny, nz, n_det)`` volume from
    ``repro.replay.replay_jacobian`` — or its gate-resolved
    ``(nx, ny, nz, n_det, ntg)`` variant; returns ``(n_det, n_media)``
    — the detected weight's first-order sensitivity to each *medium's*
    absorption coefficient (a gate-resolved Jacobian is summed over its
    gate axis first, since the gates partition the scatter).  With
    ``per_gate=True`` the gate axis of a gate-resolved Jacobian is kept:
    ``(n_det, ntg, n_media)`` — the time-gated partial-pathlength sums
    whose gate-sum recovers the ungated identity.

    By construction the ``(n_det, n_media)`` result equals the forward
    run's ``det_ppath`` (weight-weighted partial pathlength sums): each
    detected packet contributes ``w_exit * L_m`` to medium ``m`` in both
    quantities.  That identity is the replay subsystem's primary
    consistency check (DESIGN.md §replay), and it connects the Jacobian
    to :func:`rescale_detected`, whose first-order expansion is
    ``dW_d = -sum_m det_ppath[d, m] * dmua_m``.
    """
    jac = np.asarray(jacobian, np.float64)  # reprolint: disable=REP301 - host-side Jacobian reduction
    if jac.ndim not in (4, 5):
        raise ValueError(
            f"jacobian must be (nx, ny, nz, n_det[, ntg]), got shape "
            f"{jac.shape}")
    if per_gate and jac.ndim != 5:
        raise ValueError("per_gate=True requires a gate-resolved "
                         "(nx, ny, nz, n_det, ntg) Jacobian")
    labels = np.asarray(volume.labels).reshape(-1)
    n_media = volume.media.shape[0]
    trail = jac.shape[3:]                      # (n_det,) or (n_det, ntg)
    flat = jac.reshape(-1, *trail)
    out = np.zeros(trail + (n_media,), np.float64)  # reprolint: disable=REP301 - host-side Jacobian reduction
    for m in range(n_media):
        out[..., m] = flat[labels == m].sum(axis=0)
    if jac.ndim == 5 and not per_gate:
        out = out.sum(axis=1)                  # gate axis partitions J
    return out


def energy_balance(result: SimResult) -> dict[str, float]:
    """Launched = absorbed + escaped + timed_out (+ roulette residue).

    ``timed_out`` is the weight retired deterministically by the
    ``tmax_ns`` time gate and the ``max_steps`` cap — reported as its
    own line so ``residue_frac`` only measures the *statistical*
    Russian-roulette residue (unbiased in expectation), i.e. genuine
    conservation error.
    """
    absorbed = float(jnp.sum(result.energy))
    escaped = float(result.escaped_w)
    launched = float(result.launched_w)
    timed_out = float(result.timed_out_w)
    residue = launched - absorbed - escaped - timed_out
    return {
        "launched": launched,
        "absorbed": absorbed,
        "escaped": escaped,
        "timed_out": timed_out,
        "residue": residue,
        "residue_frac": residue / max(launched, 1.0),
    }


def mu_eff_theory(mua: float, mus: float, g: float) -> float:
    """Diffusion-theory effective attenuation coefficient, 1/mm."""
    musp = mus * (1.0 - g)
    return float(np.sqrt(3.0 * mua * (mua + musp)))


def fit_axial_decay(result: SimResult, volume: Volume,
                    z_range: tuple[int, int],
                    axis_xy: tuple[int, int] | None = None) -> float:
    """Fit exp-decay slope of on-axis fluence vs depth; returns mu_fit (1/mm).

    For a pencil beam into a scattering half-space, diffusion theory gives
    Phi(z) ~ exp(-mu_eff r) / r with r = z + z0 (z0 ~ one transport mean
    free path, the equivalent isotropic source depth).  We therefore fit
    ln(Phi * r) vs z; without the 1/r correction the slope is inflated by
    ~1/z.  ``axis_xy`` is the beam axis in voxel coordinates (defaults to
    the volume center); the on-axis averaging neighborhood is clamped to
    the volume, so beams within 2 voxels of an edge average a smaller
    patch instead of silently wrapping through a negative slice start.
    """
    phi = np.asarray(fluence_cw(result, volume))
    nx, ny, _ = volume.shape
    # average a small on-axis neighborhood to reduce variance, clamped so
    # an off-center beam axis never produces an empty or wrapped slice
    cx, cy = axis_xy if axis_xy is not None else (nx // 2, ny // 2)
    if not (0 <= cx < nx and 0 <= cy < ny):
        raise ValueError(f"axis_xy {(cx, cy)} outside volume {(nx, ny)}")
    x0, x1 = max(cx - 2, 0), min(cx + 3, nx)
    y0, y1 = max(cy - 2, 0), min(cy + 3, ny)
    line = phi[x0:x1, y0:y1, :].mean(axis=(0, 1))
    z0, z1 = z_range
    zs = (np.arange(z0, z1) + 0.5) * volume.unitinmm
    labels = np.asarray(volume.labels)
    props = np.asarray(volume.media)[labels[cx, cy, (z0 + z1) // 2]]
    musp = props[1] * (1.0 - props[2])
    src_depth = 1.0 / max(musp, 1e-6)  # transport mfp, mm
    vals = line[z0:z1] * (zs + src_depth)
    good = vals > 0
    if good.sum() < 3:
        raise ValueError("not enough nonzero fluence samples to fit decay")
    slope, _ = np.polyfit(zs[good], np.log(vals[good]), 1)
    return float(-slope)
