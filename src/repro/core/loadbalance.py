"""Device-level workload partitioning (paper Fig. 3b).

The paper models per-device runtime as ``T = a * n + T0`` (slope ``a``
per photon, fixed overhead ``T0``), fits (a, T0) from two pilot runs
(n1 = 1e6, n2 = 5e6 in the paper; scaled down here), and compares three
partitioning strategies for the total photon budget N:

  S1  proportional to core count (the naive baseline),
  S2  proportional to throughput 1/a,
  S3  the minimax linear program  min_T max_i (a_i n_i + T0_i)
      s.t. sum n_i = N  — the paper solves it with MATLAB ``fminimax``;
      we exploit monotonicity:  n_i(T) = max(0, (T - T0_i) / a_i) is
      nondecreasing in T, so the optimal T is found by bisection
      (waterfilling), no solver dependency.

The same machinery drives elastic re-partitioning: when the device set
changes mid-run, the remaining photon budget is re-partitioned over the
surviving devices (multidevice.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Fitted linear runtime model of one device (group).

    ``a`` must be a positive finite seconds-per-photon slope: the
    partitioners divide by it (S2's throughput, S3's waterfilling), so a
    zero/negative/NaN slope would silently produce negative or NaN
    shares that ``_largest_remainder_round`` then mangles into a
    nonsense partition.  Validated here so every entry point — hand-built
    models included — fails loudly instead.
    """

    name: str
    a: float      # seconds per photon
    t0: float     # fixed overhead, seconds
    cores: int = 1

    def __post_init__(self):
        if not (math.isfinite(self.a) and self.a > 0.0):
            raise ValueError(
                f"device model {self.name!r} needs a positive finite "
                f"seconds-per-photon slope, got a={self.a!r} — refit the "
                f"pilot (fit_pilot) with larger photon counts or more "
                f"repeats")
        if not (math.isfinite(self.t0) and self.t0 >= 0.0):
            raise ValueError(
                f"device model {self.name!r} needs a nonnegative finite "
                f"overhead, got t0={self.t0!r}")

    def predict(self, n: float) -> float:
        return self.a * max(n, 0.0) + (self.t0 if n > 0 else 0.0)

    @property
    def throughput(self) -> float:
        """Photons per second, ignoring overhead (the paper's 1/a)."""
        return 1.0 / self.a


def fit_pilot(ns: Sequence[float], times: Sequence[float], name: str = "dev",
              cores: int = 1) -> DeviceModel:
    """Fit T = a*n + T0.  Two points reproduce the paper; more -> lstsq."""
    if len(ns) != len(times) or len(ns) < 2:
        raise ValueError("need >= 2 pilot (n, time) pairs")
    if len(set(ns)) < 2:
        # a degenerate design (all pilot sizes equal) cannot fit a slope:
        # the two-point path would divide by zero and hand an inf/NaN
        # device model to partition_s3, whose bisection then never
        # converges — fail loudly at the fit instead
        raise ValueError(
            f"pilot sizes must contain at least two distinct photon "
            f"counts to fit a slope, got {list(ns)}")
    if len(ns) == 2:
        (n1, n2), (t1, t2) = ns, times
        a = (t2 - t1) / (n2 - n1)
        t0 = t1 - a * n1
    else:
        import numpy as np

        A = np.stack([np.asarray(ns, np.float64),  # reprolint: disable=REP301 - host-side lstsq on pilot timings
                      np.ones(len(ns))], axis=1)
        (a, t0), *_ = np.linalg.lstsq(
            A, np.asarray(times, np.float64), rcond=None)  # reprolint: disable=REP301 - host-side lstsq on pilot timings
    a = float(a)
    if not (math.isfinite(a) and a > 0.0):
        # a noisy pilot (e.g. the larger run timed *faster* than the
        # smaller one) fits a non-positive slope; the old silent
        # clamp-to-1e-12 made the device look ~infinitely fast and the
        # partitioners handed it essentially the whole photon budget —
        # fail loudly with the measurements instead
        raise ValueError(
            f"pilot fit for {name!r} produced a non-positive photon cost "
            f"slope a={a:.3g} (times {list(times)} s at photon counts "
            f"{list(ns)}): timing noise exceeded the signal — rerun the "
            f"pilot with larger photon counts, more repeats, or a warmed-up "
            f"device")
    return DeviceModel(name=name, a=a, t0=max(float(t0), 0.0), cores=cores)


def model_from_samples(samples: Sequence[tuple[float, float]],
                       name: str = "dev", cores: int = 1) -> DeviceModel | None:
    """Fit a DeviceModel from runtime ``(photons, seconds)`` samples.

    The shared fitting rule for measured-throughput feedback (telemetry
    ``fit_device_models``, the resilience pool's per-worker deadline
    models): samples spanning >= 2 distinct photon counts get the
    paper's full ``T = a*n + T0`` fit; equal-size samples (the common
    fixed chunk-size case) fall back to the aggregate-throughput model
    ``a = sum(T)/sum(n), t0 = 0``.  A degenerate fit (timing noise
    producing a non-positive slope) falls back the same way rather than
    raising — live feedback must tolerate noisy early samples.  Returns
    None when the samples carry no usable signal (no positive photon
    count or elapsed time).
    """
    ns = [float(n) for n, _ in samples]
    ts = [float(t) for _, t in samples]
    if len(set(ns)) >= 2:
        try:
            return fit_pilot(ns, ts, name=name, cores=cores)
        except ValueError:
            pass  # noisy fit: fall through to aggregate throughput
    total_n, total_t = sum(ns), sum(ts)
    if total_n <= 0 or total_t <= 0:
        return None
    return DeviceModel(name=name, a=total_t / total_n, t0=0.0, cores=cores)


def run_pilot(run_fn: Callable[[int], float], n1: int, n2: int,
              name: str = "dev", cores: int = 1) -> DeviceModel:
    """Fit a model by timing ``run_fn`` (returns wall seconds) at n1, n2."""
    t1 = run_fn(n1)
    t2 = run_fn(n2)
    return fit_pilot([n1, n2], [t1, t2], name=name, cores=cores)


def _largest_remainder_round(fractions: Sequence[float], total: int) -> list[int]:
    """Round nonnegative real shares to ints summing exactly to ``total``."""
    floors = [int(math.floor(f)) for f in fractions]
    deficit = total - sum(floors)
    order = sorted(
        range(len(fractions)), key=lambda i: fractions[i] - floors[i],
        reverse=True,
    )
    out = list(floors)
    for i in order[:deficit]:
        out[i] += 1
    return out


def partition_s1(n_total: int, devices: Sequence[DeviceModel]) -> list[int]:
    """S1: split proportional to stream-processor / core counts."""
    total_cores = sum(d.cores for d in devices)
    shares = [n_total * d.cores / total_cores for d in devices]
    return _largest_remainder_round(shares, n_total)


def partition_s2(n_total: int, devices: Sequence[DeviceModel]) -> list[int]:
    """S2: split proportional to measured throughput 1/a."""
    total_tp = sum(d.throughput for d in devices)
    shares = [n_total * d.throughput / total_tp for d in devices]
    return _largest_remainder_round(shares, n_total)


def partition_s3(n_total: int, devices: Sequence[DeviceModel],
                 iters: int = 60) -> list[int]:
    """S3: minimax makespan via bisection on the finish time T."""
    if n_total == 0:
        return [0] * len(devices)

    def photons_at(T: float) -> float:
        return sum(max(0.0, (T - d.t0) / d.a) for d in devices)

    lo = min(d.t0 for d in devices)
    hi = max(d.t0 for d in devices) + n_total * min(d.a for d in devices) + 1.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if photons_at(mid) >= n_total:
            hi = mid
        else:
            lo = mid
    shares = [max(0.0, (hi - d.t0) / d.a) for d in devices]
    scale = n_total / max(sum(shares), 1e-12)
    return _largest_remainder_round([s * scale for s in shares], n_total)


def makespan(partition: Sequence[int], devices: Sequence[DeviceModel]) -> float:
    """Predicted wall time of a partition = slowest device's finish time."""
    return max(d.predict(n) for d, n in zip(devices, partition))


def ideal_makespan(n_total: int, devices: Sequence[DeviceModel]) -> float:
    """The paper's 'ideal' bound: summed device speeds, zero overhead."""
    total_tp = sum(d.throughput for d in devices)
    return n_total / total_tp


PARTITIONERS = {"S1": partition_s1, "S2": partition_s2, "S3": partition_s3}
