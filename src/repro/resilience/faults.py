"""Deterministic, seeded fault injection (DESIGN.md §resilience).

Chaos testing a Monte Carlo scheduler only proves something if the
fault schedule is *reproducible*: the anchor property — "under any
injected fault schedule the final result is bit-identical to the
fault-free run" — needs the same faults to fire on every replay of a
failing seed.  :class:`FaultInjector` therefore derives every decision
from a counter-based hash of ``(seed, kind, chunk_id, attempt)``
(splitmix64, the same mixer family as ``repro.core.rng``), never from
wall-clock time, scheduling order, or Python's randomized ``hash``.
A chunk's fate on its k-th attempt is a pure function of the injector
config — independent of which worker picks it up or when.

Fault kinds (all off by default):

  * ``p_fail`` — the dispatch raises :class:`InjectedFault` (a device
    that died mid-chunk);
  * ``poison_chunks`` — chunk start-ids whose dispatch *always* fails
    (a deterministic poison pill; exercises retry caps + quarantine);
  * ``p_delay`` / ``delay_s`` — the result is withheld for ``delay_s``
    seconds after dispatch (a straggler; exercises deadlines +
    speculative re-dispatch).  The pool honors this as a non-blocking
    "not ready before t" gate so delayed workers overlap, mimicking a
    genuinely slow device rather than a frozen host;
  * ``p_nan`` — the completed chunk's energy grid is NaN-corrupted
    before the merge (a bad result; exercises ``validate_chunk``);
  * ``dropout`` — ``{worker_label: n}``: the labelled worker is
    permanently dropped once it has dispatched ``n`` chunks (a device
    leaving the fleet; exercises health states + re-partitioning);
  * ``kill_after_merges`` — raise :class:`InjectedCrash` once this many
    chunks have merged (a host crash; exercises checkpoint/restart).

Used by tests (tests/test_resilience.py), the resilience benchmark
(benchmarks/resilience.py) and the CLI ``--chaos`` drill.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


class InjectedFault(RuntimeError):
    """A deterministic, injector-scheduled device failure."""


class InjectedCrash(RuntimeError):
    """A deterministic, injector-scheduled host crash (checkpoint
    tests catch this, restore, and finish the campaign)."""


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Seeded chaos layer; every decision is replay-stable.

    All probabilities are per ``(chunk, attempt)`` pair, so a failed
    chunk's retry rolls a fresh — but deterministic — die: transient
    faults clear on retry, and only ``poison_chunks`` fail forever.
    """

    seed: int = 0
    p_fail: float = 0.0
    p_nan: float = 0.0
    p_delay: float = 0.0
    delay_s: float = 0.05
    poison_chunks: tuple[int, ...] = ()
    dropout: Mapping[str, int] = dataclasses.field(default_factory=dict)
    kill_after_merges: int | None = None

    def __post_init__(self):
        # JSON configs (--chaos) hand lists/dicts; normalize so the
        # injector stays hashable where it can be
        object.__setattr__(self, "poison_chunks",
                           tuple(int(c) for c in self.poison_chunks))
        object.__setattr__(self, "dropout",
                           {str(k): int(v)
                            for k, v in dict(self.dropout).items()})

    # -- the counter-based coin ---------------------------------------------

    def _uniform(self, kind: str, chunk_id: int, attempt: int) -> float:
        """Deterministic uniform in [0, 1) for one (kind, chunk, attempt)."""
        h = _splitmix64((int(self.seed) & _M64) ^ zlib.crc32(kind.encode()))
        h = _splitmix64(h ^ (int(chunk_id) & _M64))
        h = _splitmix64(h ^ (int(attempt) & _M64))
        return h / float(1 << 64)

    # -- dispatch-time faults -----------------------------------------------

    def check_dispatch(self, chunk_id: int, attempt: int,
                       worker: str = "") -> None:
        """Raise :class:`InjectedFault` if this (chunk, attempt) is
        scheduled to fail; called by the workers at dispatch time."""
        if chunk_id in self.poison_chunks:
            raise InjectedFault(
                f"poison chunk {chunk_id} (attempt {attempt}, "
                f"worker {worker or '?'})")
        if self.p_fail > 0.0 and \
                self._uniform("fail", chunk_id, attempt) < self.p_fail:
            raise InjectedFault(
                f"injected dispatch failure on chunk {chunk_id} "
                f"(attempt {attempt}, worker {worker or '?'})")

    def delay_for(self, chunk_id: int, attempt: int) -> float:
        """Seconds this (chunk, attempt) result is withheld (0 = none)."""
        if self.p_delay > 0.0 and \
                self._uniform("delay", chunk_id, attempt) < self.p_delay:
            return float(self.delay_s)
        return 0.0

    # -- result corruption ---------------------------------------------------

    def corrupts(self, chunk_id: int, attempt: int) -> bool:
        """True when this (chunk, attempt) result is scheduled for NaN
        corruption (applied by the caller to its host-side copy)."""
        return self.p_nan > 0.0 and \
            self._uniform("nan", chunk_id, attempt) < self.p_nan

    # -- fleet-level schedules ----------------------------------------------

    def dropped(self, worker_label: str, n_dispatched: int) -> bool:
        """True once ``worker_label`` has dispatched its scheduled
        number of chunks and must leave the fleet."""
        limit = self.dropout.get(worker_label)
        return limit is not None and n_dispatched >= limit

    def maybe_kill(self, n_merged: int) -> None:
        """Raise :class:`InjectedCrash` at the scheduled merge count."""
        if self.kill_after_merges is not None and \
                n_merged >= self.kill_after_merges:
            raise InjectedCrash(
                f"injected host crash after {n_merged} merged chunks")

    @property
    def active(self) -> bool:
        """Whether any fault kind is actually configured."""
        return bool(self.p_fail or self.p_nan or self.p_delay or
                    self.poison_chunks or self.dropout or
                    self.kill_after_merges is not None)
