"""Merge-guard validation of per-chunk results (DESIGN.md §resilience).

A corrupted chunk that reaches the host-side accumulator poisons the
whole campaign: one NaN voxel NaNs every downstream fluence sum, and a
silently short-launched chunk skews the normalization.  The schedulers
therefore harvest every chunk to host numpy first and run it through
:func:`validate_chunk` *before* merging; a rejected chunk is requeued
(bit-identical replay makes that free) instead of corrupting the
accumulator.

Checks, in order of cost:

  * scalar accounting is finite and a chunk launched exactly the
    photons it was assigned (``n_launched == chunk.count``);
  * every grid (energy, exitance, detector TPSF, partial pathlengths)
    is finite and non-negative — NaN/inf *or* negative-weight
    corruption is caught;
  * the per-chunk energy balance closes: ``launched_w = absorbed +
    escaped + timed_out + roulette residue`` with ``|residue| /
    launched_w <= max_residue_frac``.  The residue of a healthy chunk
    is the unbiased Russian-roulette leftover (|residue_frac| < 1e-4
    for the benchmark volumes); the default tolerance of 5e-3 leaves
    headroom for very small chunks while still rejecting any
    corruption large enough to matter.
"""

from __future__ import annotations

import numpy as np

# SimResult fields harvested to host numpy before validation/merge.
# det_rec is trimmed to its valid rows at harvest time so buffered
# copies don't pin the full capacity buffer.
_GRID_FIELDS = ("energy", "exitance", "det_w", "det_ppath")
_SCALAR_FIELDS = ("escaped_w", "timed_out_w", "launched_w")


def harvest_result(res) -> dict:
    """Copy one SimResult's fields to host numpy (blocks on readiness).

    Returns a plain dict the schedulers buffer, validate, and merge —
    detached from device memory so buffered out-of-order chunks don't
    hold device buffers alive.
    """
    out = {
        "energy": np.asarray(res.energy),
        "exitance": np.asarray(res.exitance),
        "escaped_w": float(res.escaped_w),
        "timed_out_w": float(res.timed_out_w),
        "det_w": np.asarray(res.det_w),
        "det_ppath": np.asarray(res.det_ppath),
        "det_rec": np.asarray(res.det_rec)[: int(res.det_rec_n)],
        "det_rec_overflow": int(res.det_rec_overflow),
        "n_launched": int(res.n_launched),
        "launched_w": float(res.launched_w),
        "steps": int(np.max(np.asarray(res.steps))),
        "stats": None,
    }
    if res.stats is not None:
        from repro.telemetry.stats import RoundStats

        out["stats"] = RoundStats(*(np.asarray(v) for v in res.stats))
    return out


def validate_chunk(harvest: dict, expected_photons: int | None = None,
                   max_residue_frac: float = 5e-3) -> list[str]:
    """Validate one harvested chunk; returns a list of defects (empty =
    the chunk is safe to merge)."""
    errs: list[str] = []
    for k in _SCALAR_FIELDS:
        if not np.isfinite(harvest[k]):
            errs.append(f"{k} is not finite ({harvest[k]!r})")
    if expected_photons is not None and \
            harvest["n_launched"] != int(expected_photons):
        errs.append(f"launched {harvest['n_launched']} photons, chunk "
                    f"assigned {int(expected_photons)}")
    for k in _GRID_FIELDS:
        a = harvest[k]
        if a.size == 0:
            continue
        if not np.isfinite(a).all():
            errs.append(f"{k} contains {int((~np.isfinite(a)).sum())} "
                        f"non-finite value(s)")
        elif float(a.min()) < 0.0:
            errs.append(f"{k} contains negative weight "
                        f"(min {float(a.min()):.3g})")
    if errs:
        # the residue check below would just re-report NaN arithmetic
        return errs
    launched = harvest["launched_w"]
    residue = (launched - float(harvest["energy"].sum())
               - harvest["escaped_w"] - harvest["timed_out_w"])
    frac = residue / max(launched, 1.0)
    if abs(frac) > max_residue_frac:
        errs.append(f"energy-balance residue {frac:.3e} of launched "
                    f"weight exceeds {max_residue_frac:.1e} "
                    f"(launched={launched:.4f}, "
                    f"absorbed={float(harvest['energy'].sum()):.4f}, "
                    f"escaped={harvest['escaped_w']:.4f}, "
                    f"timed_out={harvest['timed_out_w']:.4f})")
    return errs


def corrupt_harvest(harvest: dict) -> dict:
    """NaN-corrupt one harvested chunk (the FaultInjector's ``p_nan``
    fault, applied to the host-side copy so device results and other
    chunks are untouched)."""
    bad = dict(harvest)
    energy = harvest["energy"].copy()
    energy.flat[0] = np.nan
    bad["energy"] = energy
    return bad
