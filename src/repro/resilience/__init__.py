"""Fault-tolerant heterogeneous execution (DESIGN.md §resilience).

Public surface of the robustness layer: the device pool and its specs,
the retry/health policy, the merge-guard validators, and the seeded
chaos injector used by tests, benchmarks, and the CLI ``--chaos``
drill.
"""

from repro.resilience.faults import (FaultInjector, InjectedCrash,
                                     InjectedFault)
from repro.resilience.policy import (HEALTHY, QUARANTINED, SUSPECT,
                                     RetryPolicy)
from repro.resilience.pool import (ChunkQuarantinedError, DevicePool,
                                   DeviceSpec, PoolExhaustedError,
                                   PoolReport, Worker)
from repro.resilience.validate import (corrupt_harvest, harvest_result,
                                       validate_chunk)

__all__ = [
    "DevicePool", "DeviceSpec", "Worker", "PoolReport",
    "PoolExhaustedError", "ChunkQuarantinedError",
    "RetryPolicy", "HEALTHY", "SUSPECT", "QUARANTINED",
    "FaultInjector", "InjectedFault", "InjectedCrash",
    "validate_chunk", "harvest_result", "corrupt_harvest",
]
