"""Fault-tolerant heterogeneous device pool (DESIGN.md §resilience).

The paper's headline result runs *unequal* CPU+GPU devices together
under device-level load balancing; any such fleet serving long
campaigns will see stragglers, hangs, dropped devices, and corrupted
results.  :class:`DevicePool` is the robustness layer that lets the
chunked schedulers survive all of them:

  * **Heterogeneous workers** — each :class:`Worker` wraps one
    :class:`DeviceSpec` ``(device, engine, n_lanes)`` with its own
    compiled executor (per *bit-class* fn cache, shared by workers
    whose specs compile identically), so CPU-jnp and GPU/interpreted-
    Pallas workers coexist in one run.
  * **Retries with caps** — a failed dispatch or rejected result is
    requeued through :class:`repro.resilience.RetryPolicy` (exponential
    backoff, honored as a non-blocking eligibility gate); a chunk that
    exhausts its attempt budget is quarantined and recorded, never
    merged.
  * **Deadlines + speculation** — per-chunk deadlines derive from the
    worker's fitted ``loadbalance.DeviceModel`` (measured samples feed
    back as chunks complete); an overdue chunk is speculatively
    re-dispatched to another worker, the first valid result wins, and
    duplicates are discarded by chunk id.
  * **Validated merges** — every result is harvested to host numpy and
    run through :func:`repro.resilience.validate_chunk` (NaN/inf scan +
    per-chunk energy-balance residual) before it may touch the
    accumulator.
  * **Worker health** — healthy -> suspect -> quarantined, with
    graceful degradation down to one device; an empty pool raises
    :class:`PoolExhaustedError` with the full failure history.
  * **Deterministic merges** — valid results are buffered and merged in
    *chunk-id order* (a bounded reordering frontier), so the float
    accumulation order — and therefore every output bit — is
    independent of completion order, worker assignment and fault
    schedule.  Combined with engine binding (below) this makes the
    final result bit-identical to the fault-free run under any fault
    schedule.
  * **Engine binding** — per-chunk results are only bit-reproducible
    across workers of the same *bit-class* ``(engine, n_lanes, mode)``
    (engines agree to fp-accumulation order, not bitwise).  With
    ``bind_engines=True`` (default) each chunk is deterministically
    bound round-robin to one of the pool's bit-classes, so retries and
    speculation move a chunk only between bit-identical workers.  If a
    class loses its last live worker the chunk is re-bound to survive
    (counted in ``PoolReport.rebound`` — bit-identity degrades to
    engine-parity tolerance for exactly those chunks).
  * **Checkpoints** — every ``checkpoint_every`` merged chunks the
    contiguous merged prefix is saved through the atomic
    ``checkpoint.Checkpointer``; ``run(resume=True)`` restores it and
    only simulates the remainder.
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loadbalance import DeviceModel, model_from_samples
from repro.core.rng import split_id64
from repro.core.simulator import SimResult, build_sim_fn
from repro.core.volume import SimConfig, Source, Volume
from repro.detectors import as_detectors
from repro.resilience.faults import FaultInjector, InjectedFault
from repro.resilience.policy import (HEALTHY, QUARANTINED, SUSPECT,
                                     RetryPolicy)
from repro.resilience.validate import (corrupt_harvest, harvest_result,
                                       validate_chunk)
from repro.sources import PhotonSource, as_source
from repro.telemetry.stats import RoundStats
from repro.telemetry.trace import device_label


class PoolExhaustedError(RuntimeError):
    """Every worker has been quarantined/dropped with work remaining."""


class ChunkQuarantinedError(RuntimeError):
    """A chunk exhausted its retry budget (raise_on_quarantine=True)."""


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One worker's execution recipe: device + engine + lane count.

    ``device=None`` resolves to the default device.  ``label`` names the
    worker in reports, fault schedules (``FaultInjector.dropout``) and
    telemetry; it defaults to ``w<i>:<platform>:<id>``.  ``throttle_s``
    imposes a per-chunk latency floor — a *simulated* slow device, used
    by tests and benchmarks to build genuinely unequal fleets on
    identical host CPUs (the paper's unequal-device Fig. 8 setup,
    fake-device approximation).
    """

    device: Any = None
    engine: str = "jnp"
    n_lanes: int = 1024
    mode: str = "dynamic"
    label: str | None = None
    throttle_s: float = 0.0

    @property
    def bit_class(self) -> tuple:
        """Workers sharing this key produce bit-identical chunk results
        (same compiled computation; devices only change placement)."""
        return (self.engine, int(self.n_lanes), self.mode)


class Worker:
    """One pool member: a spec, its health, and its measured samples."""

    def __init__(self, spec: DeviceSpec, index: int):
        self.spec = spec
        self.device = spec.device if spec.device is not None \
            else jax.devices()[0]
        self.label = spec.label or f"w{index}:{device_label(self.device)}"
        self.health = HEALTHY
        self.consecutive_failures = 0
        self.n_dispatched = 0
        self.n_merged = 0
        self.photons_merged = 0
        self.failures = 0
        self.samples: list[tuple[float, float]] = []  # (photons, seconds)
        self.busy = False
        self._model: DeviceModel | None = None

    @property
    def bit_class(self) -> tuple:
        return self.spec.bit_class

    def record_sample(self, photons: int, seconds: float) -> None:
        if seconds > 0:
            self.samples.append((float(photons), float(seconds)))
            self._model = None  # refit lazily

    @property
    def model(self) -> DeviceModel | None:
        """Runtime model fitted from this worker's completed chunks
        (the measured-throughput feedback loop)."""
        if self._model is None and self.samples:
            self._model = model_from_samples(self.samples, name=self.label)
        return self._model

    def predict_s(self, photons: int) -> float | None:
        m = self.model
        return m.predict(photons) if m is not None else None

    def summary(self) -> dict:
        m = self.model
        return {
            "label": self.label,
            "device": device_label(self.device),
            "engine": self.spec.engine,
            "n_lanes": int(self.spec.n_lanes),
            "health": self.health,
            "chunks_merged": self.n_merged,
            "photons_merged": self.photons_merged,
            "dispatched": self.n_dispatched,
            "failures": self.failures,
            "photons_per_s": (m.throughput if m is not None else None),
        }


@dataclasses.dataclass
class PoolReport:
    """Resilience accounting of one :meth:`DevicePool.run`."""

    n_chunks: int = 0
    merged: int = 0
    retries: int = 0               # chunk re-entries into the queue
    speculative: int = 0           # deadline-triggered re-dispatches
    duplicates_discarded: int = 0  # late results for already-merged chunks
    validation_failures: int = 0   # results rejected by validate_chunk
    dispatch_failures: int = 0     # dispatches that raised
    injected_faults: int = 0       # ... of which were FaultInjector's
    rebound: int = 0               # chunks re-bound after class extinction
    workers_quarantined: int = 0   # workers dropped/quarantined mid-run
    checkpoints: int = 0
    wall_s: float = 0.0
    quarantined_chunks: list = dataclasses.field(default_factory=list)
    chunk_failures: dict = dataclasses.field(default_factory=dict)
    workers: list = dataclasses.field(default_factory=list)
    per_device_photons: dict = dataclasses.field(default_factory=dict)

    @property
    def quarantine_events(self) -> int:
        """Total quarantine events (poison chunks + lost workers)."""
        return len(self.quarantined_chunks) + self.workers_quarantined

    def counters(self) -> dict:
        """Flat numeric counters (telemetry sinks, benchmark JSON)."""
        return {
            "chunks": self.n_chunks,
            "merged": self.merged,
            "retries": self.retries,
            "speculative": self.speculative,
            "duplicates_discarded": self.duplicates_discarded,
            "validation_failures": self.validation_failures,
            "dispatch_failures": self.dispatch_failures,
            "injected_faults": self.injected_faults,
            "rebound": self.rebound,
            "quarantined_chunks": len(self.quarantined_chunks),
            "workers_quarantined": self.workers_quarantined,
            "quarantine_events": self.quarantine_events,
            "checkpoints": self.checkpoints,
            "wall_s": self.wall_s,
        }

    def to_dict(self) -> dict:
        return {**self.counters(),
                "quarantined": [(c.start_id, c.count)
                                for c in self.quarantined_chunks],
                "chunk_failures": dict(self.chunk_failures),
                "workers": list(self.workers)}


@dataclasses.dataclass
class _Chunk:
    start_id: int
    count: int


class _Task:
    """Per-chunk scheduler state."""

    __slots__ = ("chunk", "idx", "bound", "failures", "retry_at", "merged",
                 "quarantined", "inflight", "reasons", "last_error",
                 "harvest", "merged_by")

    def __init__(self, chunk, idx, bound):
        self.chunk = chunk
        self.idx = idx
        self.bound = bound          # bit-class this chunk is bound to
        self.failures = 0
        self.retry_at = 0.0
        self.merged = False
        self.quarantined = False
        self.inflight = 0
        self.reasons: list[str] = []
        self.last_error: BaseException | None = None
        self.harvest: dict | None = None   # valid result awaiting frontier
        self.merged_by: Worker | None = None


class _Inflight:
    __slots__ = ("task", "worker", "attempt", "result", "span", "t0",
                 "ready_at", "deadline", "speculated")

    def __init__(self, task, worker, attempt, result, span, t0, ready_at,
                 deadline):
        self.task = task
        self.worker = worker
        self.attempt = attempt
        self.result = result
        self.span = span
        self.t0 = t0
        self.ready_at = ready_at
        self.deadline = deadline
        self.speculated = False


class DevicePool:
    """Resilient chunk executor over heterogeneous device workers.

    ``specs`` defaults to one jnp worker per visible device.  See the
    module docstring for the full semantics; ``run()`` returns
    ``(SimResult, PoolReport)``.
    """

    def __init__(self, volume: Volume, cfg: SimConfig,
                 specs: Sequence[DeviceSpec] | None = None, *,
                 source: PhotonSource | Source | None = None,
                 detectors=None, record_detected: int = 0,
                 retry_policy: RetryPolicy | None = None,
                 fault_injector: FaultInjector | None = None,
                 validate: bool = True, max_residue_frac: float = 5e-3,
                 chunk_timeout_s: float | None = None,
                 deadline_factor: float = 4.0, deadline_slack_s: float = 1.0,
                 bind_engines: bool = True,
                 raise_on_quarantine: bool = True,
                 checkpointer=None, checkpoint_every: int = 0,
                 tracer=None):
        self.volume = volume
        self.cfg = cfg
        if specs is None:
            specs = [DeviceSpec(device=d) for d in jax.devices()]
        if not specs:
            raise ValueError("DevicePool needs at least one DeviceSpec")
        self.workers = [Worker(spec, i) for i, spec in enumerate(specs)]
        labels = [w.label for w in self.workers]
        if len(set(labels)) != len(labels):
            raise ValueError(f"worker labels must be unique, got {labels}")
        self.policy = retry_policy or RetryPolicy()
        self.injector = fault_injector
        self.validate = bool(validate)
        self.max_residue_frac = float(max_residue_frac)
        self.chunk_timeout_s = chunk_timeout_s
        self.deadline_factor = float(deadline_factor)
        self.deadline_slack_s = float(deadline_slack_s)
        self.bind_engines = bool(bind_engines)
        self.raise_on_quarantine = bool(raise_on_quarantine)
        self.checkpointer = checkpointer
        self.checkpoint_every = int(checkpoint_every)
        self.tracer = tracer
        self._default_source = as_source(source)
        self.detectors = as_detectors(detectors)
        self.record_detected = int(record_detected)
        self._labels = volume.labels.reshape(-1)
        self._media = volume.media
        # compiled executors shared per (source, bit-class); device
        # placement follows the device_put of the inputs
        self._fns: dict[tuple, Callable] = {}
        self._dev_buffers: dict[Any, tuple] = {}
        # deterministic class order for engine binding: list order of
        # first appearance in `specs`, so the binding — and therefore
        # the output bits — depends only on the spec list, never on
        # which workers survive
        self._classes: list[tuple] = []
        for w in self.workers:
            if w.bit_class not in self._classes:
                self._classes.append(w.bit_class)

    # -- executors -----------------------------------------------------------

    def _fn_for(self, source: PhotonSource, bit_class: tuple):
        key = (source, bit_class)
        if key not in self._fns:
            engine, n_lanes, mode = bit_class
            raw = build_sim_fn(self.volume.shape, self.volume.unitinmm,
                               self.cfg, n_lanes, mode, source, engine,
                               detectors=self.detectors,
                               record_detected=self.record_detected)
            self._fns[key] = jax.jit(raw)
        return self._fns[key]

    def _buffers_for(self, device):
        if device not in self._dev_buffers:
            self._dev_buffers[device] = (
                jax.device_put(self._labels, device),
                jax.device_put(self._media, device),
            )
        return self._dev_buffers[device]

    # -- fleet bookkeeping ---------------------------------------------------

    def live_workers(self) -> list[Worker]:
        return [w for w in self.workers if w.health != QUARANTINED]

    def _quarantine_worker(self, w: Worker, report: PoolReport,
                           reason: str) -> None:
        if w.health == QUARANTINED:
            return
        w.health = QUARANTINED
        report.workers_quarantined += 1
        if self.tracer is not None:
            self.tracer.counter("resilience.worker_quarantined", 1,
                                worker=w.label, reason=reason)

    def _mark_failure(self, w: Worker, report: PoolReport,
                      reason: str) -> None:
        w.failures += 1
        w.consecutive_failures += 1
        health = self.policy.health_for(w.consecutive_failures)
        if health == QUARANTINED:
            self._quarantine_worker(w, report, reason)
        else:
            w.health = health

    def _mark_success(self, w: Worker) -> None:
        w.consecutive_failures = 0
        if w.health == SUSPECT:
            w.health = HEALTHY

    # -- chunk failure routing ----------------------------------------------

    def _chunk_failed(self, task: _Task, report: PoolReport, reason: str,
                      now: float, pending: deque,
                      error: BaseException | None = None) -> None:
        task.failures += 1
        task.reasons.append(reason)
        if error is not None:
            task.last_error = error
        report.chunk_failures.setdefault(task.chunk.start_id,
                                         []).append(reason)
        if self.policy.exhausted(task.failures):
            task.quarantined = True
            report.quarantined_chunks.append(task.chunk)
            if self.tracer is not None:
                self.tracer.counter("resilience.chunk_quarantined", 1,
                                    chunk_start=task.chunk.start_id,
                                    reason=reason)
        else:
            task.retry_at = now + self.policy.backoff(task.failures)
            report.retries += 1
            if task.inflight == 0 and task not in pending:
                pending.append(task)  # back of the queue: no starvation

    # -- the run loop --------------------------------------------------------

    def run(self, n_photons: int, chunk_size: int, seed: int = 1234,
            source: PhotonSource | Source | None = None,
            deadline_s: float | None = None, id_offset: int = 0,
            resume: bool = False) -> tuple[SimResult, dict]:
        """Simulate ``n_photons`` in ``chunk_size`` chunks across the
        pool; returns ``(SimResult, PoolReport)``.

        ``deadline_s`` bounds the whole run (TimeoutError past it —
        never an unbounded busy-wait).  ``resume=True`` restores the
        newest auto-checkpoint (requires ``checkpointer``) and only
        simulates the chunks past its merged frontier.
        """
        t_start = time.monotonic()
        src = (as_source(source) if source is not None
               else self._default_source)
        chunks = [_Chunk(id_offset + s, min(chunk_size, n_photons - s))
                  for s in range(0, n_photons, chunk_size)]
        n_classes = len(self._classes) if self.bind_engines else 1
        tasks = [
            _Task(ch, i,
                  self._classes[i % n_classes] if self.bind_engines else None)
            for i, ch in enumerate(chunks)
        ]
        report = PoolReport(n_chunks=len(tasks))
        acc = self._zero_acc()
        frontier = 0
        if resume:
            frontier = self._restore(acc, tasks, n_photons, chunk_size,
                                     seed, src)
            for t in tasks[:frontier]:
                if not t.quarantined:
                    t.merged = True
                    report.merged += 1
        pending: deque[_Task] = deque(t for t in tasks if not t.merged
                                      and not t.quarantined)
        inflight: list[_Inflight] = []
        last_ckpt_merged = report.merged

        def all_done() -> bool:
            return all(t.merged or t.quarantined for t in tasks)

        while not all_done():
            now = time.monotonic()
            if deadline_s is not None and now - t_start > deadline_s:
                stuck = [(i.task.chunk.start_id, i.worker.label)
                         for i in inflight]
                raise TimeoutError(
                    f"pool run exceeded deadline_s={deadline_s}: "
                    f"{report.merged}/{len(tasks)} chunks merged, "
                    f"inflight {stuck}")
            progressed = False

            # scheduled device dropout (the chaos layer's fleet faults)
            if self.injector is not None:
                for w in self.live_workers():
                    if self.injector.dropped(w.label, w.n_dispatched):
                        self._quarantine_worker(w, report,
                                                "injected dropout")
                        progressed = True

            # harvest ready results
            for inf in list(inflight):
                if now < inf.ready_at or not inf.result.energy.is_ready():
                    continue
                inflight.remove(inf)
                inf.worker.busy = False
                inf.task.inflight -= 1
                progressed = True
                self._complete(inf, report, time.monotonic(), pending)

            # lost workers keep "computing" forever as far as the pool
            # is concerned; their inflight entries are abandoned and the
            # chunks requeued (unless already merged elsewhere)
            for inf in list(inflight):
                if inf.worker.health == QUARANTINED:
                    inflight.remove(inf)
                    inf.task.inflight -= 1
                    if inf.span is not None:
                        inf.span.end(outcome="abandoned")
                    if not (inf.task.merged or inf.task.quarantined
                            or inf.task.inflight > 0
                            or inf.task in pending):
                        inf.task.retry_at = 0.0
                        report.retries += 1
                        pending.appendleft(inf.task)
                    progressed = True

            # deadline scan: overdue chunks speculate on another worker
            for inf in inflight:
                if (inf.deadline is not None and not inf.speculated
                        and now - inf.t0 > inf.deadline
                        and not inf.task.merged):
                    inf.speculated = True
                    if inf.worker.health == HEALTHY:
                        inf.worker.health = SUSPECT
                    if inf.task.inflight == 1 and inf.task not in pending:
                        inf.task.retry_at = 0.0
                        report.speculative += 1
                        pending.appendleft(inf.task)
                        progressed = True
                        if self.tracer is not None:
                            self.tracer.counter(
                                "resilience.speculative_dispatch", 1,
                                chunk_start=inf.task.chunk.start_id,
                                worker=inf.worker.label)

            # merge the contiguous frontier (chunk-id order => the
            # accumulation order is schedule-independent)
            while frontier < len(tasks):
                t = tasks[frontier]
                if t.quarantined and t.harvest is None:
                    frontier += 1
                    continue
                if t.harvest is None:
                    break
                self._merge(acc, t, report)
                frontier += 1
                progressed = True
                if (self.checkpointer is not None and self.checkpoint_every
                        and report.merged - last_ckpt_merged
                        >= self.checkpoint_every):
                    self._save_checkpoint(acc, frontier, tasks, n_photons,
                                          chunk_size, seed, src, report)
                    last_ckpt_merged = report.merged
                if self.injector is not None:
                    # the injected host crash fires after the checkpoint
                    # (a host dying between saves; the atomic writer
                    # already covers torn files)
                    self.injector.maybe_kill(report.merged)

            live = self.live_workers()
            if not live and not all_done():
                raise PoolExhaustedError(
                    f"every worker is quarantined with "
                    f"{len(tasks) - report.merged} chunks unfinished; "
                    f"worker history: {[w.summary() for w in self.workers]}")

            # dispatch: healthy workers first, suspects as last resort
            for w in sorted((w for w in live if not w.busy),
                            key=lambda w: w.health != HEALTHY):
                task = self._next_task(pending, w, now)
                if task is None:
                    continue
                pending.remove(task)
                self._dispatch(w, task, seed, src, report, inflight,
                               pending)
                progressed = True

            if not progressed:
                time.sleep(5e-4)

        report.wall_s = time.monotonic() - t_start
        report.workers = [w.summary() for w in self.workers]
        for w in self.workers:
            did = w.device.id
            report.per_device_photons[did] = (
                report.per_device_photons.get(did, 0) + w.photons_merged)
        self._emit_counters(report)
        if report.quarantined_chunks and self.raise_on_quarantine:
            qc = report.quarantined_chunks[0]
            raise ChunkQuarantinedError(
                f"{len(report.quarantined_chunks)} chunk(s) exhausted "
                f"their {self.policy.max_attempts}-attempt budget; first: "
                f"chunk {qc.start_id} (+{qc.count}) after failures "
                f"{report.chunk_failures.get(qc.start_id)}"
            ) from tasks[[t.chunk for t in tasks].index(qc)].last_error
        return self._result(acc), report

    # -- dispatch / completion ----------------------------------------------

    def _next_task(self, pending: deque, w: Worker,
                   now: float) -> _Task | None:
        """First eligible pending task for this worker (binding-aware)."""
        for task in pending:
            if task.merged or task.quarantined or task.retry_at > now:
                continue
            if task.bound is not None and task.bound != w.bit_class:
                # the bound class may have lost its last worker; only
                # then may a foreign worker steal the chunk (bit-
                # identity degrades to engine parity for this chunk)
                if any(lw.bit_class == task.bound
                       for lw in self.live_workers()):
                    continue
                task.bound = w.bit_class
                self._report_rebound(task)
            return task
        return None

    def _report_rebound(self, task: _Task) -> None:
        self._rebound_count = getattr(self, "_rebound_count", 0) + 1
        if self.tracer is not None:
            self.tracer.counter("resilience.chunk_rebound", 1,
                                chunk_start=task.chunk.start_id)

    def _dispatch(self, w: Worker, task: _Task, seed: int,
                  src: PhotonSource, report: PoolReport,
                  inflight: list[_Inflight], pending: deque) -> None:
        ch = task.chunk
        attempt = task.failures
        w.n_dispatched += 1
        span = None
        if self.tracer is not None:
            span = self.tracer.span("chunk", device=w.device,
                                    engine=w.spec.engine, photons=ch.count,
                                    chunk_start=ch.start_id, attempt=attempt,
                                    worker=w.label)
        now = time.monotonic()
        delay = w.spec.throttle_s
        try:
            if self.injector is not None:
                self.injector.check_dispatch(ch.start_id, attempt, w.label)
                delay = max(delay, self.injector.delay_for(ch.start_id,
                                                           attempt))
            labels_dev, media_dev = self._buffers_for(w.device)
            fn = self._fn_for(src, w.bit_class)
            lo, hi = split_id64(ch.start_id)
            result = fn(labels_dev, media_dev, ch.count, seed, lo, hi)
        except InjectedFault as e:
            if span is not None:
                span.end(outcome="injected-fault")
            report.dispatch_failures += 1
            report.injected_faults += 1
            self._mark_failure(w, report, str(e))
            self._chunk_failed(task, report, f"dispatch: {e}", now, pending,
                               e)
            return
        except Exception as e:  # real dispatch error: requeue + surface
            if span is not None:
                span.end(outcome="error")
            report.dispatch_failures += 1
            self._mark_failure(w, report, repr(e))
            self._chunk_failed(task, report, f"dispatch: {e!r}", now,
                               pending, e)
            return
        deadline = self.chunk_timeout_s
        predicted = w.predict_s(ch.count)
        if predicted is not None:
            model_deadline = (self.deadline_factor * predicted
                              + self.deadline_slack_s)
            deadline = (model_deadline if deadline is None
                        else min(deadline, model_deadline))
        task.inflight += 1
        w.busy = True
        inflight.append(_Inflight(task, w, attempt, result, span, now,
                                  now + delay, deadline))

    def _complete(self, inf: _Inflight, report: PoolReport,
                  now: float, pending: deque) -> None:
        task, w = inf.task, inf.worker
        elapsed = now - inf.t0
        if task.merged or task.harvest is not None or task.quarantined:
            # a speculative twin (or a late result for a quarantined
            # chunk) already settled this chunk id — discard, but keep
            # the timing sample: the worker did real work
            report.duplicates_discarded += 1
            if inf.span is not None:
                inf.span.end(outcome="duplicate")
            w.record_sample(task.chunk.count, elapsed)
            return
        harvest = harvest_result(inf.result)
        if self.injector is not None and \
                self.injector.corrupts(task.chunk.start_id, inf.attempt):
            harvest = corrupt_harvest(harvest)
            report.injected_faults += 1
        errs = (validate_chunk(harvest, task.chunk.count,
                               self.max_residue_frac)
                if self.validate else [])
        if errs:
            if inf.span is not None:
                inf.span.end(outcome="invalid")
            report.validation_failures += 1
            self._mark_failure(w, report, errs[0])
            self._chunk_failed(task, report, f"validation: {errs}", now,
                               pending)
            return
        if inf.span is not None:
            inf.span.end(outcome="merged")
        w.record_sample(task.chunk.count, elapsed)
        self._mark_success(w)
        task.harvest = harvest
        task.merged_by = w

    # -- accumulation --------------------------------------------------------

    def _zero_acc(self) -> dict:
        nx, ny = self.volume.shape[:2]
        ntg = int(self.cfg.n_time_gates)
        n_det = len(self.detectors)
        n_media = self.volume.media.shape[0]
        eshape = (self.volume.shape if ntg == 1
                  else (*self.volume.shape, ntg))
        return {
            "energy": np.zeros(eshape, np.float32),
            "exitance": np.zeros((nx, ny), np.float32),
            "escaped_w": 0.0,
            "timed_out_w": 0.0,
            "det_w": np.zeros((n_det, ntg), np.float32),
            "det_ppath": np.zeros((n_det, n_media), np.float32),
            "det_rec": [],
            "det_rec_overflow": 0,
            "n_launched": 0,
            "launched_w": 0.0,
            "steps": 0,
            "stats": (RoundStats.zeros() if self.cfg.collect_stats
                      else None),
        }

    def _merge(self, acc: dict, task: _Task, report: PoolReport) -> None:
        h = task.harvest
        task.harvest = None
        task.merged = True
        report.merged += 1
        acc["energy"] += h["energy"]
        acc["exitance"] += h["exitance"]
        acc["escaped_w"] += h["escaped_w"]
        acc["timed_out_w"] += h["timed_out_w"]
        acc["det_w"] += h["det_w"]
        acc["det_ppath"] += h["det_ppath"]
        if h["det_rec"].size:
            acc["det_rec"].append(h["det_rec"])
        acc["det_rec_overflow"] += h["det_rec_overflow"]
        acc["n_launched"] += h["n_launched"]
        acc["launched_w"] += h["launched_w"]
        acc["steps"] += h["steps"]
        if acc["stats"] is not None and h["stats"] is not None:
            acc["stats"] = acc["stats"].add(h["stats"])
        w = task.merged_by
        if w is not None:
            w.n_merged += 1
            w.photons_merged += task.chunk.count

    def _result(self, acc: dict) -> SimResult:
        det_rec = (np.concatenate(acc["det_rec"], axis=0)
                   if acc["det_rec"] else np.zeros((0, 4), np.uint32))
        return SimResult(
            energy=jnp.asarray(acc["energy"]),
            exitance=jnp.asarray(acc["exitance"]),
            escaped_w=jnp.float32(acc["escaped_w"]),
            timed_out_w=jnp.float32(acc["timed_out_w"]),
            det_w=jnp.asarray(acc["det_w"]),
            det_ppath=jnp.asarray(acc["det_ppath"]),
            det_rec=jnp.asarray(det_rec),
            det_rec_n=jnp.int32(det_rec.shape[0]),
            det_rec_overflow=jnp.int32(acc["det_rec_overflow"]),
            n_launched=jnp.int32(acc["n_launched"]),
            launched_w=jnp.float32(acc["launched_w"]),
            steps=jnp.int32(acc["steps"]),
            stats=acc["stats"],
        )

    # -- checkpoint / resume -------------------------------------------------

    def _run_key(self, n_photons: int, chunk_size: int, seed: int,
                 src: PhotonSource) -> np.ndarray:
        """Campaign identity: mixing checkpoints across different
        configs would merge incompatible accumulators."""
        from repro.detectors import to_dicts
        from repro.sources import to_dict as source_to_dict

        src_key = (json.dumps(source_to_dict(src), sort_keys=True)
                   if hasattr(src, "type_name")
                   else f"<custom:{type(src).__qualname__}>")
        key = json.dumps({
            "n_photons": int(n_photons), "chunk_size": int(chunk_size),
            "seed": int(seed), "source": src_key,
            "detectors": to_dicts(self.detectors),
            "record_detected": self.record_detected,
        }, sort_keys=True)
        return np.frombuffer(key.encode(), np.uint8)

    def _state_dict(self, acc: dict, frontier: int, tasks: list,
                    n_photons: int, chunk_size: int, seed: int,
                    src: PhotonSource) -> dict:
        det_rec = (np.concatenate(acc["det_rec"], axis=0)
                   if acc["det_rec"] else np.zeros((0, 4), np.uint32))
        state = {
            "energy": acc["energy"].copy(),
            "exitance": acc["exitance"].copy(),
            "escaped_w": np.float64(acc["escaped_w"]),  # reprolint: disable=REP301 - checkpoint payload is f64
            "timed_out_w": np.float64(acc["timed_out_w"]),  # reprolint: disable=REP301 - checkpoint payload is f64
            "det_w": acc["det_w"].copy(),
            "det_ppath": acc["det_ppath"].copy(),
            "det_rec": det_rec,
            "det_rec_overflow": np.int64(acc["det_rec_overflow"]),
            "n_launched": np.int64(acc["n_launched"]),
            "launched_w": np.float64(acc["launched_w"]),  # reprolint: disable=REP301 - checkpoint payload is f64
            "steps": np.int64(acc["steps"]),
            "frontier": np.int64(frontier),
            "quarantined": np.asarray(
                [(t.chunk.start_id, t.chunk.count)
                 for t in tasks if t.quarantined], np.int64).reshape(-1, 2),
            "run_key": self._run_key(n_photons, chunk_size, seed, src),
        }
        if acc["stats"] is not None:
            state["stats"] = np.asarray(
                [float(v) for v in acc["stats"]], np.float64)  # reprolint: disable=REP301 - checkpoint payload is f64
        return state

    def _save_checkpoint(self, acc, frontier, tasks, n_photons, chunk_size,
                         seed, src, report: PoolReport) -> None:
        state = self._state_dict(acc, frontier, tasks, n_photons,
                                 chunk_size, seed, src)
        self.checkpointer.save(frontier, state,
                               extra={"kind": "device_pool",
                                      "merged": report.merged,
                                      **{k: v for k, v in
                                         report.counters().items()
                                         if isinstance(v, int)}})
        report.checkpoints += 1
        if self.tracer is not None:
            self.tracer.counter("resilience.checkpoint", frontier)

    def _restore(self, acc: dict, tasks: list, n_photons: int,
                 chunk_size: int, seed: int, src: PhotonSource) -> int:
        """Load the newest checkpoint into ``acc``; returns the merged
        frontier (0 when no checkpoint exists yet)."""
        if self.checkpointer is None:
            raise ValueError("resume=True needs a checkpointer")
        if self.checkpointer.latest_step() is None:
            return 0
        template = self._state_dict(self._zero_acc(), 0, [], n_photons,
                                    chunk_size, seed, src)
        _, state = self.checkpointer.restore(template)
        want = self._run_key(n_photons, chunk_size, seed, src)
        got = np.asarray(state["run_key"], np.uint8)
        if got.shape != want.shape or not np.array_equal(got, want):
            raise ValueError(
                f"checkpoint belongs to a different campaign: "
                f"{bytes(got).decode()} vs {bytes(want).decode()}")
        acc["energy"] = np.asarray(state["energy"], np.float32).copy()
        acc["exitance"] = np.asarray(state["exitance"], np.float32).copy()
        acc["escaped_w"] = float(state["escaped_w"])
        acc["timed_out_w"] = float(state["timed_out_w"])
        acc["det_w"] = np.asarray(state["det_w"], np.float32).copy()
        acc["det_ppath"] = np.asarray(state["det_ppath"], np.float32).copy()
        rec = np.asarray(state["det_rec"], np.uint32).reshape(-1, 4)
        acc["det_rec"] = [rec] if rec.size else []
        acc["det_rec_overflow"] = int(state["det_rec_overflow"])
        acc["n_launched"] = int(state["n_launched"])
        acc["launched_w"] = float(state["launched_w"])
        acc["steps"] = int(state["steps"])
        if acc["stats"] is not None and "stats" in state:
            acc["stats"] = RoundStats.from_vector(
                np.asarray(state["stats"], np.float64))  # reprolint: disable=REP301 - checkpoint payload is f64
        quarantined = {int(s) for s, _ in
                       np.asarray(state["quarantined"],
                                  np.int64).reshape(-1, 2)}
        frontier = int(state["frontier"])
        for t in tasks[:frontier]:
            if t.chunk.start_id in quarantined:
                t.quarantined = True
        return frontier

    # -- telemetry -----------------------------------------------------------

    def _emit_counters(self, report: PoolReport) -> None:
        report.rebound = getattr(self, "_rebound_count", 0)
        self._rebound_count = 0
        if self.tracer is None:
            return
        for k, v in report.counters().items():
            self.tracer.counter(f"resilience.{k}", v)
