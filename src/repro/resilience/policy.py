"""Retry and health policies for resilient chunk execution
(DESIGN.md §resilience).

Retries are *safe* here in a way they are not in most distributed
systems: photons are keyed by 64-bit global id, so re-dispatching a
chunk reproduces the exact same photon set bit-for-bit (DESIGN.md
§determinism).  The policy layer only has to decide *when to stop* —
a chunk that keeps failing is a poison pill (bad input, a genuinely
broken device pairing, an injector's ``poison_chunks``) and must be
quarantined instead of starving the campaign, and a worker that keeps
failing must stop receiving work before it burns the retry budget of
every chunk it touches.
"""

from __future__ import annotations

import dataclasses

# Worker health ladder: healthy -> suspect -> quarantined.  Suspect
# workers still receive work (they are deprioritized behind healthy
# ones); quarantined workers are out of the fleet for the rest of the
# run.  One success climbs a worker back to healthy.
HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Attempt caps, backoff, and worker health thresholds.

    ``max_attempts`` bounds the total number of dispatches of one chunk
    (counting the first); a chunk that fails ``max_attempts`` times is
    quarantined — recorded, never merged, never retried again.  Backoff
    is exponential (``backoff_s * backoff_factor**(attempt-1)``, capped
    at ``max_backoff_s``) and is honored by the pool as a "not eligible
    before t" gate, never a blocking sleep, so other chunks keep
    flowing while a flaky one cools down.

    ``suspect_after`` / ``quarantine_after`` count *consecutive*
    failures of one worker (any success resets the streak).
    """

    max_attempts: int = 5
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 2.0
    suspect_after: int = 2
    quarantine_after: int = 5

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.quarantine_after < self.suspect_after:
            raise ValueError("quarantine_after must be >= suspect_after")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff durations must be >= 0")

    def backoff(self, attempt: int) -> float:
        """Cool-down seconds before retry number ``attempt`` (1-based:
        the first retry is attempt 1)."""
        if self.backoff_s <= 0.0:
            return 0.0
        return min(self.backoff_s * self.backoff_factor ** max(attempt - 1, 0),
                   self.max_backoff_s)

    def exhausted(self, failures: int) -> bool:
        """True once a chunk has failed away its whole attempt budget."""
        return failures >= self.max_attempts

    def health_for(self, consecutive_failures: int) -> str:
        """Health state implied by a worker's current failure streak."""
        if consecutive_failures >= self.quarantine_after:
            return QUARANTINED
        if consecutive_failures >= self.suspect_after:
            return SUSPECT
        return HEALTHY
