"""Hymba-1.5B: hybrid heads — attention and Mamba(2-style) SSM run in
parallel in every layer, outputs fused after per-path norm; 128 learnable
meta tokens prepended; sliding-window attention keeps decode state
bounded (long_500k runs).  [arXiv:2411.13676]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", kind="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64, rope_theta=10_000.0,
        ssm_state=16, ssm_headdim=50, ssm_expand=2, ssm_conv=4,
        ssm_ngroups=1, meta_tokens=128, sliding_window=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", kind="hybrid",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, rope_theta=10_000.0,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4,
        ssm_ngroups=1, meta_tokens=8, sliding_window=16,
    )
