"""Registry of the assigned architectures (+ the paper's MC benchmarks).

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` resolve the ids
used by ``--arch`` flags across the launchers, benchmarks and dry-run.
"""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "phi3-medium-14b": "phi3_medium_14b",
    "granite-20b": "granite_20b",
    "llama3.2-1b": "llama3_2_1b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "whisper-medium": "whisper_medium",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS = list(_MODULES)

# long_500k requires a bounded decode state (sub-quadratic attention);
# pure full-attention archs skip it — see DESIGN.md §Arch-applicability.
LONG_CONTEXT_ARCHS = {"mamba2-1.3b", "hymba-1.5b", "mixtral-8x7b"}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def cells(include_skipped: bool = False):
    """All (arch_id, shape) dry-run cells; 40 total, minus documented skips."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = (shape.name == "long_500k"
                       and arch not in LONG_CONTEXT_ARCHS)
            if skipped and not include_skipped:
                continue
            out.append((arch, shape))
    return out
