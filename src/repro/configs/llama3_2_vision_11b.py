"""Llama-3.2-11B-Vision: dense backbone + cross-attn image layers every
5th layer; image patch embeddings are a STUB input (precomputed).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", kind="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128, rope_theta=500_000.0,
        cross_attn_every=5, n_image_tokens=1600,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke", kind="vlm",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, head_dim=32, rope_theta=500_000.0,
        cross_attn_every=2, n_image_tokens=16,
    )
