"""DeepSeek-V3 (671B): MLA attention, 1 shared + 256 routed top-8 MoE,
first 3 layers dense.  MTP head omitted (training objective variant, not
an architecture requirement — see DESIGN.md).  [arXiv:2412.19437]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", kind="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,            # dense-layer FFN width
        vocab=129280, head_dim=128, rope_theta=10_000.0,
        n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
        first_dense_layers=3, capacity_factor=1.25,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", kind="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=256, head_dim=32, rope_theta=10_000.0,
        n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=64,
        first_dense_layers=1, capacity_factor=2.0,
        use_mla=True, q_lora_rank=48, kv_lora_rank=32,
        qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
    )
