"""Whisper-medium: encoder-decoder; the conv audio frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, frames, d).
[arXiv:2212.04356]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", kind="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865, head_dim=64, rope_theta=10_000.0,
        n_encoder_layers=24, encoder_frames=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", kind="encdec",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=256, head_dim=32, rope_theta=10_000.0,
        n_encoder_layers=2, encoder_frames=16,
    )
