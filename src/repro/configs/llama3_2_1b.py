"""Llama-3.2-1B: small llama3 dense, GQA kv=8. [hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", kind="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=128256, head_dim=64, rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", kind="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, head_dim=32, rope_theta=500_000.0,
    )
