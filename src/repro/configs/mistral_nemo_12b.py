"""Mistral-Nemo-Base-2407 (12B): dense, GQA kv=8, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", kind="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=131072, head_dim=128, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", kind="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, head_dim=32, rope_theta=10_000.0,
    )
