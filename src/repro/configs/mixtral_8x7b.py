"""Mixtral-8x7B: 8 experts top-2 MoE with sliding-window attention.
SWA makes decode state bounded (ring-buffer KV cache), so the long_500k
cell runs for this arch.  [arXiv:2401.04088]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", kind="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128, rope_theta=1_000_000.0,
        n_experts=8, top_k=2, moe_d_ff=14336, capacity_factor=1.25,
        sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", kind="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=256, head_dim=32, rope_theta=1_000_000.0,
        n_experts=4, top_k=2, moe_d_ff=256, capacity_factor=2.0,
        sliding_window=16,
    )
