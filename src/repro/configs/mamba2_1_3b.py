"""Mamba2-1.3B: attention-free SSD (state-space duality).
O(1) decode state -> long_500k cell runs.  [arXiv:2405.21060]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", kind="ssm",
        n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, head_dim=0,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        ssm_ngroups=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke", kind="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256, head_dim=0,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4,
        ssm_ngroups=1,
    )
