"""Granite-20B-code: llama-arch dense with MQA (kv=1). [arXiv:2405.04324]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", kind="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, head_dim=128, rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", kind="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab=256, head_dim=32, rope_theta=10_000.0,
    )
