"""Phi-3-medium (14B): dense, GQA kv=10, RoPE + SwiGLU. [arXiv:2404.14219]
Note: 40 heads do not divide the 16-way model axis; GSPMD pads the head
dim (see DESIGN.md §uneven-sharding)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", kind="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab=100352, head_dim=128, rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke", kind="dense",
        n_layers=2, d_model=120, n_heads=5, n_kv_heads=5,
        d_ff=256, vocab=256, head_dim=24, rope_theta=10_000.0,
    )
