"""Shared photon-step kernel contract: output spec + VMEM budget.

This module is the single statically-extractable source of truth for
the engine-parity contract that the Pallas kernel
(``photon_step.photon_step_pallas``), the pure-jnp oracle
(``ref.photon_steps_ref``), the jit wrapper (``ops.photon_steps``) and
the jnp/pallas round executors (``repro.core.simulator``) have
maintained by hand since PR 2: every mirrored implementation must
produce the same optional output groups, in the same order, gated by
the same flags.  ``reprolint`` (repro.lint, DESIGN.md
§static-analysis) parses this file with ``ast.literal_eval`` and
cross-checks each mirror against it, so the constants below must stay
plain literals — no imports, no computed values.  The runtime asserts
the same arities after every ``pallas_call`` (``output_arity``).

It also owns the kernel's VMEM budget model (DESIGN.md
§static-analysis): per grid step the kernel keeps the full volume
blocks (labels, gate-major fluence, exitance, optional Jacobian) plus
one lane block of photon state resident in VMEM.  ``check_vmem``
rejects configs that cannot fit *before* Mosaic fails to lower them;
the lint VMEM rule applies the identical formula to statically
resolvable call sites.

Keep this module dependency-free: reprolint loads it by file path
without importing jax.
"""

from __future__ import annotations

# --- output contract -------------------------------------------------------

# The photon state, packed as one PhotonState in the oracle/engine and
# unpacked into one array per field by the Pallas kernel (lane-blocked).
STATE_FIELDS = ("pos", "dir", "ivox", "w", "s_left", "t", "rng", "alive")

# Unconditional outputs that follow the state in every mirror.
BASE_OUTPUTS = ("fluence", "exitance", "escaped", "timed")

# Optional output groups, in emission order, keyed by the flag that
# gates them.  Each mirror appends (or unpacks) exactly these arities
# under exactly these flags; "stats" is always last (DESIGN.md
# §observability).  The round executor in repro.core.simulator guards
# the stats group with its local name ``collect`` — reprolint treats
# the names in each tuple's first element as aliases of one flag.
OUTPUT_GROUPS = (
    (("n_det",), ("ppath", "det_w", "det_ppath")),
    (("record",), ("cap_det", "cap_gate")),
    (("jac_cols",), ("jac",)),
    (("stats", "collect"), ("stats",)),
)

# Positional prefix every mirrored entry point takes, in this order.
CORE_PARAMS = ("labels_flat", "media", "state", "shape", "unitinmm",
               "cfg", "n_steps")

# Optional trailing parameters every mirrored entry point accepts, in
# this relative order (the mirror-drift rule checks the subsequence).
EXT_PARAMS = ("ppath", "det_geom", "record", "jac_w", "jac_col",
              "jac_cols", "stats")

# Bytes per lane of photon state: pos/dir (3 f32 each), ivox (3 i32),
# w/s_left/t (f32), rng (4 u32), alive (i8).
STATE_LANE_BYTES = 65


def output_arity(n_det: int = 0, record: bool = False, jac_cols: int = 0,
                 stats: bool = False, packed_state: bool = True) -> int:
    """Number of outputs a mirrored photon-step call must produce.

    ``packed_state=True`` counts the photon state as one element (the
    oracle/engine tuple); ``False`` counts one output per state field
    (the raw ``pallas_call`` output list).
    """
    n = (1 if packed_state else len(STATE_FIELDS)) + len(BASE_OUTPUTS)
    flags = {"n_det": bool(n_det), "record": bool(record),
             "jac_cols": bool(jac_cols), "stats": bool(stats)}
    for names, members in OUTPUT_GROUPS:
        if flags[names[0]]:
            n += len(members)
    return n


# --- VMEM budget -----------------------------------------------------------

# A TPU core's VMEM (16 MiB on every generation this targets), minus a
# reserve for Mosaic scratch, semaphores and the double-buffered lane
# blocks the pipeline keeps in flight.  The usable budget caps the
# gate-major fluence block at ntg <= 16 on the paper's 60^3 volume and
# the replay-Jacobian block at n_det * ntg <= 16 (DESIGN.md
# §time-resolved, §replay) — the same numbers the ROADMAP carries as
# the HBM-accumulator work item.
VMEM_BYTES = 16 * 2**20
VMEM_RESERVE_BYTES = 2 * 2**20


def estimate_vmem_bytes(nvox: int, nxy: int, ntg: int = 1,
                        block_lanes: int = 256, n_media: int = 4,
                        n_det: int = 0, record: bool = False,
                        jac_cols: int = 0, stats: bool = False) -> int:
    """Statically estimate the kernel's resident VMEM per grid step.

    Sums the full (grid-revisited) volume blocks and one lane block of
    inputs + outputs, mirroring the BlockSpecs ``photon_step_pallas``
    builds:

      labels    nvox                bytes (uint8)
      fluence   nvox * ntg * 4      bytes (gate-major f32, revisited)
      exitance  nxy * 4             bytes (revisited)
      jacobian  nvox * jac_cols * 4 bytes (revisited, replay pass B)
      media     n_media * 16        bytes
      detector  n_det * (12 + 4 * ntg + 4 * n_media) bytes
      lanes     block_lanes * (2 * state + per-lane extras)

    The estimate is deliberately simple — exact to the BlockSpec sizes,
    ignoring compiler scratch, which the reserve absorbs.
    """
    vol = nvox + nvox * ntg * 4 + nxy * 4 + nvox * jac_cols * 4
    vol += n_media * 16
    if n_det:
        # det_geom + det_w histogram + det_ppath sums (all full blocks)
        vol += n_det * (12 + 4 * ntg + 4 * n_media)
    lane = 2 * STATE_LANE_BYTES + 8          # state in+out, esc + timed
    if n_det:
        lane += 2 * 4 * n_media              # ppath in + out
    if record:
        lane += 2 * 4                        # cap_det + cap_gate
    if jac_cols:
        lane += 2 * 4                        # jac_w + jac_col inputs
    if stats:
        lane += 2 * 4                        # (n, 2) f32 telemetry block
    return vol + block_lanes * lane


def check_vmem(nvox: int, nxy: int, ntg: int = 1, block_lanes: int = 256,
               n_media: int = 4, n_det: int = 0, record: bool = False,
               jac_cols: int = 0, stats: bool = False) -> int:
    """Validate a kernel config against the VMEM budget.

    Returns the byte estimate; raises ``ValueError`` when the config
    cannot fit ``VMEM_BYTES - VMEM_RESERVE_BYTES``.  Called by
    ``photon_step_pallas`` before dispatching the *compiled* kernel
    (the interpreter has no VMEM), and by the reprolint VMEM rule for
    statically resolvable call sites — one formula, one threshold.
    """
    est = estimate_vmem_bytes(nvox, nxy, ntg, block_lanes, n_media,
                              n_det, record, jac_cols, stats)
    budget = VMEM_BYTES - VMEM_RESERVE_BYTES
    if est > budget:
        raise ValueError(
            f"photon-step kernel config needs ~{est / 2**20:.1f} MiB of "
            f"VMEM (nvox={nvox}, ntg={ntg}, jac_cols={jac_cols}, "
            f"block_lanes={block_lanes}) but only "
            f"{budget / 2**20:.1f} MiB of the {VMEM_BYTES / 2**20:.0f} "
            f"MiB core budget is usable — shrink n_time_gates / "
            f"jac_cols / block_lanes or use the jnp engine (DESIGN.md "
            f"§static-analysis; the HBM-resident accumulator is the "
            f"ROADMAP fix)")
    return est
