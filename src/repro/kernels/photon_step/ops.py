"""Jit'd public wrapper around the photon_step Pallas kernel."""

from __future__ import annotations

import functools

import jax

from repro.core import photon as ph
from repro.core import rng as xrng
from repro.core.volume import SimConfig, Source, Volume
from repro.detectors import as_detectors, det_geometry, validate_detectors
from repro.kernels.photon_step.photon_step import (default_interpret,
                                                  photon_step_pallas)
from repro.sources import PhotonSource, as_source


@functools.partial(jax.jit, static_argnames=(
    "shape", "unitinmm", "cfg", "n_steps", "block_lanes", "interpret",
    "record", "jac_cols", "stats"))
def _photon_steps_jit(labels_flat, media, state, shape, unitinmm,
                      cfg: SimConfig, n_steps: int, block_lanes: int,
                      interpret: bool, ppath=None, det_geom=None,
                      record: bool = False, jac_w=None, jac_col=None,
                      jac_cols: int = 0, stats: bool = False):
    return photon_step_pallas(labels_flat, media, state, shape, unitinmm,
                              cfg, n_steps, block_lanes, interpret,
                              ppath=ppath, det_geom=det_geom, record=record,
                              jac_w=jac_w, jac_col=jac_col,
                              jac_cols=jac_cols, stats=stats)


def photon_steps(labels_flat, media, state, shape, unitinmm, cfg: SimConfig,
                 n_steps: int, block_lanes: int = 256,
                 interpret: bool | None = None, ppath=None, det_geom=None,
                 record: bool = False, jac_w=None, jac_col=None,
                 jac_cols: int = 0, stats: bool = False):
    """Returns ``(new_state, fluence_flat, exitance_flat,
    escaped_per_lane, timed_per_lane)`` — plus
    ``(ppath, det_w_flat, det_ppath)`` when detectors are configured,
    plus per-lane ``(cap_det, cap_gate)`` capture records when
    ``record`` is set, plus the ``(nvox * jac_cols,)`` replay-Jacobian
    accumulator when ``jac_cols > 0``, plus the trailing ``(n, 2)``
    telemetry counter block when ``stats`` is set (see
    ``photon_step_pallas``).

    ``interpret=None`` auto-detects: interpreter off TPU, compiled
    Mosaic kernel on TPU.  Resolved here, outside jit, so ``None`` and
    the equivalent explicit mode share one cached executable.
    """
    if interpret is None:
        interpret = default_interpret()
    return _photon_steps_jit(labels_flat, media, state, shape, unitinmm,
                             cfg, n_steps, block_lanes, interpret,
                             ppath=ppath, det_geom=det_geom, record=record,
                             jac_w=jac_w, jac_col=jac_col,
                             jac_cols=jac_cols, stats=stats)


def simulate_kernel(volume: Volume, cfg: SimConfig, n_photons: int,
                    n_steps: int, seed: int = 1234,
                    source: PhotonSource | Source | None = None,
                    block_lanes: int = 256, interpret: bool | None = None,
                    detectors=None, record: bool = False,
                    id_offset: int = 0):
    """Launch one photon per lane and advance n_steps with the kernel.

    Any registered source (repro.sources) works: the source samples the
    launch states outside the kernel, so the Pallas step body is
    source-agnostic.  ``detectors`` (repro.detectors spec) enables
    in-kernel TPSF capture; fresh photons start with zero partial
    pathlengths.  ``record`` adds the per-lane capture records; with
    one photon per lane, ``cap_det[k]`` directly refers to global
    photon id ``id_offset + k`` (64-bit ids via rng.PhotonId).
    """
    source = as_source(source)
    dets = as_detectors(detectors)
    lo, hi = xrng.split_id64(id_offset)
    ids = xrng.PhotonId(
        lo=jax.numpy.uint32(lo) + jax.numpy.arange(
            n_photons, dtype=jax.numpy.uint32),
        hi=jax.numpy.full((n_photons,), hi, jax.numpy.uint32),
    )
    # carry the low-word wraparound into the high word so ids straddling
    # a 2**32 boundary stay distinct
    ids = ids._replace(hi=ids.hi + (ids.lo < jax.numpy.uint32(lo)).astype(
        jax.numpy.uint32))
    pos, direc, w0, rng = source.sample(ids, jax.numpy.uint32(seed))
    state = ph.launch(pos, direc, w0, rng,
                      jax.numpy.ones((n_photons,), bool), volume.shape)
    ppath = det_geom = None
    if dets:
        validate_detectors(dets, volume.shape)
        n_media = volume.media.shape[0]
        ppath = jax.numpy.zeros((n_photons, n_media), jax.numpy.float32)
        det_geom = det_geometry(dets)
    return photon_steps(volume.labels.reshape(-1), volume.media, state,
                        volume.shape, volume.unitinmm, cfg, n_steps,
                        block_lanes, interpret, ppath=ppath,
                        det_geom=det_geom, record=record)
