"""Pure-jnp oracle for the photon_step Pallas kernel.

Runs ``n_steps`` lock-step iterations of the hop-drop-spin physics over
all lanes, accumulating deposition into a (gate-major, time-resolved)
fluence grid, z=0-face exits into a flat exitance image, and escaped /
timed-out weight per lane — plus, when detectors are configured, the
per-(detector, gate) TPSF histogram and per-medium partial pathlengths —
exactly the computation the kernel performs, without any blocking/VMEM
structure.  The kernel test asserts allclose (and for matching RNG,
bit-equality of trajectories) against this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import photon as ph
from repro.core.volume import SimConfig
from repro.detectors import accumulate_capture, update_capture


def photon_steps_ref(labels_flat, media, state: ph.PhotonState,
                     shape, unitinmm, cfg: SimConfig, n_steps: int,
                     ppath=None, det_geom=None, record=False,
                     jac_w=None, jac_col=None, jac_cols: int = 0,
                     stats: bool = False):
    """Returns ``(new_state, fluence_flat, exitance_flat,
    escaped_per_lane, timed_per_lane)`` — plus
    ``(ppath, det_w_flat, det_ppath)`` when detectors are configured,
    plus ``(cap_det, cap_gate)`` per-lane capture records when
    ``record`` is set, plus the ``(nvox * jac_cols,)`` replay-Jacobian
    accumulator when ``jac_cols > 0``, plus the trailing ``(n, 2)``
    telemetry counter block (segments-entered-alive, deposited weight)
    when ``stats`` is set (same contract as ``photon_step_pallas``)."""
    if (ppath is None) != (det_geom is None):
        raise ValueError("ppath and det_geom must be given together")
    jac_cols = int(jac_cols)
    if (jac_cols > 0) != (jac_w is not None) or \
            (jac_w is None) != (jac_col is None):
        raise ValueError("jac_w, jac_col and jac_cols > 0 must be given "
                         "together")
    nvox = labels_flat.shape[0]
    ntg = int(cfg.n_time_gates)
    nxy = shape[0] * shape[1]
    n = state.w.shape[0]
    n_media = media.shape[0]
    n_det = 0 if det_geom is None else det_geom.shape[0]
    if record and not n_det:
        raise ValueError("record=True requires detectors (det_geom)")

    def body(_, carry):
        st, flu, exi, esc, timed = carry[:5]
        cur = 5
        if n_det:
            pp, dw, dp = carry[cur:cur + 3]
            cur += 3
        if record:
            capd, capg = carry[cur:cur + 2]
            cur += 2
        if jac_cols:
            jac = carry[cur]
            cur += 1
        if stats:
            stbl = carry[cur]
        res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
        gate = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
        flu = flu.at[res.dep_idx * ntg + gate].add(res.dep_w)
        xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
        exi = exi.at[xy].add(xw)
        esc = esc + res.esc_w
        timed = timed + res.timed_w
        out = (res.state, flu, exi, esc, timed)
        if n_det:
            pp, dw, dp = accumulate_capture(pp, dw, dp, res, gate,
                                            det_geom, ntg)
            out = out + (pp, dw, dp)
            if record:
                capd, capg = update_capture(capd, capg, res, gate, det_geom)
                out = out + (capd, capg)
        if jac_cols:
            jac = jac.at[res.dep_idx * jac_cols + jac_col].add(
                jac_w * res.seg_len)
            out = out + (jac,)
        if stats:
            stbl = stbl + jnp.stack(
                [st.alive.astype(jnp.float32), res.dep_w], axis=1)
            out = out + (stbl,)
        return out

    init = (state, jnp.zeros((nvox * ntg,), jnp.float32),
            jnp.zeros((nxy,), jnp.float32), jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32))
    if n_det:
        init = init + (ppath, jnp.zeros((n_det * ntg,), jnp.float32),
                       jnp.zeros((n_det, n_media), jnp.float32))
    if record:
        init = init + (jnp.full((n,), -1, jnp.int32),
                       jnp.zeros((n,), jnp.int32))
    if jac_cols:
        init = init + (jnp.zeros((nvox * jac_cols,), jnp.float32),)
    if stats:
        init = init + (jnp.zeros((n, 2), jnp.float32),)
    return jax.lax.fori_loop(0, n_steps, body, init)
