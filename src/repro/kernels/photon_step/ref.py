"""Pure-jnp oracle for the photon_step Pallas kernel.

Runs ``n_steps`` lock-step iterations of the hop-drop-spin physics over
all lanes, accumulating deposition into a (gate-major, time-resolved)
fluence grid, z=0-face exits into a flat exitance image, and escaped /
timed-out weight per lane — plus, when detectors are configured, the
per-(detector, gate) TPSF histogram and per-medium partial pathlengths —
exactly the computation the kernel performs, without any blocking/VMEM
structure.  The kernel test asserts allclose (and for matching RNG,
bit-equality of trajectories) against this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import photon as ph
from repro.core.volume import SimConfig
from repro.detectors import accumulate_capture, update_capture


def photon_steps_ref(labels_flat, media, state: ph.PhotonState,
                     shape, unitinmm, cfg: SimConfig, n_steps: int,
                     ppath=None, det_geom=None, record=False):
    """Returns ``(new_state, fluence_flat, exitance_flat,
    escaped_per_lane, timed_per_lane)`` — plus
    ``(ppath, det_w_flat, det_ppath)`` when detectors are configured,
    plus ``(cap_det, cap_gate)`` per-lane capture records when
    ``record`` is set (same contract as ``photon_step_pallas``)."""
    if (ppath is None) != (det_geom is None):
        raise ValueError("ppath and det_geom must be given together")
    nvox = labels_flat.shape[0]
    ntg = int(cfg.n_time_gates)
    nxy = shape[0] * shape[1]
    n = state.w.shape[0]
    n_media = media.shape[0]
    n_det = 0 if det_geom is None else det_geom.shape[0]
    if record and not n_det:
        raise ValueError("record=True requires detectors (det_geom)")

    def body(_, carry):
        if record:
            st, flu, exi, esc, timed, pp, dw, dp, capd, capg = carry
        elif n_det:
            st, flu, exi, esc, timed, pp, dw, dp = carry
        else:
            st, flu, exi, esc, timed = carry
        res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
        gate = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
        flu = flu.at[res.dep_idx * ntg + gate].add(res.dep_w)
        xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
        exi = exi.at[xy].add(xw)
        esc = esc + res.esc_w
        timed = timed + res.timed_w
        if n_det:
            pp, dw, dp = accumulate_capture(pp, dw, dp, res, gate,
                                            det_geom, ntg)
            if record:
                capd, capg = update_capture(capd, capg, res, gate, det_geom)
                return (res.state, flu, exi, esc, timed, pp, dw, dp,
                        capd, capg)
            return (res.state, flu, exi, esc, timed, pp, dw, dp)
        return (res.state, flu, exi, esc, timed)

    init = (state, jnp.zeros((nvox * ntg,), jnp.float32),
            jnp.zeros((nxy,), jnp.float32), jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32))
    if n_det:
        init = init + (ppath, jnp.zeros((n_det * ntg,), jnp.float32),
                       jnp.zeros((n_det, n_media), jnp.float32))
    if record:
        init = init + (jnp.full((n,), -1, jnp.int32),
                       jnp.zeros((n,), jnp.int32))
    return jax.lax.fori_loop(0, n_steps, body, init)
