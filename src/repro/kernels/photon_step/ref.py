"""Pure-jnp oracle for the photon_step Pallas kernel.

Runs ``n_steps`` lock-step iterations of the hop-drop-spin physics over
all lanes, accumulating deposition into a fluence grid, z=0-face exits
into a flat exitance image, and escaped weight per lane — exactly the
computation the kernel performs, without any blocking/VMEM structure.
The kernel test asserts allclose (and for matching RNG, bit-equality of
trajectories) against this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import photon as ph
from repro.core.volume import SimConfig


def photon_steps_ref(labels_flat, media, state: ph.PhotonState,
                     shape, unitinmm, cfg: SimConfig, n_steps: int):
    """Returns (new_state, fluence_flat, exitance_flat, escaped_per_lane)."""
    nvox = labels_flat.shape[0]
    nxy = shape[0] * shape[1]
    n = state.w.shape[0]

    def body(_, carry):
        st, flu, exi, esc = carry
        res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
        flu = flu.at[res.dep_idx].add(res.dep_w)
        xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
        exi = exi.at[xy].add(xw)
        esc = esc + res.esc_w
        return (res.state, flu, exi, esc)

    st, flu, exi, esc = jax.lax.fori_loop(
        0, n_steps, body,
        (state, jnp.zeros((nvox,), jnp.float32),
         jnp.zeros((nxy,), jnp.float32), jnp.zeros((n,), jnp.float32)),
    )
    return st, flu, exi, esc
