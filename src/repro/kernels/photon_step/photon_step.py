"""Pallas TPU kernel for the photon transport hot loop.

TPU adaptation of the paper's OpenCL simulation kernel (DESIGN.md
§kernel):

  * The voxel volume (uint8 labels, 216 KB at the paper's 60^3) and the
    optical-property table live in VMEM for the whole kernel — the
    analogue of the paper keeping the volume in texture/constant memory.
  * Photon state is SoA, blocked over lanes: each grid step processes
    one block of photons entirely in VMEM/VREGs, advancing ``n_steps``
    segments per invocation (the "simulation loop" of Fig. 1).
  * Fluence / exitance accumulation: the paper needs atomic float adds
    (its B2a benchmark measures their cost).  TPU Pallas has no atomics
    and needs none: the grid is sequential on a core, so each block
    scatter-adds into fluence / exitance output blocks that are
    REVISITED by every grid step — race-free accumulation by
    construction.  Cross-device accumulation is one psum in the caller
    (multidevice.py).
  * In-kernel bookkeeping (DESIGN.md §rounds): deposition, the 2-D
    z=0-face exitance image and per-lane escaped weight are all
    accumulated *inside* the kernel across the fused ``n_steps``
    segments, so the host flushes each global grid once per round — the
    deferred-accumulation structure the paper uses to amortize global
    memory traffic over many transport steps.
  * RNG: same counter-seeded xorshift128 as the engine (32-bit ops only;
    TPUs have no 64-bit vector units — the paper's xorshift128+ is
    64-bit, see DESIGN.md §rng).

The physics body is shared with the engine (repro.core.photon.step), so
kernel trajectories are bit-identical to the oracle by construction; the
kernel's contribution is the memory/layout architecture.

Validated with interpret=True on CPU (tests/test_kernels_photon.py); on
real TPU hardware the label gather (jnp.take) and fluence scatter-add
lower via XLA gather/scatter — supported by Mosaic for rank-1 VMEM
operands.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import photon as ph
from repro.core.volume import SimConfig


def default_interpret() -> bool:
    """Auto-detect the Pallas execution mode.

    Mosaic lowering only exists on TPU backends; everywhere else
    (CPU/GPU test rigs) the kernel must run under the Pallas
    interpreter.  Callers may still force either mode explicitly — the
    auto-detect only replaces ``interpret=None`` — so real-TPU runs get
    the compiled kernel instead of silently falling back to the
    interpreter (the old hard default).
    """
    return jax.default_backend() != "tpu"


def _kernel(labels_ref, media_ref,
            pos_ref, dir_ref, ivox_ref, w_ref, s_ref, t_ref, rng_ref,
            alive_ref,
            out_pos, out_dir, out_ivox, out_w, out_s, out_t, out_rng,
            out_alive, fluence_ref, exitance_ref, esc_ref,
            *, shape, unitinmm, cfg: SimConfig, n_steps: int):
    # zero the (revisited) accumulator blocks on the first grid step only
    @pl.when(pl.program_id(0) == 0)
    def _():
        fluence_ref[...] = jnp.zeros_like(fluence_ref)
        exitance_ref[...] = jnp.zeros_like(exitance_ref)

    labels = labels_ref[...]
    media = media_ref[...]
    state = ph.PhotonState(
        pos=pos_ref[...], dir=dir_ref[...], ivox=ivox_ref[...],
        w=w_ref[...], s_left=s_ref[...], t=t_ref[...], rng=rng_ref[...],
        alive=alive_ref[...] != 0,
    )
    n = state.w.shape[0]

    def body(_, carry):
        st, flu, exi, esc = carry
        res = ph.step(st, labels, media, shape, unitinmm, cfg)
        flu = flu.at[res.dep_idx].add(res.dep_w)
        xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
        exi = exi.at[xy].add(xw)
        esc = esc + res.esc_w
        return (res.state, flu, exi, esc)

    state, flu_add, exi_add, esc = jax.lax.fori_loop(
        0, n_steps, body,
        (state, jnp.zeros_like(fluence_ref), jnp.zeros_like(exitance_ref),
         jnp.zeros((n,), jnp.float32)),
    )

    out_pos[...] = state.pos
    out_dir[...] = state.dir
    out_ivox[...] = state.ivox
    out_w[...] = state.w
    out_s[...] = state.s_left
    out_t[...] = state.t
    out_rng[...] = state.rng
    out_alive[...] = state.alive.astype(jnp.int8)
    esc_ref[...] = esc
    # accumulate this block's deposition into the shared output blocks
    fluence_ref[...] += flu_add
    exitance_ref[...] += exi_add


def photon_step_pallas(labels_flat, media, state: ph.PhotonState,
                       shape, unitinmm, cfg: SimConfig, n_steps: int,
                       block_lanes: int = 256,
                       interpret: bool | None = None):
    """Advance all lanes ``n_steps`` segments; returns
    ``(new_state, fluence_flat, exitance_flat, escaped_per_lane)``.

    ``fluence_flat`` is (nvox,), ``exitance_flat`` is (nx*ny,) — the
    z=0-face exitance image accumulated in-kernel over all ``n_steps``
    segments.  ``interpret=None`` auto-detects the backend
    (:func:`default_interpret`).
    """
    if interpret is None:
        interpret = default_interpret()
    n = state.w.shape[0]
    if n % block_lanes:
        raise ValueError(f"lane count {n} not divisible by {block_lanes}")
    nblocks = n // block_lanes
    nvox = labels_flat.shape[0]
    nxy = shape[0] * shape[1]
    n_media = media.shape[0]

    def lane_spec(extra=()):
        return pl.BlockSpec((block_lanes,) + extra,
                            lambda i: (i,) + (0,) * len(extra))

    full_vol = pl.BlockSpec((nvox,), lambda i: (0,))       # revisited
    full_img = pl.BlockSpec((nxy,), lambda i: (0,))        # revisited
    full_media = pl.BlockSpec((n_media, 4), lambda i: (0, 0))

    out_shapes = (
        jax.ShapeDtypeStruct((n, 3), jnp.float32),   # pos
        jax.ShapeDtypeStruct((n, 3), jnp.float32),   # dir
        jax.ShapeDtypeStruct((n, 3), jnp.int32),     # ivox
        jax.ShapeDtypeStruct((n,), jnp.float32),     # w
        jax.ShapeDtypeStruct((n,), jnp.float32),     # s_left
        jax.ShapeDtypeStruct((n,), jnp.float32),     # t
        jax.ShapeDtypeStruct((n, 4), jnp.uint32),    # rng
        jax.ShapeDtypeStruct((n,), jnp.int8),        # alive
        jax.ShapeDtypeStruct((nvox,), jnp.float32),  # fluence (accumulated)
        jax.ShapeDtypeStruct((nxy,), jnp.float32),   # exitance (accumulated)
        jax.ShapeDtypeStruct((n,), jnp.float32),     # escaped weight
    )
    out_specs = (
        lane_spec((3,)), lane_spec((3,)), lane_spec((3,)),
        lane_spec(), lane_spec(), lane_spec(),
        lane_spec((4,)), lane_spec(),
        full_vol, full_img, lane_spec(),
    )
    in_specs = (
        full_vol, full_media,
        lane_spec((3,)), lane_spec((3,)), lane_spec((3,)),
        lane_spec(), lane_spec(), lane_spec(),
        lane_spec((4,)), lane_spec(),
    )

    kernel = functools.partial(
        _kernel, shape=shape, unitinmm=unitinmm, cfg=cfg, n_steps=n_steps)
    outs = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(labels_flat, media,
      state.pos, state.dir, state.ivox, state.w, state.s_left, state.t,
      state.rng, state.alive.astype(jnp.int8))

    new_state = ph.PhotonState(
        pos=outs[0], dir=outs[1], ivox=outs[2], w=outs[3], s_left=outs[4],
        t=outs[5], rng=outs[6], alive=outs[7] != 0,
    )
    return new_state, outs[8], outs[9], outs[10]
