"""Pallas TPU kernel for the photon transport hot loop.

TPU adaptation of the paper's OpenCL simulation kernel (DESIGN.md
§kernel):

  * The voxel volume (uint8 labels, 216 KB at the paper's 60^3) and the
    optical-property table live in VMEM for the whole kernel — the
    analogue of the paper keeping the volume in texture/constant memory.
  * Photon state is SoA, blocked over lanes: each grid step processes
    one block of photons entirely in VMEM/VREGs, advancing ``n_steps``
    segments per invocation (the "simulation loop" of Fig. 1).
  * Fluence / exitance accumulation: the paper needs atomic float adds
    (its B2a benchmark measures their cost).  TPU Pallas has no atomics
    and needs none: the grid is sequential on a core, so each block
    scatter-adds into fluence / exitance output blocks that are
    REVISITED by every grid step — race-free accumulation by
    construction.  Cross-device accumulation is one psum in the caller
    (multidevice.py).
  * In-kernel bookkeeping (DESIGN.md §rounds, §time-resolved):
    deposition (gate-major ``nvox * cfg.n_time_gates`` when
    time-resolved), the 2-D z=0-face exitance image, per-lane
    escaped / timed-out weight, and — when detectors are configured —
    the per-(detector, gate) TPSF histogram with per-medium partial
    pathlengths are all accumulated *inside* the kernel across the
    fused ``n_steps`` segments, so the host flushes each global grid
    once per round — the deferred-accumulation structure the paper uses
    to amortize global memory traffic over many transport steps.
    The gate index is computed at deposit time from the photon's
    time-of-flight (``photon.time_gate_bins``), so time-resolved
    recording adds zero state to the photon and one integer op to the
    scatter.  Note the VMEM budget: the revisited fluence block is
    ``nvox * ntg * 4`` bytes (a 60^3 volume supports ntg <= ~16 within
    a 16 MB VMEM core; larger gate counts need an HBM-resident
    accumulator, see DESIGN.md §time-resolved).
  * RNG: same counter-seeded xorshift128 as the engine (32-bit ops only;
    TPUs have no 64-bit vector units — the paper's xorshift128+ is
    64-bit, see DESIGN.md §rng).

The physics body is shared with the engine (repro.core.photon.step), so
kernel trajectories are bit-identical to the oracle by construction; the
kernel's contribution is the memory/layout architecture.

Validated with interpret=True on CPU (tests/test_kernels_photon.py); on
real TPU hardware the label gather (jnp.take) and fluence scatter-add
lower via XLA gather/scatter — supported by Mosaic for rank-1 VMEM
operands.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import photon as ph
from repro.core.volume import SimConfig
from repro.detectors import accumulate_capture, update_capture
from repro.kernels.photon_step import spec as kspec


def default_interpret() -> bool:
    """Auto-detect the Pallas execution mode.

    Mosaic lowering only exists on TPU backends; everywhere else
    (CPU/GPU test rigs) the kernel must run under the Pallas
    interpreter.  Callers may still force either mode explicitly — the
    auto-detect only replaces ``interpret=None`` — so real-TPU runs get
    the compiled kernel instead of silently falling back to the
    interpreter (the old hard default).
    """
    return jax.default_backend() != "tpu"


def resolve_block_lanes(n_lanes: int, block_lanes: int) -> int:
    """Clamp ``block_lanes`` to a divisor of ``n_lanes``.

    The kernel grid needs ``block_lanes | n_lanes``; fall back to the
    largest divisor <= the requested block so any lane count works
    through the public APIs (schedulers don't expose block_lanes).
    Shared by every executor that dispatches the kernel (the forward
    engine and both replay passes) so the fallback policy cannot
    diverge.
    """
    requested = block_lanes = min(block_lanes, n_lanes)
    while n_lanes % block_lanes:
        block_lanes -= 1
    if block_lanes < requested:
        warnings.warn(
            f"n_lanes={n_lanes} is not divisible by "
            f"block_lanes={requested}; falling back to "
            f"block_lanes={block_lanes} — small blocks serialize the "
            f"Pallas grid (prefer a lane count with a divisor near "
            f"{requested})", stacklevel=3)
    return block_lanes


def _kernel(labels_ref, media_ref, *refs,
            shape, unitinmm, cfg: SimConfig, n_steps: int, n_det: int,
            record: bool, jac_cols: int, stats: bool):
    # unpack the variadic refs: 8 state inputs [+ ppath + det_geom]
    # [+ jac_w + jac_col], then 8 state outputs + fluence/exitance/esc/
    # timed [+ ppath + det_w + det_ppath] [+ cap_det + cap_gate]
    # [+ jac] [+ stats] — assembled to match photon_step_pallas's specs
    (pos_ref, dir_ref, ivox_ref, w_ref, s_ref, t_ref, rng_ref,
     alive_ref) = refs[:8]
    cur = 8
    if n_det:
        ppath_ref, det_geom_ref = refs[cur:cur + 2]
        cur += 2
    if jac_cols:
        jac_w_ref, jac_col_ref = refs[cur:cur + 2]
        cur += 2
    outs = refs[cur:]
    (out_pos, out_dir, out_ivox, out_w, out_s, out_t, out_rng,
     out_alive, fluence_ref, exitance_ref, esc_ref, timed_ref) = outs[:12]
    cur = 12
    if n_det:
        out_ppath, det_w_ref, det_ppath_ref = outs[cur:cur + 3]
        cur += 3
    if record:
        cap_det_ref, cap_gate_ref = outs[cur:cur + 2]
        cur += 2
    if jac_cols:
        jac_ref = outs[cur]
        cur += 1
    if stats:
        stats_ref = outs[cur]

    ntg = int(cfg.n_time_gates)

    # zero the (revisited) accumulator blocks on the first grid step only
    @pl.when(pl.program_id(0) == 0)
    def _():
        fluence_ref[...] = jnp.zeros_like(fluence_ref)
        exitance_ref[...] = jnp.zeros_like(exitance_ref)
        if n_det:
            det_w_ref[...] = jnp.zeros_like(det_w_ref)
            det_ppath_ref[...] = jnp.zeros_like(det_ppath_ref)
        if jac_cols:
            jac_ref[...] = jnp.zeros_like(jac_ref)

    labels = labels_ref[...]
    media = media_ref[...]
    state = ph.PhotonState(
        pos=pos_ref[...], dir=dir_ref[...], ivox=ivox_ref[...],
        w=w_ref[...], s_left=s_ref[...], t=t_ref[...], rng=rng_ref[...],
        alive=alive_ref[...] != 0,
    )
    n = state.w.shape[0]
    if n_det:
        det_geom = det_geom_ref[...]
    if jac_cols:
        jac_w = jac_w_ref[...]
        jac_col = jac_col_ref[...]

    def body(_, carry):
        st, flu, exi, esc, timed = carry[:5]
        cur = 5
        if n_det:
            pp, dw, dp = carry[cur:cur + 3]
            cur += 3
        if record:
            capd, capg = carry[cur:cur + 2]
            cur += 2
        if jac_cols:
            jac = carry[cur]
            cur += 1
        if stats:
            stbl = carry[cur]
        res = ph.step(st, labels, media, shape, unitinmm, cfg)
        gate = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
        flu = flu.at[res.dep_idx * ntg + gate].add(res.dep_w)
        xy, xw = ph.exitance_bins(res.esc_pos, res.esc_w, shape)
        exi = exi.at[xy].add(xw)
        esc = esc + res.esc_w
        timed = timed + res.timed_w
        out = (res.state, flu, exi, esc, timed)
        if n_det:
            pp, dw, dp = accumulate_capture(pp, dw, dp, res, gate,
                                            det_geom, ntg)
            out = out + (pp, dw, dp)
            if record:
                capd, capg = update_capture(capd, capg, res, gate, det_geom)
                out = out + (capd, capg)
        if jac_cols:
            # replay pass-B scatter (DESIGN.md §replay): each lane
            # deposits jac_w * seg_len into its fixed Jacobian column;
            # seg_len is 0 for dead lanes and jac_w is 0 for padding,
            # so masked lanes add exact zeros
            jac = jac.at[res.dep_idx * jac_cols + jac_col].add(
                jac_w * res.seg_len)
            out = out + (jac,)
        if stats:
            # telemetry counters (DESIGN.md §observability): col 0 counts
            # segments entered alive, col 1 sums deposited weight; pure
            # extra reductions, never read back by any physics value
            stbl = stbl + jnp.stack(
                [st.alive.astype(jnp.float32), res.dep_w], axis=1)
            out = out + (stbl,)
        return out

    init = (state, jnp.zeros_like(fluence_ref),
            jnp.zeros_like(exitance_ref), jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32))
    if n_det:
        init = init + (ppath_ref[...], jnp.zeros_like(det_w_ref),
                       jnp.zeros_like(det_ppath_ref))
    if record:
        init = init + (jnp.full((n,), -1, jnp.int32),
                       jnp.zeros((n,), jnp.int32))
    if jac_cols:
        init = init + (jnp.zeros_like(jac_ref),)
    if stats:
        init = init + (jnp.zeros((n, 2), jnp.float32),)
    final = jax.lax.fori_loop(0, n_steps, body, init)
    state, flu_add, exi_add, esc, timed = final[:5]

    out_pos[...] = state.pos
    out_dir[...] = state.dir
    out_ivox[...] = state.ivox
    out_w[...] = state.w
    out_s[...] = state.s_left
    out_t[...] = state.t
    out_rng[...] = state.rng
    out_alive[...] = state.alive.astype(jnp.int8)
    esc_ref[...] = esc
    timed_ref[...] = timed
    # accumulate this block's deposition into the shared output blocks
    fluence_ref[...] += flu_add
    exitance_ref[...] += exi_add
    cur = 5
    if n_det:
        pp, dw_add, dp_add = final[cur:cur + 3]
        cur += 3
        out_ppath[...] = pp
        det_w_ref[...] += dw_add
        det_ppath_ref[...] += dp_add
    if record:
        cap_det_ref[...] = final[cur]
        cap_gate_ref[...] = final[cur + 1]
        cur += 2
    if jac_cols:
        jac_ref[...] += final[cur]
        cur += 1
    if stats:
        stats_ref[...] = final[cur]


def photon_step_pallas(labels_flat, media, state: ph.PhotonState,
                       shape, unitinmm, cfg: SimConfig, n_steps: int,
                       block_lanes: int = 256,
                       interpret: bool | None = None,
                       ppath=None, det_geom=None, record: bool = False,
                       jac_w=None, jac_col=None, jac_cols: int = 0,
                       stats: bool = False):
    """Advance all lanes ``n_steps`` segments; returns
    ``(new_state, fluence_flat, exitance_flat, escaped_per_lane,
    timed_per_lane)`` — plus ``(ppath, det_w_flat, det_ppath)`` when
    detectors are configured, plus per-lane ``(cap_det, cap_gate)``
    int32 capture records when ``record`` is set (DESIGN.md §replay:
    detector index of this round's capture, -1 for none, and its exit
    time gate — the caller owns the global photon ids and appends the
    records to the fixed-capacity id buffer), plus a trailing
    ``(nvox * jac_cols,)`` replay-Jacobian accumulator when
    ``jac_cols > 0``: each lane scatter-adds ``jac_w * seg_len`` of
    every transport segment into column ``jac_col`` of its deposition
    voxel (``jac_w``/``jac_col`` are per-lane (n,) f32/int32 inputs —
    the exit-weight scale and fixed Jacobian column of the record being
    replayed; DESIGN.md §replay).

    ``stats=True`` appends one more lane-blocked ``(n, 2)`` float32
    output (always last): column 0 counts segments each lane entered
    alive, column 1 sums the lane's deposited weight over the round —
    the in-kernel half of the ``SimConfig.collect_stats`` telemetry
    counters (DESIGN.md §observability).  The block is accumulated
    alongside the physics carries and written per lane block; it never
    feeds back into any physics value, so every other output is
    bit-identical with ``stats`` on or off.

    ``fluence_flat`` is gate-major ``(nvox * cfg.n_time_gates,)``
    (``(nvox,)`` for the CW case, bit-identical to the ungated kernel),
    ``exitance_flat`` is (nx*ny,) — the z=0-face exitance image
    accumulated in-kernel over all ``n_steps`` segments;
    ``timed_per_lane`` is the weight each lane retired at the tmax_ns
    gate.  ``ppath`` is the (n, n_media) per-medium partial-pathlength
    state (pass the previous round's output back in) and ``det_geom``
    the (n_det, 3) array from ``repro.detectors.det_geometry`` —
    detector capture accumulates the flat ``(n_det * ntg,)`` TPSF
    histogram and the (n_det, n_media) weighted pathlength sums
    in-kernel.  ``interpret=None`` auto-detects the backend
    (:func:`default_interpret`).
    """
    if interpret is None:
        interpret = default_interpret()
    if (ppath is None) != (det_geom is None):
        raise ValueError("ppath and det_geom must be given together")
    jac_cols = int(jac_cols)
    if (jac_cols > 0) != (jac_w is not None) or \
            (jac_w is None) != (jac_col is None):
        raise ValueError("jac_w, jac_col and jac_cols > 0 must be given "
                         "together")
    n = state.w.shape[0]
    if n % block_lanes:
        raise ValueError(f"lane count {n} not divisible by {block_lanes}")
    nblocks = n // block_lanes
    nvox = labels_flat.shape[0]
    ntg = int(cfg.n_time_gates)
    nxy = shape[0] * shape[1]
    n_media = media.shape[0]
    n_det = 0 if det_geom is None else det_geom.shape[0]
    if record and not n_det:
        raise ValueError("record=True requires detectors (det_geom)")
    if not interpret:
        # compiled mode only: the interpreter has no VMEM to overflow,
        # and the CPU benches legitimately run configs (60^3, ntg=32)
        # the hardware budget rejects
        kspec.check_vmem(nvox, nxy, ntg, block_lanes, n_media, n_det,
                         record, jac_cols, stats)

    def lane_spec(extra=()):
        return pl.BlockSpec((block_lanes,) + extra,
                            lambda i: (i,) + (0,) * len(extra))

    def full_spec(*dims):
        return pl.BlockSpec(dims, lambda i, _nd=len(dims): (0,) * _nd)

    full_vol = full_spec(nvox * ntg)                       # revisited
    full_img = full_spec(nxy)                              # revisited
    full_media = full_spec(n_media, 4)

    out_shapes = [
        jax.ShapeDtypeStruct((n, 3), jnp.float32),   # pos
        jax.ShapeDtypeStruct((n, 3), jnp.float32),   # dir
        jax.ShapeDtypeStruct((n, 3), jnp.int32),     # ivox
        jax.ShapeDtypeStruct((n,), jnp.float32),     # w
        jax.ShapeDtypeStruct((n,), jnp.float32),     # s_left
        jax.ShapeDtypeStruct((n,), jnp.float32),     # t
        jax.ShapeDtypeStruct((n, 4), jnp.uint32),    # rng
        jax.ShapeDtypeStruct((n,), jnp.int8),        # alive
        jax.ShapeDtypeStruct((nvox * ntg,), jnp.float32),  # fluence (accum)
        jax.ShapeDtypeStruct((nxy,), jnp.float32),   # exitance (accumulated)
        jax.ShapeDtypeStruct((n,), jnp.float32),     # escaped weight
        jax.ShapeDtypeStruct((n,), jnp.float32),     # timed-out weight
    ]
    out_specs = [
        lane_spec((3,)), lane_spec((3,)), lane_spec((3,)),
        lane_spec(), lane_spec(), lane_spec(),
        lane_spec((4,)), lane_spec(),
        full_vol, full_img, lane_spec(), lane_spec(),
    ]
    in_specs = [
        full_spec(nvox), full_media,
        lane_spec((3,)), lane_spec((3,)), lane_spec((3,)),
        lane_spec(), lane_spec(), lane_spec(),
        lane_spec((4,)), lane_spec(),
    ]
    operands = [labels_flat, media,
                state.pos, state.dir, state.ivox, state.w, state.s_left,
                state.t, state.rng, state.alive.astype(jnp.int8)]
    if n_det:
        in_specs += [lane_spec((n_media,)), full_spec(n_det, 3)]
        operands += [ppath, det_geom]
        out_shapes += [
            jax.ShapeDtypeStruct((n, n_media), jnp.float32),      # ppath
            jax.ShapeDtypeStruct((n_det * ntg,), jnp.float32),    # det TPSF
            jax.ShapeDtypeStruct((n_det, n_media), jnp.float32),  # det ppath
        ]
        out_specs += [lane_spec((n_media,)), full_spec(n_det * ntg),
                      full_spec(n_det, n_media)]
    if jac_cols:
        in_specs += [lane_spec(), lane_spec()]
        operands += [jac_w, jac_col]
    if record:
        out_shapes += [
            jax.ShapeDtypeStruct((n,), jnp.int32),   # cap_det (-1: none)
            jax.ShapeDtypeStruct((n,), jnp.int32),   # cap_gate
        ]
        out_specs += [lane_spec(), lane_spec()]
    if jac_cols:
        out_shapes += [
            jax.ShapeDtypeStruct((nvox * jac_cols,), jnp.float32),  # jac
        ]
        out_specs += [full_spec(nvox * jac_cols)]              # revisited
    if stats:
        out_shapes += [
            jax.ShapeDtypeStruct((n, 2), jnp.float32),   # telemetry block
        ]
        out_specs += [lane_spec((2,))]

    kernel = functools.partial(
        _kernel, shape=shape, unitinmm=unitinmm, cfg=cfg, n_steps=n_steps,
        n_det=n_det, record=record, jac_cols=jac_cols, stats=stats)
    outs = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(*operands)

    assert len(outs) == kspec.output_arity(
        n_det, record, jac_cols, stats, packed_state=False), \
        "pallas output list drifted from kernels/photon_step/spec.py"
    new_state = ph.PhotonState(
        pos=outs[0], dir=outs[1], ivox=outs[2], w=outs[3], s_left=outs[4],
        t=outs[5], rng=outs[6], alive=outs[7] != 0,
    )
    return (new_state,) + tuple(outs[8:])
