"""Committed-baseline handling for reprolint.

The baseline file (``.reprolint.json`` at the repo root) grandfathers
pre-existing findings so a new rule can land before every legacy
violation is fixed: CI fails only on findings *not* covered by the
baseline.  The format is a fingerprint -> count map — a fingerprint
hashes (rule, path, normalized line text — comments and whitespace
stripped), so findings survive line moves and whitespace/comment-only
edits but are re-surfaced when the offending line's content changes.

The traced tier (tracelint) reuses this format for ``.tracelint.json``
with message-based fingerprints (jaxprs have no source lines).

Policy: prefer fixing or pragma-annotating over baselining — the
baseline is a ratchet for rule rollout, not a parking lot.  The repo
is currently fully clean and the committed baseline is empty.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.lint import LintReport

BASELINE_NAME = ".reprolint.json"
FORMAT_VERSION = 1


def baseline_path(root: Path | str) -> Path:
    return Path(root) / BASELINE_NAME


def load_baseline(path: Path | str) -> dict[str, int]:
    """Fingerprint -> count map; empty when the file doesn't exist."""
    path = Path(path)
    if not path.is_file():
        return {}
    data = json.loads(path.read_text())
    if data.get("version") != FORMAT_VERSION:
        raise ValueError(
            f"{path}: unsupported baseline version {data.get('version')!r} "
            f"(this reprolint writes version {FORMAT_VERSION}; regenerate "
            f"with --write-baseline)")
    counts = data.get("findings", {})
    if not isinstance(counts, dict) or \
            not all(isinstance(v, int) and v > 0 for v in counts.values()):
        raise ValueError(f"{path}: malformed findings map")
    return dict(counts)


def save_baseline(path: Path | str, report: LintReport) -> dict[str, int]:
    """Write the report's live findings as the new baseline."""
    counts: dict[str, int] = {}
    for f in report.findings:
        counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
    payload = {
        "version": FORMAT_VERSION,
        "comment": ("reprolint grandfathered findings: fingerprint -> "
                    "count; regenerate with "
                    "`python -m repro.lint --write-baseline`"),
        "findings": dict(sorted(counts.items())),
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return counts
