"""reprolint CLI:  python -m repro.lint [options]

Exit codes: 0 clean (or fully baselined), 1 findings, 2 usage/internal
error.  CI runs ``--format json`` before the test lanes and fails on
any non-baselined finding (.github/workflows/ci.yml `lint` job).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.lint import run_lint
from repro.lint.baseline import (baseline_path, load_baseline,
                                 save_baseline)
from repro.lint.rules import ALL_RULES


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-specific static analysis (engine parity, "
                    "determinism, dtype, VMEM; DESIGN.md "
                    "§static-analysis)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (default: cwd)")
    ap.add_argument("--format", choices=("human", "json"),
                    default="human")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids/names to run "
                         "(default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: <root>/.reprolint.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report grandfathered findings too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for cls in ALL_RULES:
            r = cls()
            print(f"{r.id}  {r.name:<14} [{r.severity}] {r.description}")
        return 0

    root = Path(args.root).resolve()
    if not root.is_dir():
        print(f"error: --root {root} is not a directory", file=sys.stderr)
        return 2
    bpath = Path(args.baseline) if args.baseline else baseline_path(root)
    try:
        base = {} if (args.no_baseline or args.write_baseline) else \
            load_baseline(bpath)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    rule_ids = None
    if args.rules:
        rule_ids = [r.strip() for r in args.rules.split(",") if r.strip()]
    report = run_lint(root, baseline=base, rule_ids=rule_ids)

    if args.write_baseline:
        counts = save_baseline(bpath, report)
        print(f"wrote {bpath} ({sum(counts.values())} grandfathered "
              f"finding(s) across {len(counts)} fingerprint(s))")
        return 0

    if args.format == "json":
        print(json.dumps(report.to_json(), indent=2))
    else:
        for f in report.findings:
            print(f.format())
        supp = []
        if report.suppressed_pragma:
            supp.append(f"{report.suppressed_pragma} pragma-disabled")
        if report.suppressed_baseline:
            supp.append(f"{report.suppressed_baseline} baselined")
        tail = f" ({', '.join(supp)})" if supp else ""
        if report.clean:
            print(f"reprolint: clean — {report.n_modules} modules, "
                  f"{len(report.rules_run)} rules{tail}")
        else:
            print(f"reprolint: {len(report.findings)} finding(s) over "
                  f"{report.n_modules} modules{tail}")
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
