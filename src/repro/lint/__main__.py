"""reprolint CLI:  python -m repro.lint [options]

Two analysis tiers (``--tier``):

* ``ast`` (default) — the dependency-free source-level rules (REP1xx-
  REP7xx); never imports the code under analysis, safe in the jax-free
  CI lint job.
* ``traced`` — tracelint (REP8xx): traces the real entrypoints to
  closed jaxprs and lints the traced programs.  Needs jax.
* ``all`` — both.

Exit codes: 0 clean (or fully baselined/allowlisted), 1 findings, 2
usage/internal error.  ``--format github`` emits workflow-command
annotations (``::error file=...``) so findings render inline on PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.lint import LintReport, run_lint
from repro.lint.baseline import (baseline_path, load_baseline,
                                 save_baseline)
from repro.lint.rules import ALL_RULES

_TIERS = ("ast", "traced", "all")


def _github_escape(text: str) -> str:
    # workflow-command data: percent-encode the control characters
    return (text.replace("%", "%25").replace("\r", "%0D")
            .replace("\n", "%0A"))


def _emit_github(report: LintReport) -> None:
    for f in report.findings:
        kind = "error" if f.severity == "error" else "warning"
        print(f"::{kind} file={_github_escape(f.path)},line={f.line},"
              f"col={f.col},title={f.rule}[{f.name}]::"
              f"{_github_escape(f.message)}")


def _emit_human(label: str, report: LintReport, unit: str) -> None:
    for f in report.findings:
        print(f.format())
    supp = []
    if report.suppressed_pragma:
        kind = "allowlisted" if label == "tracelint" else "pragma-disabled"
        supp.append(f"{report.suppressed_pragma} {kind}")
    if report.suppressed_baseline:
        supp.append(f"{report.suppressed_baseline} baselined")
    tail = f" ({', '.join(supp)})" if supp else ""
    if report.clean:
        print(f"{label}: clean — {report.n_modules} {unit}, "
              f"{len(report.rules_run)} rules{tail}")
    else:
        print(f"{label}: {len(report.findings)} finding(s) over "
              f"{report.n_modules} {unit}{tail}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-specific static analysis (engine parity, "
                    "determinism, dtype, VMEM, traced jaxprs; "
                    "DESIGN.md §static-analysis)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (default: cwd)")
    ap.add_argument("--tier", choices=_TIERS, default="ast",
                    help="analysis tier: ast (source rules, no jax), "
                         "traced (jaxpr rules, needs jax), or all")
    ap.add_argument("--format", choices=("human", "json", "github"),
                    default="human")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids/names to run "
                         "(default: all in the selected tier)")
    ap.add_argument("--baseline", default=None,
                    help="AST-tier baseline file (default: "
                         "<root>/.reprolint.json)")
    ap.add_argument("--traced-baseline", default=None,
                    help="traced-tier baseline file (default: "
                         "<root>/.tracelint.json)")
    ap.add_argument("--allowlist", default=None,
                    help="traced-tier allowlist file (default: "
                         "<root>/.tracelint-allow.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report grandfathered findings too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the selected tier(s)' findings as the "
                         "new baseline(s) and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        rules = [cls() for cls in ALL_RULES]
        if args.tier in ("traced", "all"):
            from repro.lint.traced.rules import TRACED_RULES
            traced = [cls() for cls in TRACED_RULES]
            rules = traced if args.tier == "traced" else rules + traced
        for r in rules:
            print(f"{r.id}  {r.name:<14} [{r.severity}] {r.description}")
        return 0

    root = Path(args.root).resolve()
    if not root.is_dir():
        print(f"error: --root {root} is not a directory", file=sys.stderr)
        return 2

    rule_ids = None
    if args.rules:
        rule_ids = [r.strip() for r in args.rules.split(",") if r.strip()]

    skip_base = args.no_baseline or args.write_baseline
    reports: dict[str, LintReport] = {}
    try:
        if args.tier in ("ast", "all"):
            bpath = Path(args.baseline) if args.baseline else \
                baseline_path(root)
            base = {} if skip_base else load_baseline(bpath)
            reports["ast"] = run_lint(root, baseline=base,
                                      rule_ids=rule_ids)
            if args.write_baseline:
                counts = save_baseline(bpath, reports["ast"])
                print(f"wrote {bpath} ({sum(counts.values())} "
                      f"grandfathered finding(s) across {len(counts)} "
                      f"fingerprint(s))")
        if args.tier in ("traced", "all"):
            from repro.lint.traced import (allowlist_path, load_allowlist,
                                           run_traced_lint,
                                           traced_baseline_path)
            tbpath = Path(args.traced_baseline) if args.traced_baseline \
                else traced_baseline_path(root)
            tbase = {} if skip_base else load_baseline(tbpath)
            apath = Path(args.allowlist) if args.allowlist else \
                allowlist_path(root)
            allow = load_allowlist(apath)
            reports["traced"] = run_traced_lint(
                root, rule_ids=rule_ids, baseline=tbase, allowlist=allow)
            if args.write_baseline:
                counts = save_baseline(tbpath, reports["traced"])
                print(f"wrote {tbpath} ({sum(counts.values())} "
                      f"grandfathered finding(s) across {len(counts)} "
                      f"fingerprint(s))")
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        return 0

    clean = all(r.clean for r in reports.values())
    if args.format == "json":
        if args.tier == "all":
            payload = {"version": 1, "clean": clean,
                       "tiers": {k: r.to_json()
                                 for k, r in reports.items()}}
        else:
            payload = reports[args.tier].to_json()
            payload["tier"] = args.tier
        print(json.dumps(payload, indent=2))
    elif args.format == "github":
        for rep in reports.values():
            _emit_github(rep)
        labels = " + ".join(sorted(reports))
        n = sum(len(r.findings) for r in reports.values())
        print(f"lint[{labels}]: " +
              ("clean" if clean else f"{n} finding(s)"))
    else:
        if "ast" in reports:
            _emit_human("reprolint", reports["ast"], "modules")
        if "traced" in reports:
            _emit_human("tracelint", reports["traced"], "targets")
    return 0 if clean else 1


if __name__ == "__main__":
    sys.exit(main())
