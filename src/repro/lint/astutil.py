"""AST helpers shared by the reprolint rules.

Everything here works on plain ``ast`` trees — reprolint never imports
the modules it checks (linting must not initialize the JAX backend,
and must work on fixture trees that aren't importable at all).  The
two workhorses are the import-alias map (so ``np.random.rand`` and
``numpy.random.rand`` and ``from numpy import random; random.rand``
all resolve to the same dotted name) and the literal-constant loader
used to read ``kernels/photon_step/spec.py`` without executing it.
"""

from __future__ import annotations

import ast
from typing import Iterator


def build_alias_map(tree: ast.AST, package: str = "") -> dict[str, str]:
    """Map local names to fully-dotted import targets.

    ``import numpy as np``            -> {"np": "numpy"}
    ``import jax.numpy as jnp``       -> {"jnp": "jax.numpy"}
    ``import jax.numpy``              -> {"jax": "jax"}
    ``from numpy import random``      -> {"random": "numpy.random"}
    ``from x import y as z``          -> {"z": "x.y"}
    ``from . import volume`` (in package p) -> {"volume": "p.volume"}

    Collected over the whole tree (function-local imports included) —
    alias resolution is about *naming*, reachability scope is handled
    separately by the import-graph walk.
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    # "import x.y" binds the root package name
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom):
            base = resolve_from_module(node, package)
            if base is None:
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{base}.{a.name}"
    return aliases


def resolve_from_module(node: ast.ImportFrom, package: str) -> str | None:
    """Absolute module a ``from X import ...`` pulls from, or None."""
    if node.level == 0:
        return node.module
    # relative import: strip (level - 1) trailing components off the
    # importing module's package
    parts = package.split(".") if package else []
    if node.level - 1 > len(parts):
        return None
    base = parts[:len(parts) - (node.level - 1)]
    if node.module:
        base = base + node.module.split(".")
    return ".".join(base) if base else None


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain as a string, or None for non-chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted name with its leading alias expanded (np.x -> numpy.x)."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    if head in aliases:
        return aliases[head] + ("." + rest if rest else "")
    return name


def matches_prefix(name: str, prefixes: tuple[str, ...]) -> str | None:
    """The prefix ``name`` falls under, respecting dot boundaries."""
    for p in prefixes:
        if name == p or name.startswith(p + "."):
            return p
    return None


def load_literal_constants(tree: ast.AST) -> dict[str, object]:
    """Module-level ``NAME = <literal>`` assignments, literal-evaled.

    Used to read the kernel output-spec constants from spec.py without
    importing it; non-literal assignments are silently skipped.
    """
    out: dict[str, object] = {}
    for node in getattr(tree, "body", []):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            try:
                out[node.targets[0].id] = ast.literal_eval(node.value)
            except (ValueError, TypeError, SyntaxError):
                pass
    return out


def find_function(tree: ast.AST, name: str) -> ast.FunctionDef | None:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name == name:
            return node
    return None


def param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def is_subsequence(sub: tuple[str, ...], seq: list[str]) -> bool:
    it = iter(seq)
    return all(x in it for x in sub)


def test_flag_names(test: ast.AST) -> set[str]:
    """Plain names appearing in an ``if`` test (the guard flags)."""
    return {n.id for n in ast.walk(test) if isinstance(n, ast.Name)}


def literal_env(fn: ast.FunctionDef,
                module_tree: ast.AST | None = None) -> dict[str, ast.AST]:
    """Map of simple single-target assignments visible inside a function.

    Supports constant propagation for the VMEM rule: ``shape = (60, 60,
    60)`` followed by ``photon_step_pallas(..., shape, ...)``, including
    aliases (``shp = shape``) via :func:`resolve_literal` /
    :func:`chase_names`.  When ``module_tree`` is given, module-level
    single assignments seed the environment (``SHAPE = (60, 60, 60)``
    at the top of the file), with function-local bindings shadowing
    them.  Names rebound more than once in a scope are dropped (their
    value at the call site is ambiguous).
    """
    env: dict[str, ast.AST] = {}
    if module_tree is not None:
        seen: set[str] = set()
        for node in getattr(module_tree, "body", []):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                if name in seen:
                    env.pop(name, None)
                else:
                    seen.add(name)
                    env[name] = node.value
    rebound: set[str] = set()
    local: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if name in local or name in rebound:
                env.pop(name, None)
                rebound.add(name)
            else:
                local.add(name)
                env[name] = node.value
    return env


def chase_names(node: ast.AST | None, env: dict[str, ast.AST],
                depth: int = 4) -> ast.AST | None:
    """Follow single-assignment ``Name`` bindings to the defining
    expression (``cfg2 = cfg``; ``cfg = SimConfig(...)`` — returns the
    ``SimConfig(...)`` call).  Stops at non-Name nodes, unknown names,
    or the depth cap (self-referential chains)."""
    while depth > 0 and isinstance(node, ast.Name) and node.id in env:
        nxt = env[node.id]
        if nxt is node:
            break
        node = nxt
        depth -= 1
    return node


def resolve_literal(node: ast.AST | None, env: dict[str, ast.AST],
                    _depth: int = 0) -> object:
    """Literal value of an expression, chasing one level of locals.

    Returns the sentinel :data:`UNRESOLVED` when the expression cannot
    be reduced to a Python literal statically.
    """
    if node is None or _depth > 4:
        return UNRESOLVED
    if isinstance(node, ast.Name) and node.id in env:
        return resolve_literal(env[node.id], env, _depth + 1)
    try:
        return ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError):
        return UNRESOLVED


class _Unresolved:
    def __repr__(self) -> str:  # pragma: no cover - repr only
        return "<unresolved>"


UNRESOLVED = _Unresolved()


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
