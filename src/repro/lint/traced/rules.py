"""REP8xx — the traced-tier rule registry.

Every rule walks closed jaxprs via :func:`repro.lint.traced.iter_eqns`
and yields :class:`~repro.lint.Finding`s anchored to the target's
entry file.  Adding a rule: subclass :class:`TracedRule` here, append
it to ``TRACED_RULES``, add positive + negative fixture tests to
tests/test_tracelint.py, and document it in DESIGN.md
§static-analysis.
"""

from __future__ import annotations

from typing import Iterator

from repro.lint import Finding
from repro.lint.traced import (TraceTarget, TracedRule, iter_eqns,
                               jaxpr_fingerprint)

# dtypes that must never appear in a traced program: the portability
# contract is float32/int32-class everywhere (DESIGN.md §dtype)
_WIDE_DTYPES = frozenset({"float64", "complex128", "complex64",
                          "int64", "uint64"})

# host-transfer primitives: inside the round loop each one is a
# device->host sync per iteration
_CALLBACK_PRIMS = frozenset({"pure_callback", "debug_callback",
                             "io_callback", "infeed", "outfeed",
                             "device_get", "host_callback"})

# scatter modes whose result depends on the order duplicate indices
# are applied in (add/min/max are order-dependent only under
# non-associative fp accumulation; plain scatter overwrites)
_SCATTER_PRIMS = frozenset({"scatter", "scatter-add", "scatter-sub",
                            "scatter-mul", "scatter-min", "scatter-max"})

# size-preserving unary reshapes the index-provenance analysis sees
# through
_PASSTHROUGH_PRIMS = frozenset({"copy", "convert_element_type",
                                "reshape", "squeeze", "expand_dims",
                                "rev"})


def _dtype_str(aval) -> str | None:
    d = getattr(aval, "dtype", None)
    return None if d is None else str(d)


class TracedDtypeRule(TracedRule):
    id = "REP801"
    name = "traced-dtype"
    severity = "error"
    description = ("no f64/i64/complex values or weak-typed float "
                   "promotion anywhere in a traced program")

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        for t in targets:
            closed = t.jaxpr()
            seen: set[tuple] = set()

            def emit(kind, detail, target=t, seen=seen):
                if (kind, detail) in seen:
                    return None
                seen.add((kind, detail))
                return self.finding(target, detail)

            for i, av in enumerate(closed.out_avals):
                d = _dtype_str(av)
                if d is None:
                    continue
                if d in _WIDE_DTYPES:
                    f = emit("out-wide", f"entrypoint output {i} has wide "
                             f"dtype {d} — traced programs are "
                             f"f32/i32-class only")
                    if f:
                        yield f
                elif getattr(av, "weak_type", False) and \
                        d.startswith("float"):
                    f = emit("out-weak", f"entrypoint output {i} is "
                             f"weak-typed {d} — a Python scalar leaked "
                             f"into the outputs (promotion depends on "
                             f"the caller)")
                    if f:
                        yield f
            for var in closed.jaxpr.constvars:
                d = _dtype_str(getattr(var, "aval", None))
                if d in _WIDE_DTYPES:
                    f = emit("const-wide", f"closed-over constant has wide "
                             f"dtype {d}")
                    if f:
                        yield f
            for _jaxpr, eqn, _depth in iter_eqns(closed):
                prim = eqn.primitive.name
                for var in eqn.outvars:
                    av = getattr(var, "aval", None)
                    d = _dtype_str(av)
                    if d is None:
                        continue
                    if d in _WIDE_DTYPES:
                        f = emit("eqn-wide", f"`{prim}` produces wide dtype "
                                 f"{d} inside the trace")
                        if f:
                            yield f
                    elif getattr(av, "weak_type", False) and \
                            d.startswith("float"):
                        # weak *ints* are jax-internal loop counters
                        # (fori_loop lowers its bounds weakly); weak
                        # floats mean a bare Python float is steering
                        # promotion mid-trace
                        f = emit("eqn-weak", f"`{prim}` produces a "
                                 f"weak-typed {d} — a bare Python float "
                                 f"is steering promotion inside the "
                                 f"trace")
                        if f:
                            yield f


# ---------------------------------------------------------------------------
# REP802 — scatter-race / nondeterministic accumulation
# ---------------------------------------------------------------------------

def _const_scalar(atom, producers, depth=0):
    """Python scalar value of an atom, chasing broadcasts of literals."""
    import numpy as np
    val = getattr(atom, "val", None)
    if val is not None:  # Literal: scalar or nothing (may be unhashable)
        if np.ndim(val) == 0:
            return val.item() if hasattr(val, "item") else val
        return None
    if depth > 4:
        return None
    eqn = producers.get(atom)
    if eqn is not None and eqn.primitive.name in (
            "broadcast_in_dim", "convert_element_type", "copy"):
        return _const_scalar(eqn.invars[0], producers, depth + 1)
    return None


def _affine_of(var, producers, depth=0):
    """Prove ``var``'s elements form ``{scale*i + o : o in offsets}``
    over one iota — the shape every lane-disjoint accumulator index
    has.  Returns ``(root, scale, offsets, length)`` or None.
    """
    if depth > 16:
        return None
    if getattr(var, "val", None) is not None:
        return None  # Literal arrays are handled by the caller
    eqn = producers.get(var)
    if eqn is None:
        return None
    prim = eqn.primitive.name
    if prim == "iota":
        shape = var.aval.shape
        dim = eqn.params.get("dimension", 0)
        if not shape:
            return None
        return (var, 1, frozenset({0}), int(shape[dim]))
    if prim in _PASSTHROUGH_PRIMS and prim != "rev":
        return _affine_of(eqn.invars[0], producers, depth + 1)
    if prim == "broadcast_in_dim":
        import numpy as np
        src = eqn.invars[0]
        if np.prod(getattr(src.aval, "shape", (0,)), dtype=int) == \
                np.prod(var.aval.shape, dtype=int):
            return _affine_of(src, producers, depth + 1)
        return None  # true broadcast duplicates values: never injective
    if prim in ("add", "sub"):
        a, b = eqn.invars
        ca = _const_scalar(a, producers)
        cb = _const_scalar(b, producers)
        if cb is not None:
            base = _affine_of(a, producers, depth + 1)
            if base is None:
                return None
            root, s, offs, n = base
            d = cb if prim == "add" else -cb
            return (root, s, frozenset(o + d for o in offs), n)
        if ca is not None and prim == "add":
            base = _affine_of(b, producers, depth + 1)
            if base is None:
                return None
            root, s, offs, n = base
            return (root, s, frozenset(o + ca for o in offs), n)
        if ca is not None and prim == "sub":  # c - x: negate the map
            base = _affine_of(b, producers, depth + 1)
            if base is None:
                return None
            root, s, offs, n = base
            return (root, -s, frozenset(ca - o for o in offs), n)
        return None
    if prim == "mul":
        a, b = eqn.invars
        for x, c in ((a, _const_scalar(b, producers)),
                     (b, _const_scalar(a, producers))):
            if c is not None and c != 0:
                base = _affine_of(x, producers, depth + 1)
                if base is None:
                    return None
                root, s, offs, n = base
                return (root, s * c, frozenset(o * c for o in offs), n)
        return None
    if prim == "select_n":
        infos = [_affine_of(v, producers, depth + 1)
                 for v in eqn.invars[1:]]
        if any(i is None for i in infos):
            return None
        roots = {i[0] for i in infos}
        scales = {i[1] for i in infos}
        if len(roots) != 1 or len(scales) != 1:
            return None
        root = infos[0][0]
        scale = infos[0][1]
        length = infos[0][3]
        offs = frozenset().union(*(i[2] for i in infos))
        return (root, scale, offs, length)
    return None


def _indices_provably_disjoint(idx_var, producers) -> bool:
    """True when every element of the scatter-index operand is provably
    distinct (so duplicate-index accumulation order cannot matter)."""
    import numpy as np
    val = getattr(idx_var, "val", None)  # Literal indices: check directly
    if val is not None:
        arr = np.asarray(val).reshape(-1, np.asarray(val).shape[-1]) \
            if np.ndim(val) > 1 else np.asarray(val).reshape(-1, 1)
        return len(np.unique(arr, axis=0)) == arr.shape[0]
    info = _affine_of(idx_var, producers)
    if info is None:
        return False
    _root, scale, offsets, length = info
    if scale == 0:
        return False
    offs = sorted(offsets)
    gap = abs(scale) * length
    # distinct branches of the map never collide when their offset
    # bands (width |scale|*length) don't overlap
    return all(b - a >= gap for a, b in zip(offs, offs[1:]))


class ScatterRaceRule(TracedRule):
    id = "REP802"
    name = "scatter-race"
    severity = "error"
    description = ("scatter accumulations whose indices can alias "
                   "across lanes need a deterministic merge (symbolic "
                   "disjointness check)")

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        for t in targets:
            for jaxpr, eqn, _depth in iter_eqns(t.jaxpr()):
                prim = eqn.primitive.name
                if prim not in _SCATTER_PRIMS:
                    continue
                if eqn.params.get("unique_indices"):
                    continue  # caller asserts disjointness
                producers = {}
                for e in jaxpr.eqns:
                    for v in e.outvars:
                        producers[v] = e
                idx = eqn.invars[1]
                if _indices_provably_disjoint(idx, producers):
                    continue
                out = eqn.outvars[0].aval
                yield self.finding(
                    t, f"`{prim}` onto {out.dtype}{list(out.shape)} with "
                       f"alias-capable indices — accumulation order is "
                       f"unordered on atomic backends; prove "
                       f"lane-disjointness, pre-sort/segment the "
                       f"indices, or allowlist with the serialization "
                       f"argument")


class HostSyncRule(TracedRule):
    id = "REP803"
    name = "host-sync"
    severity = "error"
    description = ("no host callbacks / transfers inside the traced "
                   "round loop (one device->host sync per iteration)")

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        for t in targets:
            seen: set[str] = set()
            for _jaxpr, eqn, depth in iter_eqns(t.jaxpr()):
                prim = eqn.primitive.name
                if prim in _CALLBACK_PRIMS and depth >= 1 and \
                        prim not in seen:
                    seen.add(prim)
                    yield self.finding(
                        t, f"`{prim}` executes inside the round loop "
                           f"(loop depth {depth}) — that is a host "
                           f"sync per iteration; hoist it out of the "
                           f"loop or accumulate on-device")


class EngineParityRule(TracedRule):
    id = "REP804"
    name = "engine-parity"
    severity = "error"
    description = ("targets in one parity group (jnp vs pallas) must "
                   "produce identical output avals")

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        groups: dict[str, list[TraceTarget]] = {}
        for t in targets:
            if t.group:
                groups.setdefault(t.group, []).append(t)
        for name in sorted(groups):
            members = groups[name]
            if len(members) < 2:
                continue
            ref = members[0]
            ra = list(ref.jaxpr().out_avals)
            for other in members[1:]:
                oa = list(other.jaxpr().out_avals)
                if len(oa) != len(ra):
                    yield self.finding(
                        other, f"parity group `{name}`: {len(oa)} "
                               f"outputs vs {len(ra)} from "
                               f"{ref.name} — the engines' output "
                               f"contracts diverged")
                    continue
                for i, (a, b) in enumerate(zip(ra, oa)):
                    sig_a = (getattr(a, "shape", None), _dtype_str(a),
                             getattr(a, "weak_type", False))
                    sig_b = (getattr(b, "shape", None), _dtype_str(b),
                             getattr(b, "weak_type", False))
                    if sig_a != sig_b:
                        yield self.finding(
                            other, f"parity group `{name}`: output {i} "
                                   f"is {sig_b[1]}{list(sig_b[0] or ())} "
                                   f"(weak={sig_b[2]}) vs "
                                   f"{sig_a[1]}{list(sig_a[0] or ())} "
                                   f"(weak={sig_a[2]}) from {ref.name}")


class RecompileChurnRule(TracedRule):
    id = "REP805"
    name = "recompile-churn"
    severity = "error"
    description = ("dynamic call arguments (photon count, seed, id "
                   "offset) must not change the traced program — the "
                   "compile-cache key depends on it")

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        for t in targets:
            base = jaxpr_fingerprint(t.jaxpr())
            for vname in sorted(t.variants):
                overrides = t.variants[vname]
                try:
                    varied = t.make(overrides)
                except Exception as e:
                    yield self.finding(
                        t, f"perturbing dynamic field `{vname}` "
                           f"({overrides}) failed to trace "
                           f"({type(e).__name__}: {e}) — the field is "
                           f"concretized at trace time and forces a "
                           f"retrace per value")
                    continue
                if jaxpr_fingerprint(varied) != base:
                    yield self.finding(
                        t, f"perturbing dynamic field `{vname}` "
                           f"({overrides}) changed the jaxpr — the "
                           f"value is baked into the trace, so every "
                           f"new value recompiles (churns the "
                           f"simulate_many compile cache)")


TRACED_RULES = (
    TracedDtypeRule,     # REP801 traced dtype discipline
    ScatterRaceRule,     # REP802 nondeterministic accumulation
    HostSyncRule,        # REP803 host sync in the round loop
    EngineParityRule,    # REP804 jnp-vs-pallas output parity
    RecompileChurnRule,  # REP805 recompile-key churn
)

__all__ = ["TRACED_RULES", "TracedDtypeRule", "ScatterRaceRule",
           "HostSyncRule", "EngineParityRule", "RecompileChurnRule"]
