"""Default tracelint targets: the stack's real traced entrypoints.

Every target traces a *small* configuration — (8, 8, 8) volume, 16
lanes — because tracelint cares about the program structure (dtypes,
scatter provenance, callbacks, output avals), none of which depend on
problem size.  Tracing stays cheap enough to gate CI.

Coverage map:

* ``sim-jnp`` / ``sim-pallas`` — ``build_sim_fn`` with the full
  feature surface on: fused rounds (K=2), time gates, a detector,
  record buffer and round stats, per engine.  Shared REP804 group
  ``sim`` — the engines' SimResult avals must agree exactly.
* ``replay-jnp`` / ``replay-pallas`` — the two-pass Jacobian replay
  (group ``replay``).
* ``pool-jnp`` / ``pool-pallas`` — the resilience pool's per-bit-class
  jitted executors, traced exactly as ``DevicePool._dispatch`` would
  call them (group ``pool``).
* ``simulate-many-jnp`` / ``simulate-many-pallas`` — the batched
  multi-scenario executor (``repro.scenarios.make_batched``): the
  round loop vmapped over a 3-scenario axis with per-scenario media
  tables, staged disk sources, detector geometry, seeds and budgets
  all traced (group ``simulate-many``).  Its REP805 variants perturb
  every one of those values — a fingerprint divergence means the
  compile cache would re-trace per scenario batch, defeating it.
* ``sharded-sim`` — the shard_mapped mesh builder, only when more than
  one device is visible (CI runs this under 8 fake CPU devices so the
  collective/psum structure is linted too).

Each target declares REP805 ``variants`` perturbing the *dynamic* call
arguments (photon count, seed, 64-bit id offset).  Those are traced
arguments by contract — "one executable serves pilot runs and
production runs" (simulator.build_sim_fn docstring) — so the jaxpr
must be bit-identical under any value change; a divergence means a
retrace per value, which is exactly the churn the simulate_many
compile cache cannot absorb.
"""

from __future__ import annotations

from repro.lint.traced import TraceTarget

# entry files findings anchor to (repo-relative)
_SIM_ENTRY = "src/repro/core/simulator.py"
_REPLAY_ENTRY = "src/repro/replay/__init__.py"
_POOL_ENTRY = "src/repro/resilience/pool.py"
_MANY_ENTRY = "src/repro/scenarios/__init__.py"
_MESH_ENTRY = "src/repro/core/multidevice.py"

_SHAPE = (8, 8, 8)
_LANES = 16
_BLOCK = 8


def _sim_cfg():
    from repro.core.volume import SimConfig
    return SimConfig(do_reflect=True, steps_per_round=2, n_time_gates=2,
                     max_steps=64, collect_stats=True)


def _volume():
    from repro.core import volume as V
    return V.benchmark_b1(_SHAPE)


def _detectors():
    from repro.detectors import Detector
    return (Detector(x=4.0, y=4.0, radius=2.0),)


def _sim_args(overrides=None):
    """Canonical dynamic args for a sim_fn trace, override-able."""
    import jax.numpy as jnp
    vol = _volume()
    ov = overrides or {}
    return (vol.labels.reshape(-1), vol.media,
            jnp.int32(ov.get("n_photons", 64)),
            jnp.uint32(ov.get("seed", 1234)),
            jnp.uint32(ov.get("id_offset", 0)),
            jnp.uint32(ov.get("id_offset_hi", 0)))


# the REP805 perturbation matrix shared by every sim-shaped target:
# each key is a dynamic field; its trace must match the canonical one
_SIM_VARIANTS = {
    "n_photons": {"n_photons": 4096},
    "seed": {"seed": 99},
    "id_offset": {"id_offset": 123456, "id_offset_hi": 7},
}


def _make_sim(engine):
    def make(overrides=None):
        import jax

        from repro.core.simulator import build_sim_fn
        vol = _volume()
        fn = build_sim_fn(vol.shape, vol.unitinmm, _sim_cfg(), _LANES,
                          "dynamic", None, engine, block_lanes=_BLOCK,
                          interpret=True, detectors=_detectors(),
                          record_detected=8)
        return jax.make_jaxpr(fn)(*_sim_args(overrides))
    return make


def _make_replay(engine):
    def make(overrides=None):
        import jax
        import jax.numpy as jnp

        from repro.detectors import det_geometry, validate_detectors
        from repro.replay import _build_replay_fn
        vol = _volume()
        dets = _detectors()
        validate_detectors(dets, vol.shape)
        fn = _build_replay_fn(vol.shape, vol.unitinmm, _sim_cfg(), _LANES,
                              len(dets), None, det_geometry(dets),
                              jac_cols=len(dets), engine=engine,
                              block_lanes=_BLOCK, interpret=True)
        ov = overrides or {}
        ids = jnp.zeros((_LANES,), jnp.uint32)
        return jax.make_jaxpr(fn)(
            vol.labels.reshape(-1), vol.media,
            ids + jnp.uint32(ov.get("id_offset", 0)), ids,
            jnp.zeros((_LANES,), jnp.int32),
            jnp.ones((_LANES,), jnp.bool_),
            jnp.uint32(ov.get("seed", 1234)))
    return make


def _make_pool(engine):
    def make(overrides=None):
        import jax

        from repro.resilience.pool import DevicePool, DeviceSpec
        pool = DevicePool(_volume(), _sim_cfg(),
                          specs=[DeviceSpec(engine=engine, n_lanes=_LANES)],
                          detectors=_detectors(), record_detected=8)
        fn = pool._fn_for(pool._default_source, pool._classes[0])
        return jax.make_jaxpr(fn)(*_sim_args(overrides))
    return make


# the simulate-many REP805 matrix: every per-scenario value the batched
# executor promises to trace (group_key docstring) gets a perturbation
_MANY_VARIANTS = {
    "seed": {"seed": 99},
    "n_photons": {"n_photons": 4096},
    "id_offset": {"id_offset": 123456},
    "source_radius": {"radius": 2.5},
    "det_coords": {"det_dx": 0.5},
    "media": {"media_scale": 1.4},
}


def _make_simulate_many(engine):
    def make(overrides=None):
        import dataclasses

        import jax
        import numpy as np

        from repro.scenarios import Scenario, make_batched
        from repro.sources import Disk
        ov = overrides or {}
        vol0 = _volume()
        scs = []
        for i in range(3):
            media = np.asarray(vol0.media).copy()
            media[1:, 1] *= ov.get("media_scale", 1.0) + 0.1 * i
            vol = dataclasses.replace(vol0, media=media)
            scs.append(Scenario(
                vol, _sim_cfg(), ov.get("n_photons", 64) + 8 * i,
                seed=ov.get("seed", 1234) + i,
                source=Disk(pos=(4.0, 4.0, 0.0),
                            radius=ov.get("radius", 1.5) + 0.25 * i),
                detectors=({"x": 4.0 + ov.get("det_dx", 0.0), "y": 4.0,
                            "radius": 2.0},),
                id_offset=ov.get("id_offset", 0) + (i << 20)))
        fn, args = make_batched(scs, n_lanes=_LANES, engine=engine,
                                block_lanes=_BLOCK, interpret=True)
        return jax.make_jaxpr(fn)(*args)
    return make


def _make_sharded():
    def make(overrides=None):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh

        from repro.core.multidevice import sharded_sim_fn
        vol = _volume()
        devs = jax.devices()
        mesh = Mesh(np.array(devs), ("data",))
        fn = sharded_sim_fn(vol, _sim_cfg(), _LANES, mesh,
                            detectors=_detectors(), record_detected=8)
        ov = overrides or {}
        n = len(devs)
        return jax.make_jaxpr(fn)(
            vol.labels.reshape(-1), vol.media,
            jnp.full((n,), ov.get("n_photons", 64), jnp.int32),
            jnp.full((n,), ov.get("id_offset", 0), jnp.uint32),
            jnp.zeros((n,), jnp.uint32),
            jnp.uint32(ov.get("seed", 1234)))
    return make


def build_default_targets(include_sharded: bool | None = None
                          ) -> list[TraceTarget]:
    """The registry CI and the CLI trace.

    ``include_sharded`` forces the mesh target on/off; the default
    includes it exactly when more than one device is visible (the
    8-fake-device CI lane).
    """
    targets = []
    for engine in ("jnp", "pallas"):
        targets.append(TraceTarget(
            name=f"sim-{engine}", entry=_SIM_ENTRY, group="sim",
            make=_make_sim(engine), variants=dict(_SIM_VARIANTS)))
    for engine in ("jnp", "pallas"):
        targets.append(TraceTarget(
            name=f"replay-{engine}", entry=_REPLAY_ENTRY, group="replay",
            make=_make_replay(engine),
            variants={"seed": {"seed": 99},
                      "id_offset": {"id_offset": 77}}))
    for engine in ("jnp", "pallas"):
        targets.append(TraceTarget(
            name=f"pool-{engine}", entry=_POOL_ENTRY, group="pool",
            make=_make_pool(engine), variants=dict(_SIM_VARIANTS)))
    for engine in ("jnp", "pallas"):
        targets.append(TraceTarget(
            name=f"simulate-many-{engine}", entry=_MANY_ENTRY,
            group="simulate-many", make=_make_simulate_many(engine),
            variants=dict(_MANY_VARIANTS)))
    if include_sharded is None:
        import jax
        include_sharded = len(jax.devices()) > 1
    if include_sharded:
        targets.append(TraceTarget(
            name="sharded-sim", entry=_MESH_ENTRY,
            make=_make_sharded(), variants=dict(_SIM_VARIANTS)))
    return targets
