"""tracelint — jaxpr-level analysis tier for the photon-transport stack.

The AST tier (reprolint, :mod:`repro.lint`) checks what the source
*says*; this tier checks what JAX actually *traces*: it builds the real
entrypoints (``build_sim_fn`` for both engines, the replay pair, the
resilience pool's per-bit-class executors, the shard_mapped mesh
builders) into closed jaxprs and walks the equations with REP8xx rules
(DESIGN.md §static-analysis).  Cross-vendor MC divergence hides in
accumulation ordering and implicit promotion — exactly the properties
only the traced program exposes.

Usage::

    PYTHONPATH=src python -m repro.lint --tier traced
    PYTHONPATH=src python -m repro.lint --tier all --format json

Architecture mirrors the AST tier:

* :class:`TraceTarget` wraps one entrypoint: a ``make(overrides)``
  callable returning a ``ClosedJaxpr``, the repo-relative ``entry``
  file findings anchor to, an optional parity ``group`` (REP804) and
  named ``variants`` — perturbations of *dynamic* call arguments that
  must not change the jaxpr (REP805).  The default registry lives in
  :mod:`repro.lint.traced.targets`.
* :class:`TracedRule` subclasses walk jaxprs via :func:`iter_eqns` and
  yield the same :class:`~repro.lint.Finding` objects the AST tier
  uses, so reports, baselines and CI artifacts share one format.
* Suppression: jaxprs have no source lines to hang pragmas on, so the
  traced tier uses a committed allowlist file (``.tracelint-allow.json``)
  instead — every entry carries a mandatory ``why``.  The committed
  traced baseline (``.tracelint.json``) stays empty, same policy as
  the AST tier.

This module stays importable without jax (the CI lint job for the AST
tier is deliberately dependency-free): jax is only imported when
targets are actually traced.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Callable, Iterable, Iterator

from repro.lint import Finding, LintReport

__all__ = [
    "TraceTarget", "TracedRule", "iter_eqns", "subjaxprs",
    "jaxpr_fingerprint", "run_traced_lint", "load_allowlist",
    "allowlist_path", "traced_baseline_path",
    "ALLOWLIST_NAME", "TRACED_BASELINE_NAME",
]

ALLOWLIST_NAME = ".tracelint-allow.json"
TRACED_BASELINE_NAME = ".tracelint.json"
ALLOWLIST_VERSION = 1

# primitives whose sub-jaxprs execute repeatedly (a "round loop" for
# REP803's purposes): their bodies raise the loop depth by one
_LOOP_PRIMS = frozenset({"while", "scan"})


@dataclasses.dataclass
class TraceTarget:
    """One traced entrypoint.

    ``make(overrides)`` builds the entrypoint and returns its
    ``ClosedJaxpr``; ``overrides`` (None for the canonical trace) remaps
    the *dynamic* call arguments — n_photons, seed, id offsets — whose
    values must never leak into the trace.  ``entry`` is the
    repo-relative source file findings anchor to; ``group`` names an
    REP804 engine-parity group (targets sharing a group must produce
    identical output avals); ``variants`` maps a perturbation name to
    an overrides dict for REP805.
    """

    name: str
    entry: str
    make: Callable[[dict | None], object]
    group: str | None = None
    variants: dict[str, dict] = dataclasses.field(default_factory=dict)
    _cached: object = dataclasses.field(default=None, repr=False)

    def jaxpr(self):
        """The canonical (no-overrides) trace, memoized."""
        if self._cached is None:
            self._cached = self.make(None)
        return self._cached


class TracedRule:
    """Base class for REP8xx rules.

    Subclasses set ``id``/``name``/``severity``/``description`` and
    override ``check(targets)``; targets arriving here have already
    traced successfully (failures surface as REP800 engine findings).
    """

    id: str = "REP800"
    name: str = "traced-base"
    severity: str = "error"
    description: str = ""

    def check(self, targets: list[TraceTarget]) -> Iterator[Finding]:
        return iter(())

    def finding(self, target: TraceTarget, message: str) -> Finding:
        return Finding(rule=self.id, name=self.name, severity=self.severity,
                       path=target.entry, line=1, col=0,
                       message=f"[{target.name}] {message}")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _as_jaxprs(value) -> list:
    """Jaxpr objects held (possibly nested in tuples) by an eqn param."""
    if hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        return [value.jaxpr]           # ClosedJaxpr
    if hasattr(value, "eqns"):
        return [value]                 # raw Jaxpr
    if isinstance(value, (tuple, list)):
        out = []
        for v in value:
            out.extend(_as_jaxprs(v))
        return out
    return []


def subjaxprs(eqn) -> list[tuple[object, bool]]:
    """(sub_jaxpr, enters_loop) for every jaxpr nested under ``eqn``.

    ``enters_loop`` is True when the sub-jaxpr body executes repeatedly
    (while/scan); pjit/cond/pallas_call bodies execute at most once per
    invocation of the enclosing program.
    """
    loops = eqn.primitive.name in _LOOP_PRIMS
    out = []
    for v in eqn.params.values():
        for j in _as_jaxprs(v):
            out.append((j, loops))
    return out


def iter_eqns(closed) -> Iterator[tuple[object, object, int]]:
    """Yield ``(owning_jaxpr, eqn, loop_depth)`` over the whole nest.

    ``loop_depth`` counts enclosing while/scan bodies — an eqn at depth
    >= 1 runs inside the round loop.
    """
    stack = [(closed.jaxpr, 0)]
    while stack:
        jaxpr, depth = stack.pop()
        for eqn in jaxpr.eqns:
            yield jaxpr, eqn, depth
            for sub, loops in subjaxprs(eqn):
                stack.append((sub, depth + (1 if loops else 0)))


def jaxpr_fingerprint(closed) -> str:
    """Stable hash of a closed jaxpr: program text + in/out aval
    signature (weak-type flags included — the pretty-printer omits
    them, but they are part of the compile-cache key)."""
    parts = [str(closed.jaxpr)]
    for av in list(closed.in_avals) + list(closed.out_avals):
        parts.append(f"{getattr(av, 'shape', None)}"
                     f"|{getattr(av, 'dtype', None)}"
                     f"|{getattr(av, 'weak_type', False)}")
    return hashlib.sha1("\n".join(parts).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------

def allowlist_path(root: Path | str) -> Path:
    return Path(root) / ALLOWLIST_NAME


def traced_baseline_path(root: Path | str) -> Path:
    return Path(root) / TRACED_BASELINE_NAME


def load_allowlist(path: Path | str) -> list[dict]:
    """Validated allowlist entries; empty when the file doesn't exist.

    Each entry must carry ``rule`` and a non-empty ``why`` (the traced
    tier's pragma analogue — suppression without a recorded reason is
    rejected).  Optional keys: ``target`` (exact target name; missing
    matches any), ``match`` (substring of the finding message) and
    ``max`` (cap on how many findings one entry may absorb, so a new
    racy scatter can't hide behind an old entry).
    """
    path = Path(path)
    if not path.is_file():
        return []
    data = json.loads(path.read_text())
    if data.get("version") != ALLOWLIST_VERSION:
        raise ValueError(
            f"{path}: unsupported allowlist version {data.get('version')!r} "
            f"(this tracelint reads version {ALLOWLIST_VERSION})")
    entries = data.get("allow", [])
    if not isinstance(entries, list):
        raise ValueError(f"{path}: 'allow' must be a list")
    for i, e in enumerate(entries):
        if not isinstance(e, dict) or not e.get("rule"):
            raise ValueError(f"{path}: allow[{i}] needs a 'rule'")
        if not isinstance(e.get("why"), str) or not e["why"].strip():
            raise ValueError(
                f"{path}: allow[{i}] ({e.get('rule')}) needs a non-empty "
                f"'why' — tracelint suppressions must record their reason")
        if "max" in e and (not isinstance(e["max"], int) or e["max"] < 1):
            raise ValueError(f"{path}: allow[{i}] 'max' must be a "
                             f"positive int")
    return list(entries)


def _allow_matches(f: Finding, entry: dict) -> bool:
    if entry["rule"] != f.rule:
        return False
    target = entry.get("target")
    if target is not None and not f.message.startswith(f"[{target}]"):
        return False
    match = entry.get("match")
    if match is not None and match not in f.message:
        return False
    return True


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _traced_fingerprint(f: Finding) -> str:
    raw = f"{f.rule}:{f.path}:{f.message}"
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def run_traced_lint(root: Path | str,
                    targets: Iterable[TraceTarget] | None = None,
                    rules: Iterable[TracedRule] | None = None,
                    rule_ids: Iterable[str] | None = None,
                    baseline: dict[str, int] | None = None,
                    allowlist: list[dict] | None = None) -> LintReport:
    """Trace the targets and run the REP8xx rules.

    Returns the same :class:`~repro.lint.LintReport` shape as the AST
    tier; ``suppressed_pragma`` counts allowlist suppressions (the
    traced tier's pragma analogue) and ``n_modules`` counts targets.
    A target whose canonical trace raises becomes an REP800
    ``trace-failure`` finding rather than aborting the run.
    """
    root = Path(root)
    if targets is None:
        from repro.lint.traced.targets import build_default_targets
        targets = build_default_targets()
    targets = list(targets)
    if rules is None:
        from repro.lint.traced.rules import TRACED_RULES
        rules = [r() for r in TRACED_RULES]
    else:
        rules = list(rules)
    if rule_ids is not None:
        wanted = set(rule_ids)
        rules = [r for r in rules if r.id in wanted or r.name in wanted]

    raw: list[Finding] = []
    ok: list[TraceTarget] = []
    for t in targets:
        try:
            t.jaxpr()
        except Exception as e:  # tracing real entrypoints: anything goes
            raw.append(Finding(
                rule="REP800", name="trace-failure", severity="error",
                path=t.entry, line=1, col=0,
                message=f"[{t.name}] tracing raised "
                        f"{type(e).__name__}: {e}"))
        else:
            ok.append(t)
    for rule in rules:
        raw.extend(rule.check(ok))
    raw.sort(key=lambda f: (f.path, f.rule, f.message))

    live = [dataclasses.replace(f, fingerprint=_traced_fingerprint(f))
            for f in raw]

    n_allow = 0
    if allowlist:
        budgets = [dict(e) for e in allowlist]
        kept = []
        for f in live:
            hit = None
            for e in budgets:
                if _allow_matches(f, e) and e.get("max", 1 << 30) > 0:
                    hit = e
                    break
            if hit is not None:
                if "max" in hit:
                    hit["max"] -= 1
                n_allow += 1
            else:
                kept.append(f)
        live = kept

    n_base = 0
    if baseline:
        budget = dict(baseline)
        kept = []
        for f in live:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
                n_base += 1
            else:
                kept.append(f)
        live = kept

    return LintReport(findings=live, suppressed_pragma=n_allow,
                      suppressed_baseline=n_base,
                      n_modules=len(targets),
                      rules_run=[r.id for r in rules])
