"""REP701 — bench-schema: BENCH writers must stamp SCHEMA_VERSION.

The perf-regression gate refuses to compare BENCH_*.json files across
schema versions (benchmarks/common.py) — but that only works if every
writer stamps ``"schema_version": SCHEMA_VERSION`` into its meta
block, importing the constant instead of hardcoding the number.  PR 6
added the versioning; this rule keeps future writers honest.

Scope: a benchmarks module that both names a ``BENCH_*`` artifact and
serializes JSON is a writer.  Two findings:

* a writer with no ``"schema_version"`` key at all;
* a ``"schema_version"`` stamped with a literal instead of the shared
  ``SCHEMA_VERSION`` constant (hardcoded versions drift silently when
  common.py bumps).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule
from repro.lint.astutil import resolve_dotted

SCHEMA_CONST = "benchmarks.common.SCHEMA_VERSION"


class BenchSchemaRule(Rule):
    id = "REP701"
    name = "bench-schema"
    severity = "error"
    description = ("benchmark writers must stamp schema_version from "
                   "benchmarks.common.SCHEMA_VERSION, not a literal")

    def applies(self, mod: Module, ctx: Context) -> bool:
        return mod.name.startswith("benchmarks")

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        names_bench = any(
            isinstance(n, ast.Constant) and isinstance(n.value, str) and
            "BENCH_" in n.value
            for n in ast.walk(mod.tree))
        dumps = [n for n in ast.walk(mod.tree)
                 if isinstance(n, ast.Call) and
                 resolve_dotted(n.func, mod.aliases) in
                 ("json.dump", "json.dumps")]
        if not (names_bench and dumps):
            return  # not a BENCH writer

        stamped = False
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Dict):
                continue
            for k, v in zip(node.keys, node.values):
                if not (isinstance(k, ast.Constant) and
                        k.value == "schema_version"):
                    continue
                stamped = True  # present (possibly wrongly) — the
                #                 "never stamps" finding stays quiet
                if isinstance(v, ast.Constant):
                    yield ctx.finding(
                        self, mod, v,
                        f"schema_version is hardcoded to {v.value!r} — "
                        f"import SCHEMA_VERSION from benchmarks.common "
                        f"so the regression gate's version fence stays "
                        f"in sync")
        if not stamped:
            yield ctx.finding(
                self, mod, dumps[0],
                "this module writes a BENCH_*.json but never stamps "
                "\"schema_version\": SCHEMA_VERSION into its meta — "
                "check_regression.py cannot fence schema drift without "
                "it")
