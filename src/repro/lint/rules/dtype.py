"""REP301 — dtype discipline: no float64 promotion in repro code.

Everything traced is float32 by contract (DESIGN.md §determinism):
TPUs have no fast f64, jax runs with x64 disabled, and a float64 leak
at a trace boundary silently double-rounds or retraces.  Host-side
analysis code legitimately accumulates in float64 (energy-balance
sums), but must say so — the rule flags every promotion site repo-wide
and intentional host-side uses carry a
``# reprolint: disable=REP301`` pragma with a why, so a reviewer can
tell a deliberate f64 accumulator from a leak at a glance.

Flagged forms:

* ``np.float64`` / ``np.double`` / ``jnp.float64`` anywhere
* ``dtype=float`` / ``dtype="float64"`` — the builtin ``float`` *is*
  float64 as a numpy dtype, the classic accidental promotion
* bare ``float`` passed positionally to an array constructor or
  ``.astype`` (``np.asarray(x, float)``)

Pinning a dtype at a host->trace boundary is the fix:
``jnp.asarray(x, jnp.float32)`` / ``np.asarray(x, np.float32)``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule
from repro.lint.astutil import resolve_dotted

F64_NAMES = ("numpy.float64", "numpy.double", "numpy.longdouble",
             "jax.numpy.float64")

# constructors whose bare-`float` positional argument means dtype=f64
_DTYPE_POS_CALLS = {"asarray", "array", "zeros", "ones", "full", "empty",
                    "astype", "arange", "asanyarray"}
_DTYPE_STRINGS = ("float64", "f8", "d", "double")


class DtypeRule(Rule):
    id = "REP301"
    name = "dtype"
    severity = "error"
    description = ("flag float64-promoting dtypes/literals; traced code "
                   "is float32 by contract, host-side f64 needs a pragma")

    def applies(self, mod: Module, ctx: Context) -> bool:
        return mod.name.startswith("repro")

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        traced = mod.name in ctx.traced_modules
        where = "traced " if traced else ""
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                resolved = resolve_dotted(node, mod.aliases)
                if resolved in F64_NAMES:
                    yield ctx.finding(
                        self, mod, node,
                        f"`{resolved}` in {where}module `{mod.name}` "
                        f"promotes to float64 — traced code is float32 "
                        f"by contract; host-side f64 accumulation needs "
                        f"a `# reprolint: disable=REP301` pragma with a "
                        f"why")
            elif isinstance(node, ast.keyword) and node.arg == "dtype":
                v = node.value
                if isinstance(v, ast.Name) and v.id == "float":
                    yield ctx.finding(
                        self, mod, v,
                        "`dtype=float` is float64 — pin float32 (or "
                        "np.float64 + pragma if the f64 is deliberate)")
                elif isinstance(v, ast.Constant) and v.value in \
                        _DTYPE_STRINGS:
                    yield ctx.finding(
                        self, mod, v,
                        f"`dtype={v.value!r}` is float64 — pin float32 "
                        f"(or np.float64 + pragma if deliberate)")
            elif isinstance(node, ast.Call):
                fname = None
                if isinstance(node.func, ast.Attribute):
                    fname = node.func.attr
                elif isinstance(node.func, ast.Name):
                    fname = node.func.id
                if fname in _DTYPE_POS_CALLS:
                    for arg in node.args:
                        if isinstance(arg, ast.Name) and arg.id == "float":
                            yield ctx.finding(
                                self, mod, arg,
                                f"bare `float` dtype in `{fname}(...)` "
                                f"is float64 — pin float32 (or "
                                f"np.float64 + pragma if deliberate)")
