"""REP101 — mirror-drift: the kernel mirrors must match spec.py.

The photon-step contract lives in four places that must stay mirrored
by hand (DESIGN.md §rounds, §static-analysis): the jit wrapper
(``kernels/photon_step/ops.py``), the Pallas kernel
(``photon_step.py``), the pure-jnp oracle (``ref.py``) and the round
executor's pallas branch (``core/simulator.py``).  Each one encodes
the same optional output groups — ``(n_det: 3, record: 2, jac_cols:
1, stats: 1)`` after the 4 base outputs — as guarded tuple appends,
list appends or slice unpacks.  PRs 2–7 re-mirrored these manually;
this rule extracts each mirror's (guard flag, arity) sequence from the
AST and diffs it against the literal constants in
``kernels/photon_step/spec.py`` (which the runtime also asserts
against, so lint and runtime cannot disagree).

Checked per mirror:

* entry-point signatures: the core positional prefix (``CORE_PARAMS``)
  and the optional-extension parameters (``EXT_PARAMS``) in spec
  order;
* ``ref.py``: base ``init`` tuple arity (packed state + base outputs)
  and every guarded ``init = init + (...)`` append;
* ``photon_step.py``: base ``out_shapes`` list arity (unpacked state +
  base outputs) and every guarded ``out_shapes += [...]`` append;
* ``simulator.py``: the pallas branch's ``outs[:k]`` base unpack and
  every guarded ``outs[cur:cur + k]`` slice unpack (groups it doesn't
  consume may be absent, but order and arity must match — the
  ``collect`` local is an accepted alias for the ``stats`` flag);
* ``ops.py``: the jit wrapper's ``static_argnames`` must cover every
  output-arity flag, otherwise a traced flag changes the output pytree
  without recompiling.

The rule is silent when the tree has no ``kernels/photon_step/spec.py``
(fixture trees for other rules); a present-but-unparseable mirror is
itself a finding.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Rule
from repro.lint.astutil import (find_function, param_names, test_flag_names)

SPEC_MOD = "repro.kernels.photon_step.spec"
MIRRORS = (
    ("repro.kernels.photon_step.ops", "photon_steps"),
    ("repro.kernels.photon_step.photon_step", "photon_step_pallas"),
    ("repro.kernels.photon_step.ref", "photon_steps_ref"),
)
SIMULATOR_MOD = "repro.core.simulator"
SIM_BUILDER = "build_sim_fn"

_SPEC_KEYS = ("STATE_FIELDS", "BASE_OUTPUTS", "OUTPUT_GROUPS",
              "EXT_PARAMS", "CORE_PARAMS")


class MirrorRule(Rule):
    id = "REP101"
    name = "mirror-drift"
    severity = "error"
    description = ("kernel wrapper / pallas kernel / jnp oracle / round "
                   "executor must match the output spec in "
                   "kernels/photon_step/spec.py")

    def check(self, ctx: Context) -> Iterator[Finding]:
        spec_mod = ctx.module(SPEC_MOD)
        if spec_mod is None:
            return  # not a kernel tree (rule-isolated fixture)
        from repro.lint.astutil import load_literal_constants
        consts = load_literal_constants(spec_mod.tree)
        missing = [k for k in _SPEC_KEYS if k not in consts]
        if missing:
            yield ctx.finding(
                self, spec_mod, None,
                f"spec.py is missing literal constants {missing} — the "
                f"mirror contract must stay statically extractable")
            return
        state = tuple(consts["STATE_FIELDS"])
        base = tuple(consts["BASE_OUTPUTS"])
        groups = [(tuple(aliases), tuple(members))
                  for aliases, members in consts["OUTPUT_GROUPS"]]
        ext = tuple(consts["EXT_PARAMS"])
        core = tuple(consts["CORE_PARAMS"])

        for mod_name, fn_name in MIRRORS:
            mod = ctx.module(mod_name)
            if mod is None:
                yield ctx.finding(self, spec_mod, None,
                                  f"mirror module `{mod_name}` not found")
                continue
            fn = find_function(mod.tree, fn_name)
            if fn is None:
                yield ctx.finding(self, mod, None,
                                  f"mirror entry point `{fn_name}` not "
                                  f"found in `{mod_name}`")
                continue
            params = param_names(fn)
            if tuple(params[:len(core)]) != core:
                yield ctx.finding(
                    self, mod, fn,
                    f"`{fn_name}` core parameters "
                    f"{tuple(params[:len(core)])} != spec.CORE_PARAMS "
                    f"{core}")
            it = iter(params)
            missing_ext = [p for p in ext if p not in it]
            if missing_ext:
                yield ctx.finding(
                    self, mod, fn,
                    f"`{fn_name}` is missing (or reorders) spec."
                    f"EXT_PARAMS entries {missing_ext} — every mirror "
                    f"accepts the extension params in the same order")

        yield from self._check_ref(ctx, state, base, groups)
        yield from self._check_pallas(ctx, state, base, groups)
        yield from self._check_simulator(ctx, base, groups)
        yield from self._check_ops_static(ctx, groups)

    # -- guarded-append extraction -------------------------------------

    def _diff_groups(self, ctx, mod, anchor, what, got, groups,
                     subset=False) -> Iterator[Finding]:
        """Diff an extracted (flag, arity, node) sequence against spec.

        ``subset=True`` allows a mirror to skip groups it never
        consumes (the forward round executor ignores the jac group),
        but order and arities of the groups it does handle must match.
        """
        gi = 0
        for flag, arity, node in got:
            while gi < len(groups) and flag not in groups[gi][0]:
                if not subset:
                    yield ctx.finding(
                        self, mod, node,
                        f"{what}: expected a group guarded by "
                        f"{'/'.join(groups[gi][0])} (arity "
                        f"{len(groups[gi][1])}) before `{flag}` — "
                        f"output groups must follow spec.OUTPUT_GROUPS "
                        f"order")
                gi += 1
            if gi >= len(groups):
                yield ctx.finding(
                    self, mod, node,
                    f"{what}: group guarded by `{flag}` is not in "
                    f"spec.OUTPUT_GROUPS (or is out of order)")
                continue
            want = len(groups[gi][1])
            if arity != want:
                yield ctx.finding(
                    self, mod, node,
                    f"{what}: group `{flag}` appends {arity} output(s) "
                    f"but spec.OUTPUT_GROUPS"
                    f"[{'/'.join(groups[gi][0])}] = "
                    f"{groups[gi][1]} has {want}")
            gi += 1
        if not subset:
            for aliases, members in groups[gi:]:
                yield ctx.finding(
                    self, mod, anchor,
                    f"{what}: missing output group guarded by "
                    f"{'/'.join(aliases)} with members {members}")

    def _check_ref(self, ctx, state, base, groups) -> Iterator[Finding]:
        mod = ctx.module("repro.kernels.photon_step.ref")
        if mod is None:
            return
        fn = find_function(mod.tree, "photon_steps_ref")
        if fn is None:
            return
        base_node = None
        got = []
        for stmt in fn.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == "init" \
                    and isinstance(stmt.value, ast.Tuple):
                base_node = stmt
            elif isinstance(stmt, ast.If):
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Assign) and \
                            isinstance(sub.value, ast.BinOp) and \
                            isinstance(sub.value.op, ast.Add) and \
                            isinstance(sub.value.left, ast.Name) and \
                            sub.value.left.id == "init" and \
                            isinstance(sub.value.right, ast.Tuple):
                        flags = test_flag_names(stmt.test)
                        flag = next((a for als, _ in groups for a in als
                                     if a in flags), None) or \
                            (sorted(flags)[0] if flags else "?")
                        got.append((flag, len(sub.value.right.elts), sub))
        if base_node is None:
            yield ctx.finding(
                self, mod, fn,
                "ref.py: could not find the base `init = (...)` tuple — "
                "the oracle's output contract must stay statically "
                "extractable (see spec.py)")
            return
        want_base = 1 + len(base)  # packed state + base outputs
        n = len(base_node.value.elts)
        if n != want_base:
            yield ctx.finding(
                self, mod, base_node,
                f"ref.py base `init` tuple has {n} elements, spec says "
                f"{want_base} (packed state + {base})")
        yield from self._diff_groups(ctx, mod, fn, "ref.py init appends",
                                     got, groups)

    def _check_pallas(self, ctx, state, base, groups) -> Iterator[Finding]:
        mod = ctx.module("repro.kernels.photon_step.photon_step")
        if mod is None:
            return
        fn = find_function(mod.tree, "photon_step_pallas")
        if fn is None:
            return
        base_node = None
        got = []
        for stmt in fn.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == "out_shapes" \
                    and isinstance(stmt.value, ast.List):
                base_node = stmt
            elif isinstance(stmt, ast.If):
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.AugAssign) and \
                            isinstance(sub.op, ast.Add) and \
                            isinstance(sub.target, ast.Name) and \
                            sub.target.id == "out_shapes" and \
                            isinstance(sub.value, (ast.List, ast.Tuple)):
                        flags = test_flag_names(stmt.test)
                        flag = next((a for als, _ in groups for a in als
                                     if a in flags), None) or \
                            (sorted(flags)[0] if flags else "?")
                        got.append((flag, len(sub.value.elts), sub))
        if base_node is None:
            yield ctx.finding(
                self, mod, fn,
                "photon_step.py: could not find the base `out_shapes = "
                "[...]` list — the kernel's output contract must stay "
                "statically extractable (see spec.py)")
            return
        want_base = len(state) + len(base)  # unpacked state + base
        n = len(base_node.value.elts)
        if n != want_base:
            yield ctx.finding(
                self, mod, base_node,
                f"photon_step.py base `out_shapes` has {n} entries, "
                f"spec says {want_base} ({len(state)} state fields + "
                f"{base})")
        yield from self._diff_groups(ctx, mod, fn,
                                     "photon_step.py out_shapes appends",
                                     got, groups)

    def _check_simulator(self, ctx, base, groups) -> Iterator[Finding]:
        mod = ctx.module(SIMULATOR_MOD)
        if mod is None:
            return
        fn = find_function(mod.tree, SIM_BUILDER)
        if fn is None:
            yield ctx.finding(
                self, mod, None,
                f"simulator.py: round-executor builder `{SIM_BUILDER}` "
                f"not found")
            return
        base_node = None
        base_n = 0
        got = []
        ifs = [n for n in ast.walk(fn) if isinstance(n, ast.If)]
        in_ifs = {id(s): i for i in ifs for s in ast.walk(i)
                  if isinstance(s, ast.Assign)}
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and
                    isinstance(node.value, ast.Subscript) and
                    isinstance(node.value.value, ast.Name) and
                    node.value.value.id == "outs"):
                continue
            sl = node.value.slice
            tgt = node.targets[0]
            n_tgt = len(tgt.elts) if isinstance(tgt, (ast.Tuple, ast.List)) \
                else 1
            if isinstance(sl, ast.Slice) and sl.lower is None and \
                    isinstance(sl.upper, ast.Constant):
                base_node = node
                base_n = sl.upper.value
                if n_tgt != base_n:
                    yield ctx.finding(
                        self, mod, node,
                        f"simulator.py: base unpack targets {n_tgt} "
                        f"names from `outs[:{base_n}]`")
            else:
                owner = in_ifs.get(id(node))
                if owner is None:
                    continue
                stmt = ifs[owner] if isinstance(owner, int) else owner
                flags = test_flag_names(stmt.test)
                flag = next((a for als, _ in groups for a in als
                             if a in flags), None)
                if flag is None:
                    continue
                got.append((flag, n_tgt, node))
        if base_node is None:
            yield ctx.finding(
                self, mod, fn,
                "simulator.py: could not find the pallas-branch base "
                "`... = outs[:k]` unpack — the round executor's output "
                "contract must stay statically extractable")
            return
        want_base = 1 + len(base)
        if base_n != want_base:
            yield ctx.finding(
                self, mod, base_node,
                f"simulator.py pallas branch unpacks `outs[:{base_n}]`, "
                f"spec says {want_base} (packed state + {base})")
        got.sort(key=lambda t: t[2].lineno)  # ast.walk is not source order
        yield from self._diff_groups(
            ctx, mod, fn, "simulator.py outs unpacks", got, groups,
            subset=True)

    def _check_ops_static(self, ctx, groups) -> Iterator[Finding]:
        mod = ctx.module("repro.kernels.photon_step.ops")
        if mod is None:
            return
        flags = {als[0] for als, _ in groups if als[0] != "n_det"}
        # n_det is derived from det_geom's shape, not a wrapper param
        names: set[str] = set()
        kw_node = None
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.keyword) and \
                    node.arg == "static_argnames" and \
                    isinstance(node.value, (ast.Tuple, ast.List)):
                kw_node = node
                for e in node.value.elts:
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, str):
                        names.add(e.value)
        if kw_node is None:
            yield ctx.finding(
                self, mod, None,
                "ops.py: no static_argnames found on the jit wrapper — "
                "the output-arity flags must be static")
            return
        missing = sorted(flags - names)
        if missing:
            yield ctx.finding(
                self, mod, kw_node,
                f"ops.py jit wrapper static_argnames is missing the "
                f"output-arity flag(s) {missing} — a traced arity flag "
                f"changes the output pytree without recompiling")
