"""REP401 — jit hygiene: host syncs in traced bodies, static_argnames.

Two failure modes this repo has actually hit:

* **Host syncs inside traced control flow.**  A ``.item()`` /
  ``float()`` / ``int()`` / ``np.asarray()`` / ``print()`` on a traced
  value inside a ``lax.while_loop`` / ``fori_loop`` / ``scan`` /
  ``cond`` body either crashes at trace time or, worse, silently
  forces a device sync per iteration.  The rule finds every function
  *passed to* a ``jax.lax`` control-flow combinator (or decorated with
  ``pl.when`` / ``jax.jit``) and scans its body.  Python-int coercion
  of *static* config (``int(cfg.n_time_gates)``) belongs outside those
  bodies — hoist it, don't pragma it.

* **Invalid / drifting ``static_argnames``.**  A name listed in
  ``static_argnames`` that is not a parameter of the jitted function
  is silently ignored by jax — the argument becomes traced, arity
  flags stop forcing recompilation, and the kernel's output pytree
  goes polymorphic at runtime.  The rule checks every
  ``jax.jit(..., static_argnames=...)`` (decorator, ``functools.
  partial(jax.jit, ...)`` decorator, and direct-call forms) against
  the wrapped function's parameter list.  (Arity-flag coverage for the
  kernel wrapper itself is checked by the mirror rule, REP101.)
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule
from repro.lint.astutil import param_names, resolve_dotted, walk_functions

_LAX_COMBINATORS = ("jax.lax.while_loop", "jax.lax.fori_loop",
                    "jax.lax.scan", "jax.lax.cond", "jax.lax.switch",
                    "jax.lax.map", "jax.lax.associative_scan")

_HOST_CALLS = {"float": "Python float() coerces a traced value on host",
               "int": "Python int() coerces a traced value on host",
               "bool": "Python bool() coerces a traced value on host",
               "print": "host I/O inside a traced body (use jax.debug."
                        "print)"}

_NP_CALL_PREFIX = "numpy."


def _jit_target(call: ast.Call, aliases: dict[str, str]):
    """(static_argnames node, wrapped-name node) of a jit call, if any.

    Handles ``jax.jit(...)`` and ``functools.partial(jax.jit, ...)``.
    """
    resolved = resolve_dotted(call.func, aliases)
    inner = call
    if resolved == "functools.partial" and call.args:
        if resolve_dotted(call.args[0], aliases) != "jax.jit":
            return None
    elif resolved != "jax.jit":
        return None
    for kw in inner.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            return kw
    return None


def _static_names(kw: ast.keyword) -> list[str] | None:
    v = kw.value
    if isinstance(v, ast.Constant) and isinstance(v.value, str):
        return [v.value]
    if isinstance(v, (ast.Tuple, ast.List)):
        names = []
        for e in v.elts:
            if not (isinstance(e, ast.Constant) and
                    isinstance(e.value, str)):
                return None
            names.append(e.value)
        return names
    return None


class JitHygieneRule(Rule):
    id = "REP401"
    name = "jit-hygiene"
    severity = "error"
    description = ("host syncs inside lax/pallas traced bodies; "
                   "static_argnames must name real parameters")

    def applies(self, mod: Module, ctx: Context) -> bool:
        return mod.name.startswith("repro")

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        fns = {f.name: f for f in walk_functions(mod.tree)}
        traced_bodies: dict[str, ast.AST] = {}

        # bodies passed to lax combinators / lambdas at the call site
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = resolve_dotted(node.func, mod.aliases)
            if resolved not in _LAX_COMBINATORS:
                continue
            for arg in node.args:
                if isinstance(arg, ast.Name) and arg.id in fns:
                    traced_bodies.setdefault(arg.id, fns[arg.id])
                elif isinstance(arg, ast.Lambda):
                    traced_bodies.setdefault(
                        f"<lambda:{arg.lineno}>", arg)

        # bodies decorated with pl.when(...) or jax.jit
        for fn in fns.values():
            for dec in fn.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                resolved = resolve_dotted(target, mod.aliases)
                if resolved and (resolved.endswith(".when") or
                                 resolved == "jax.jit"):
                    traced_bodies.setdefault(fn.name, fn)

        for name, body in sorted(traced_bodies.items(),
                                 key=lambda kv: kv[1].lineno):
            yield from self._scan_traced_body(mod, ctx, name, body)

        yield from self._check_static_argnames(mod, ctx, fns)

    def _scan_traced_body(self, mod: Module, ctx: Context, name: str,
                          body: ast.AST) -> Iterator[Finding]:
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                yield ctx.finding(
                    self, mod, node,
                    f"`.item()` inside traced body `{name}` forces a "
                    f"host sync per iteration")
                continue
            resolved = resolve_dotted(node.func, mod.aliases)
            if resolved in _HOST_CALLS:
                yield ctx.finding(
                    self, mod, node,
                    f"`{resolved}(...)` inside traced body `{name}`: "
                    f"{_HOST_CALLS[resolved]} — hoist static config "
                    f"out of the traced body")
            elif resolved and resolved.startswith(_NP_CALL_PREFIX) and \
                    not resolved.startswith("numpy.random"):
                # np.* on a traced value silently syncs; np.random is
                # REP201's finding, don't double-report
                yield ctx.finding(
                    self, mod, node,
                    f"`{resolved}(...)` inside traced body `{name}` "
                    f"materializes on host — use jnp")

    def _check_static_argnames(self, mod: Module, ctx: Context,
                               fns: dict[str, ast.FunctionDef]
                               ) -> Iterator[Finding]:
        # decorator forms: @jax.jit / @partial(jax.jit, static_argnames=...)
        for fn in fns.values():
            for dec in fn.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                kw = _jit_target(dec, mod.aliases)
                if kw is None or kw.arg != "static_argnames":
                    continue
                names = _static_names(kw)
                if names is None:
                    continue
                params = set(param_names(fn))
                for s in names:
                    if s not in params:
                        yield ctx.finding(
                            self, mod, kw,
                            f"static_argnames entry `{s}` is not a "
                            f"parameter of `{fn.name}` — jax silently "
                            f"ignores it and the argument is traced")
        # direct-call form: jitted = jax.jit(fn, static_argnames=...)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or \
                    resolve_dotted(node.func, mod.aliases) != "jax.jit":
                continue
            kw = next((k for k in node.keywords
                       if k.arg == "static_argnames"), None)
            if kw is None or not node.args:
                continue
            wrapped = node.args[0]
            if not (isinstance(wrapped, ast.Name) and wrapped.id in fns):
                continue
            names = _static_names(kw)
            if names is None:
                continue
            params = set(param_names(fns[wrapped.id]))
            for s in names:
                if s not in params:
                    yield ctx.finding(
                        self, mod, kw,
                        f"static_argnames entry `{s}` is not a "
                        f"parameter of `{wrapped.id}` — jax silently "
                        f"ignores it and the argument is traced")
