"""reprolint rule registry.

Adding a rule: implement it in a module here, append the class to
``ALL_RULES``, add fixture tests (positive + negative) to
tests/test_lint.py, and document it in DESIGN.md §static-analysis.
Rule ids are stable API — pragmas and baselines reference them.
"""

from __future__ import annotations

from repro.lint.rules.bench import BenchSchemaRule
from repro.lint.rules.determinism import DeterminismRule
from repro.lint.rules.dtype import DtypeRule
from repro.lint.rules.jit import JitHygieneRule
from repro.lint.rules.mirror import MirrorRule
from repro.lint.rules.reach import ReachabilityRule
from repro.lint.rules.vmem import VmemBudgetRule

ALL_RULES = (
    MirrorRule,        # REP101 mirror-drift
    DeterminismRule,   # REP201 determinism
    DtypeRule,         # REP301 dtype discipline
    JitHygieneRule,    # REP401 jit hygiene
    VmemBudgetRule,    # REP501 VMEM budget
    ReachabilityRule,  # REP601 import-graph reachability
    BenchSchemaRule,   # REP701 bench schema stamping
)

__all__ = ["ALL_RULES", "MirrorRule", "DeterminismRule", "DtypeRule",
           "JitHygieneRule", "VmemBudgetRule", "ReachabilityRule",
           "BenchSchemaRule"]
