"""REP601 — reachability: no dead modules under src/repro.

Modules that nothing can reach rot silently — the seed template left a
whole LLM-training scaffold (models/, configs/, optim/...) in the tree
for seven PRs.  This rule computes the import closure from the repo's
real entrypoints and flags every ``repro.*`` module outside it.

Roots:

* CLI entrypoints: every ``repro.launch.*`` module and ``repro.lint``
  itself;
* the benchmark drivers (``benchmarks/*.py``);
* the test suite (``tests/*.py``) — tests are parsed for their
  imports only, they are not themselves linted; a module only a test
  imports is alive (it is someone's fixture or oracle).

Reachability follows *all* imports, including function-level lazy ones
(lazy importing is the repo's idiom for keeping heavy deps off the
trace path, not a sign of death).  A flagged module should be deleted,
or wired to a real entrypoint — not pragma'd.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule, all_imports
from repro.lint import reachable_closure
from repro.lint.astutil import build_alias_map


class ReachabilityRule(Rule):
    id = "REP601"
    name = "reachability"
    severity = "error"
    description = ("every repro module must be importable from a CLI "
                   "entrypoint, a benchmark driver, or a test")

    def check(self, ctx: Context) -> Iterator[Finding]:
        roots = [name for name in ctx.modules
                 if name.startswith(("repro.launch", "repro.lint",
                                     "benchmarks"))]
        seen = set(reachable_closure(ctx, roots))

        # widen by test imports: parse tests/*.py for import targets
        # (tests are roots, not linted modules)
        tests_dir = ctx.root / "tests"
        test_imports: set[str] = set()
        if tests_dir.is_dir():
            for path in sorted(tests_dir.glob("*.py")):
                try:
                    tree = ast.parse(path.read_text())
                except (OSError, SyntaxError):
                    continue
                fake = Module(name=f"tests.{path.stem}", path=path,
                              relpath=path.name, source="", lines=[],
                              tree=tree,
                              aliases=build_alias_map(tree, "tests"))
                test_imports |= all_imports(fake)
        live_roots = [m for m in test_imports if m in ctx.modules]
        seen |= set(reachable_closure(ctx, live_roots))

        for name in sorted(ctx.modules):
            if not name.startswith("repro"):
                continue
            if name in seen:
                continue
            mod = ctx.modules[name]
            yield ctx.finding(
                self, mod, None,
                f"module `{name}` is unreachable from every entrypoint "
                f"(repro.launch.*, repro.lint, benchmarks/*, tests/*) — "
                f"delete it or wire it to a real consumer")
