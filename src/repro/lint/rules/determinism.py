"""REP201 — determinism: no ambient nondeterminism in traced code.

The whole stack's bit-identity story (DESIGN.md §determinism) rests on
one property: every random number and every control decision in traced
code is a pure function of the 64-bit photon id and the campaign seed,
via the counter-seeded splitmix32/xorshift128 generators in
``repro.core.rng``.  Anything ambient breaks replay, multi-device
merging and the chaos-layer bit-identity anchors — so inside the
traced closure (modules reachable from the round executors / kernel
mirrors / replay driver via top-level imports) this rule forbids:

* host RNG: ``numpy.random.*``, the stdlib ``random`` module,
  ``secrets``, ``uuid``
* stateful-key RNG: ``jax.random.*`` (the repo's RNG is counter-based
  by design — a threaded PRNG key would break id-addressed replay)
* wall clocks: ``time.time/perf_counter/monotonic/...``,
  ``datetime.now/today/utcnow``
* iteration over a ``set`` (Python hash-order leaks into trace order)

Host-side code in a traced module (e.g. the autotune helpers in
simulator.py) annotates intentional uses with
``# reprolint: disable=REP201`` and a why.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule
from repro.lint.astutil import matches_prefix, resolve_dotted

BANNED_PREFIXES = (
    "numpy.random",
    "random",
    "secrets",
    "uuid",
    "jax.random",
    "time.time",
    "time.time_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.process_time",
    "datetime.datetime.now",
    "datetime.datetime.today",
    "datetime.datetime.utcnow",
    "datetime.date.today",
)

_WHY = {
    "numpy.random": "host RNG is not a function of (seed, photon id)",
    "random": "host RNG is not a function of (seed, photon id)",
    "secrets": "host RNG is not a function of (seed, photon id)",
    "uuid": "ambient ids break bit-identical replay",
    "jax.random": "threaded PRNG keys break id-addressed replay; use "
                  "the counter-seeded generators in repro.core.rng",
}


class DeterminismRule(Rule):
    id = "REP201"
    name = "determinism"
    severity = "error"
    description = ("forbid ambient RNG / wall clocks / set iteration in "
                   "the traced import closure")

    def applies(self, mod: Module, ctx: Context) -> bool:
        return mod.name in ctx.traced_modules

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        # ast.walk is breadth-first, so an outer attribute chain is
        # seen before its own sub-expressions: flag the outermost
        # match once and skip its descendants
        skip: set[int] = set()
        for node in ast.walk(mod.tree):
            if id(node) in skip:
                skip.update(id(c) for c in ast.iter_child_nodes(node))
                continue
            if isinstance(node, (ast.Attribute, ast.Name)):
                if isinstance(node, ast.Name) and node.id not in \
                        mod.aliases:
                    continue
                resolved = resolve_dotted(node, mod.aliases)
                if resolved is None:
                    continue
                hit = matches_prefix(resolved, BANNED_PREFIXES)
                if hit is None:
                    continue
                skip.update(id(c) for c in ast.iter_child_nodes(node))
                why = _WHY.get(hit, "wall-clock values differ across "
                                    "runs and devices")
                yield ctx.finding(
                    self, mod, node,
                    f"use of `{resolved}` in traced module "
                    f"`{mod.name}`: {why}")
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if isinstance(it, ast.Set) or (
                        isinstance(it, ast.Call) and
                        isinstance(it.func, ast.Name) and
                        it.func.id in ("set", "frozenset")):
                    anchor = node if isinstance(node, ast.For) else it
                    yield ctx.finding(
                        self, mod, anchor,
                        f"iteration over a set in traced module "
                        f"`{mod.name}`: Python hash order leaks into "
                        f"trace order — iterate a sorted() or tuple "
                        f"view instead")
