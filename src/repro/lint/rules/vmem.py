"""REP501 — VMEM budget: reject over-budget kernel configs statically.

The Pallas kernel keeps the full volume blocks resident in VMEM
(photon_step.py docstring); a config whose gate-major fluence block +
Jacobian block + lane blocks exceed the ~16 MiB core budget dies in
Mosaic lowering at runtime, deep inside a compile.  The runtime now
validates via ``kernels/photon_step/spec.check_vmem`` before
dispatching the compiled kernel — this rule applies the *same
function* (same formula, same threshold; the rule imports it rather
than duplicating it) to every statically resolvable
``photon_step_pallas(...)`` / ``photon_steps(...)`` call site.

A site is statically resolvable when ``shape`` (and the knobs that
matter: ``cfg=SimConfig(n_time_gates=...)``, ``block_lanes``,
``jac_cols``) reduce to literals, chasing single-assignment local
aliases and module-level constants (astutil.literal_env /
chase_names).  Sites passing ``interpret=True`` are skipped — the
interpreter has no VMEM (that's how the CPU benches legitimately sweep
ntg=32 on 60^3).  Unresolvable sites are skipped, not guessed: the
runtime check still covers them.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.lint import Context, Finding, Module, Rule
from repro.lint.astutil import (UNRESOLVED, chase_names, literal_env,
                                resolve_dotted, resolve_literal,
                                walk_functions)

# shared positional prefix of photon_steps / photon_step_pallas
_POS = ("labels_flat", "media", "state", "shape", "unitinmm", "cfg",
        "n_steps", "block_lanes", "interpret")
_TARGET_SUFFIXES = ("photon_step_pallas", "photon_steps")


def _call_args(call: ast.Call) -> dict[str, ast.AST]:
    out: dict[str, ast.AST] = {}
    for i, a in enumerate(call.args):
        if i < len(_POS):
            out[_POS[i]] = a
    for kw in call.keywords:
        if kw.arg:
            out[kw.arg] = kw.value
    return out


def _resolve_ntg(cfg_node: ast.AST | None, env: dict) -> object:
    """n_time_gates out of a ``SimConfig(...)`` construction, if any."""
    if cfg_node is None:
        return UNRESOLVED
    cfg_node = chase_names(cfg_node, env)
    if isinstance(cfg_node, ast.Call):
        fname = cfg_node.func.attr if isinstance(cfg_node.func,
                                                 ast.Attribute) else \
            getattr(cfg_node.func, "id", None)
        if fname == "SimConfig":
            for kw in cfg_node.keywords:
                if kw.arg == "n_time_gates":
                    return resolve_literal(kw.value, env)
            return 1  # SimConfig default
    return UNRESOLVED


class VmemBudgetRule(Rule):
    id = "REP501"
    name = "vmem-budget"
    severity = "error"
    description = ("statically-resolvable kernel call sites must fit the "
                   "VMEM budget spec.check_vmem enforces at runtime")

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        try:
            from repro.kernels.photon_step import spec
        except ImportError:  # pragma: no cover - spec ships with the repo
            return
        for fn in walk_functions(mod.tree):
            env = literal_env(fn, mod.tree)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                resolved = resolve_dotted(node.func, mod.aliases) or ""
                if not resolved.rpartition(".")[2] in _TARGET_SUFFIXES:
                    continue
                yield from self._check_site(ctx, mod, node, env, spec)

    def _check_site(self, ctx: Context, mod: Module, call: ast.Call,
                    env: dict, spec) -> Iterator[Finding]:
        args = _call_args(call)

        interpret = resolve_literal(args.get("interpret"), env) \
            if "interpret" in args else None
        if interpret is True:
            return  # interpreter has no VMEM budget

        shape = resolve_literal(args.get("shape"), env)
        if shape is UNRESOLVED or not (
                isinstance(shape, (tuple, list)) and len(shape) == 3 and
                all(isinstance(s, int) for s in shape)):
            return  # not statically resolvable; runtime check covers it
        ntg = _resolve_ntg(args.get("cfg"), env)
        if ntg is UNRESOLVED or not isinstance(ntg, int):
            return

        def lit(name, default):
            if name not in args:
                return default
            v = resolve_literal(args[name], env)
            return default if v is UNRESOLVED else v

        block_lanes = lit("block_lanes", 256)
        jac_cols = lit("jac_cols", 0)
        record = bool(lit("record", False))
        stats = bool(lit("stats", False))
        if not isinstance(block_lanes, int) or not isinstance(jac_cols, int):
            return
        n_det = 0 if lit("det_geom", None) is None else 0  # unknowable
        nvox = shape[0] * shape[1] * shape[2]
        nxy = shape[0] * shape[1]
        try:
            spec.check_vmem(nvox, nxy, ntg, block_lanes,
                            n_det=n_det, record=record,
                            jac_cols=jac_cols, stats=stats)
        except ValueError as e:
            yield ctx.finding(
                self, mod, call,
                f"kernel call exceeds the VMEM budget "
                f"(spec.check_vmem would refuse this config at "
                f"runtime): {e}")
