"""reprolint — repo-specific static analysis for the photon-transport stack.

The stack depends on a handful of hand-enforced contracts (DESIGN.md
§static-analysis): the jnp round executor, the Pallas kernel and the
``ref.py`` oracle must stay mirrored; everything traced must stay
float32 and splitmix-seeded; Pallas block shapes must fit the VMEM
budget; the benchmark writers must stamp their schema version.  Every
PR since PR 2 re-checked those by hand — reprolint turns them into
machine-checked rules that run in CI before the test lanes.

Usage::

    PYTHONPATH=src python -m repro.lint                # human output
    PYTHONPATH=src python -m repro.lint --format json  # CI / tooling
    PYTHONPATH=src python -m repro.lint --write-baseline

Architecture:

* :class:`Rule` subclasses declare an id (``REP101``...), severity and
  either ``check_module`` (runs per in-scope module) or ``check``
  (runs once over the whole repo context).  The registry lives in
  :mod:`repro.lint.rules`.
* Findings can be suppressed three ways: a same-line
  ``# reprolint: disable=REP201`` pragma (with ``disable=all`` as the
  big hammer — annotate *why* in the surrounding comment), the
  committed ``.reprolint.json`` baseline (grandfathered findings, see
  :mod:`repro.lint.baseline`), or ``--rules`` selection.
* The engine never imports the code under analysis — it parses it.
  Fixture trees in tests/test_lint.py exercise every rule on
  deliberately-broken snippets.

Adding a rule: subclass :class:`Rule` in a module under
``repro/lint/rules/``, append it to ``rules.ALL_RULES``, give it a
fixture test proving it fires (and one proving it stays quiet on clean
code), and document it in DESIGN.md §static-analysis.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
from pathlib import Path
from typing import Iterable, Iterator

from repro.lint import astutil

__all__ = [
    "Finding", "Module", "Context", "Rule", "LintReport", "run_lint",
    "discover_modules", "traced_closure", "normalize_line",
    "TRACED_ENTRYPOINTS",
]

# Modules whose import closure is "traced code": everything reachable
# (via module-level imports) from the round executors, the kernel
# mirrors and the replay driver runs under jit/pallas tracing, so the
# determinism and dtype rules police it.  Function-level lazy imports
# are deliberately NOT followed — that is the repo's idiom for keeping
# host-side schedulers (multidevice, resilience) out of the traced
# surface.
TRACED_ENTRYPOINTS = (
    "repro.core.simulator",
    "repro.replay",
    "repro.kernels.photon_step.ops",
    "repro.kernels.photon_step.ref",
    "repro.kernels.photon_step.photon_step",
)

_PRAGMA_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # "REP201"
    name: str          # "determinism"
    severity: str      # "error" | "warning"
    path: str          # repo-relative posix path
    line: int          # 1-indexed
    col: int
    message: str
    fingerprint: str = ""  # stable id for the baseline (engine-filled)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}[{self.name}] {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Module:
    """A parsed source file."""

    name: str          # dotted module name ("repro.core.photon")
    path: Path
    relpath: str       # repo-relative posix path
    source: str
    lines: list[str]
    tree: ast.Module
    aliases: dict[str, str]

    @property
    def package(self) -> str:
        if self.path.name == "__init__.py":
            return self.name
        return self.name.rpartition(".")[0]

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""


class Context:
    """Everything a rule can see: the parsed repo."""

    def __init__(self, root: Path, modules: dict[str, Module]):
        self.root = root
        self.modules = modules
        self.by_relpath = {m.relpath: m for m in modules.values()}
        self._traced: frozenset[str] | None = None

    def module(self, name: str) -> Module | None:
        return self.modules.get(name)

    @property
    def traced_modules(self) -> frozenset[str]:
        if self._traced is None:
            self._traced = traced_closure(self)
        return self._traced

    def finding(self, rule: "Rule", mod: Module | None, node: ast.AST | None,
                message: str, path: str | None = None) -> Finding:
        line = getattr(node, "lineno", 1) if node is not None else 1
        col = getattr(node, "col_offset", 0) if node is not None else 0
        return Finding(rule=rule.id, name=rule.name, severity=rule.severity,
                       path=path or (mod.relpath if mod else "<repo>"),
                       line=line, col=col, message=message)


class Rule:
    """Base class for reprolint rules.

    Subclasses set ``id``/``name``/``severity``/``description`` and
    override ``check_module`` (per-module rules; gate scope via
    ``applies``) or ``check`` (whole-repo rules).
    """

    id: str = "REP000"
    name: str = "base"
    severity: str = "error"
    description: str = ""

    def applies(self, mod: Module, ctx: Context) -> bool:
        return True

    def check_module(self, mod: Module, ctx: Context) -> Iterator[Finding]:
        return iter(())

    def check(self, ctx: Context) -> Iterator[Finding]:
        for mod in sorted(ctx.modules.values(), key=lambda m: m.relpath):
            if self.applies(mod, ctx):
                yield from self.check_module(mod, ctx)


def discover_modules(root: Path) -> dict[str, Module]:
    """Parse the lintable file set: ``src/repro/**`` + ``benchmarks/*``.

    Tests are consumers, not part of the linted surface (their imports
    do feed the reachability roots — the rule reads them separately).
    """
    root = Path(root)
    modules: dict[str, Module] = {}
    specs = [(root / "src", sorted((root / "src" / "repro").rglob("*.py"))
              if (root / "src" / "repro").is_dir() else []),
             (root, sorted((root / "benchmarks").glob("*.py"))
              if (root / "benchmarks").is_dir() else [])]
    for base, paths in specs:
        for path in paths:
            if "__pycache__" in path.parts:
                continue
            rel = path.relative_to(base)
            parts = list(rel.with_suffix("").parts)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            name = ".".join(parts)
            try:
                source = path.read_text()
                tree = ast.parse(source, filename=str(path))
            except (OSError, SyntaxError):
                continue  # unparseable files are ruff/pyflakes' problem
            pkg = name if path.name == "__init__.py" else \
                name.rpartition(".")[0]
            modules[name] = Module(
                name=name, path=path,
                relpath=path.relative_to(root).as_posix(),
                source=source, lines=source.splitlines(), tree=tree,
                aliases=astutil.build_alias_map(tree, pkg))
    return modules


def module_level_imports(mod: Module) -> set[str]:
    """Absolute module names imported at a module's top level."""
    out: set[str] = set()
    for node in mod.tree.body:
        out |= _imports_of(node, mod.package)
    return out


def all_imports(mod: Module) -> set[str]:
    """Absolute module names imported anywhere (lazy imports included)."""
    out: set[str] = set()
    for node in ast.walk(mod.tree):
        out |= _imports_of(node, mod.package)
    return out


def _imports_of(node: ast.AST, package: str) -> set[str]:
    out: set[str] = set()
    if isinstance(node, ast.Import):
        for a in node.names:
            out.add(a.name)
    elif isinstance(node, ast.ImportFrom):
        base = astutil.resolve_from_module(node, package)
        if base:
            out.add(base)
            for a in node.names:
                if a.name != "*":
                    out.add(f"{base}.{a.name}")
    return out


def _close_over(ctx: Context, roots: Iterable[str],
                imports_of) -> frozenset[str]:
    seen: set[str] = set()
    stack = [r for r in roots if r in ctx.modules]
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        # importing a submodule imports its ancestor packages too
        parts = name.split(".")
        for i in range(1, len(parts)):
            anc = ".".join(parts[:i])
            if anc in ctx.modules and anc not in seen:
                stack.append(anc)
        mod = ctx.modules.get(name)
        if mod is None:
            continue
        for imp in imports_of(mod):
            if imp in ctx.modules and imp not in seen:
                stack.append(imp)
    return frozenset(seen)


def traced_closure(ctx: Context) -> frozenset[str]:
    """Modules reachable from the traced entrypoints via top-level
    imports (the determinism / dtype scope)."""
    return _close_over(ctx, TRACED_ENTRYPOINTS, module_level_imports)


def reachable_closure(ctx: Context, roots: Iterable[str]) -> frozenset[str]:
    """Modules reachable from ``roots`` via *any* import (reachability
    scope: lazy imports keep a module alive)."""
    return _close_over(ctx, roots, all_imports)


def pragma_rules(line_text: str) -> set[str] | None:
    """Rule ids disabled by a same-line pragma, or None."""
    m = _PRAGMA_RE.search(line_text)
    if not m:
        return None
    return {p.strip() for p in m.group(1).split(",") if p.strip()}


def normalize_line(text: str) -> str:
    """Canonical form of a source line for fingerprinting.

    Strips any trailing comment (quote-aware, so ``#`` inside string
    literals survives) and removes all whitespace — whitespace- and
    comment-only edits must never invalidate a committed baseline
    fingerprint (only content changes re-surface a finding).
    """
    out: list[str] = []
    quote: str | None = None
    for ch in text:
        if quote:
            out.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            out.append(ch)
        elif ch == "#":
            break
        else:
            out.append(ch)
    return "".join("".join(out).split())


def _fingerprint(f: Finding, ctx: Context) -> str:
    mod = ctx.by_relpath.get(f.path)
    text = normalize_line(mod.line_text(f.line)) if mod else ""
    raw = f"{f.rule}:{f.path}:{text}"
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


@dataclasses.dataclass
class LintReport:
    findings: list[Finding]           # live (reported) findings
    suppressed_pragma: int
    suppressed_baseline: int
    n_modules: int
    rules_run: list[str]

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "version": 1,
            "clean": self.clean,
            "n_modules": self.n_modules,
            "rules": self.rules_run,
            "suppressed": {"pragma": self.suppressed_pragma,
                           "baseline": self.suppressed_baseline},
            "findings": [f.to_json() for f in self.findings],
        }


def run_lint(root: Path | str, rules: Iterable[Rule] | None = None,
             baseline: dict[str, int] | None = None,
             rule_ids: Iterable[str] | None = None) -> LintReport:
    """Lint the repo at ``root`` and return the report.

    ``rule_ids`` selects a subset of the registered rules by id (used
    by fixture tests to isolate one rule); ``baseline`` is the
    fingerprint -> count map of grandfathered findings.
    """
    from repro.lint.rules import ALL_RULES

    root = Path(root)
    active = list(rules) if rules is not None else [r() for r in ALL_RULES]
    if rule_ids is not None:
        wanted = set(rule_ids)
        active = [r for r in active if r.id in wanted or r.name in wanted]
    ctx = Context(root, discover_modules(root))

    raw: list[Finding] = []
    for rule in active:
        raw.extend(rule.check(ctx))
    raw.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    live: list[Finding] = []
    n_pragma = 0
    for f in raw:
        mod = ctx.by_relpath.get(f.path)
        disabled = pragma_rules(mod.line_text(f.line)) if mod else None
        if disabled and (f.rule in disabled or "all" in disabled):
            n_pragma += 1
            continue
        live.append(dataclasses.replace(f, fingerprint=_fingerprint(f, ctx)))

    n_base = 0
    if baseline:
        budget = dict(baseline)
        kept = []
        for f in live:
            if budget.get(f.fingerprint, 0) > 0:
                budget[f.fingerprint] -= 1
                n_base += 1
            else:
                kept.append(f)
        live = kept

    return LintReport(findings=live, suppressed_pragma=n_pragma,
                      suppressed_baseline=n_base,
                      n_modules=len(ctx.modules),
                      rules_run=[r.id for r in active])
