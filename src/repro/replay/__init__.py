"""Detected-photon replay: bit-exact re-simulation + absorption Jacobians.

The counter-seeded RNG (repro.core.rng) makes every photon's trajectory
a pure function of ``(seed, photon_id)`` — any photon can be
re-simulated bit-exactly on any device, any engine, any time.  The
detected-photon id buffer (``SimResult.det_rec``, DESIGN.md §replay)
tells us *which* photon ids reached each detector.  This module
combines the two into the workload every image-reconstruction pipeline
downstream of MCX-CL consumes: the absorption sensitivity (Jacobian)
volume of each detector reading.

For a detected packet exiting with weight ``w`` after a path spending
``L_v`` mm in voxel ``v`` (exact Beer-Lambert deposition),

    w = w0 * exp(-sum_v mua_v * L_v)   =>   dw/dmua_v = -w * L_v.

Summing over a detector's packets gives the exact first-order
sensitivity of its detected weight.  :func:`replay_jacobian` therefore
re-launches exactly the recorded ids in two lock-step passes:

  pass A  re-runs the trajectories and reads off each packet's exit
          weight (and exit gate — bit-identical to the forward run by
          the determinism contract);
  pass B  re-runs them again (the RNG makes both passes identical) and
          scatter-adds ``w_exit * seg_len`` of every transport segment
          into the ``(nvox, n_det)`` Jacobian volume of the packet's
          recorded detector.

The per-medium row sums of the result equal the forward run's
``det_ppath`` (weight-weighted partial pathlengths) — the consistency
check :func:`repro.core.analysis.jacobian_medium_sums` exposes and
tests/test_replay.py pins, alongside a finite-difference validation
against a perturbed forward run.

Replay cost is ~2x forward transport for the detected subset only —
typically a tiny fraction of the campaign — and is embarrassingly
parallel over records (chunked over fixed-size lane batches here).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photon as ph
from repro.core import rng as xrng
from repro.core.simulator import SimResult
from repro.core.volume import SimConfig, Volume
from repro.detectors import as_detectors, det_geometry, detector_bins
from repro.sources import as_source


class ReplayResult(NamedTuple):
    """Output of :func:`replay_jacobian`."""

    jacobian: np.ndarray   # (nx, ny, nz, n_det) float64: J[v, d] =
    #                        sum over detector-d records of
    #                        w_exit * L_v (weight * mm).  The detected
    #                        weight's first-order response to a voxel
    #                        absorption change is dW_d = -J[., d] . dmua
    #                        (dmua in 1/mm); normalize by launched_w for
    #                        per-unit-weight sensitivities.
    w_exit: np.ndarray     # (n_records,) float32 replayed exit weight
    det: np.ndarray        # (n_records,) int32 detector index (from the
    #                        forward record)
    gate: np.ndarray       # (n_records,) int32 replayed exit time gate
    replayed_det: np.ndarray  # (n_records,) int32 detector index
    #                        recomputed from the replayed exit position
    #                        (-1: the replayed photon did not hit a
    #                        detector — always equals ``det`` when
    #                        volume/cfg/source/seed match the forward
    #                        run)
    n_records: int


def detected_records(result: SimResult) -> np.ndarray:
    """Extract the valid detected-photon id records of a forward run.

    Returns an ``(n, 4)`` uint32 array of ``[id_lo, id_hi, det, gate]``
    rows.  Handles both single-run results (scalar ``det_rec_n``) and
    ``simulate_sharded`` results, whose ``det_rec`` is the concatenation
    of every shard's fixed-capacity buffer with per-shard valid counts
    in the rank-1 ``det_rec_n``.
    """
    rec = np.asarray(result.det_rec, np.uint32).reshape(-1, 4)
    n = np.asarray(result.det_rec_n)
    if n.ndim == 0:
        return rec[: int(n)]
    n_shards = n.shape[0]
    if n_shards == 0 or rec.shape[0] % n_shards:
        raise ValueError(
            f"sharded det_rec of {rec.shape[0]} rows does not split over "
            f"{n_shards} shards")
    cap = rec.shape[0] // n_shards
    parts = [rec[i * cap: i * cap + int(k)] for i, k in enumerate(n)]
    return np.concatenate(parts, axis=0) if parts else rec[:0]


def _build_replay_fn(shape, unitinmm, cfg: SimConfig, n_lanes: int,
                     n_det: int, source, det_geom):
    """Raw (unjitted) two-pass replay over one batch of ``n_lanes``
    records.  Returns ``fn(labels_flat, media, id_lo, id_hi, det_idx,
    active, seed) -> (jac_flat, w_exit, gate, replayed_det)`` with
    ``jac_flat`` of shape (nvox * n_det,)."""
    source = as_source(source)
    nx, ny, nz = shape
    nvox = nx * ny * nz
    ntg = int(cfg.n_time_gates)

    def fn(labels_flat, media, id_lo, id_hi, det_idx, active, seed):
        def transport(state0, per_step, carry0):
            """Lock-step transport until every lane retires, folding
            each segment's StepResult into ``carry`` via ``per_step``."""
            def cond(c):
                st, _, steps = c
                return jnp.any(st.alive) & (steps < cfg.max_steps)

            def body(c):
                st, carry, steps = c
                res = ph.step(st, labels_flat, media, shape, unitinmm, cfg)
                return res.state, per_step(carry, res), steps + 1

            _, carry, _ = jax.lax.while_loop(
                cond, body, (state0, carry0, jnp.int32(0)))
            return carry

        ids = xrng.PhotonId(lo=id_lo, hi=id_hi)
        pos, direc, w0, rng = source.sample(ids, jnp.asarray(seed,
                                                             jnp.uint32))
        state0 = ph.launch(pos, direc, w0, rng, active, shape)

        # -- pass A: exit weight / gate / replayed detector ------------
        def step_a(carry, res):
            w_exit, gate, rdet = carry
            esc = res.esc_w > 0
            g = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
            didx, dwgt = detector_bins(res.esc_pos, res.esc_w, det_geom)
            w_exit = jnp.where(esc, res.esc_w, w_exit)
            gate = jnp.where(esc, g, gate)
            rdet = jnp.where(dwgt > 0, didx, rdet)
            return w_exit, gate, rdet

        w_exit, gate, rdet = transport(
            state0,
            step_a,
            (jnp.zeros((n_lanes,), jnp.float32),
             jnp.full((n_lanes,), -1, jnp.int32),
             jnp.full((n_lanes,), -1, jnp.int32)),
        )

        # -- pass B: scatter w_exit * seg_len into J[., det] -----------
        # the counter-seeded RNG re-creates the identical trajectory, so
        # the exit weight from pass A is available from segment one
        det_ok = active & (det_idx >= 0) & (det_idx < n_det)
        det_safe = jnp.clip(det_idx, 0, max(n_det - 1, 0))
        wscale = jnp.where(det_ok, w_exit, 0.0)

        def step_b(jac, res):
            # seg_len is 0 for dead lanes, so retired lanes (and the
            # zero-weight padding) contribute nothing
            return jac.at[res.dep_idx * n_det + det_safe].add(
                wscale * res.seg_len)

        jac = transport(state0, step_b,
                        jnp.zeros((nvox * n_det,), jnp.float32))
        return jac, w_exit, gate, rdet

    return fn


def replay_jacobian(volume: Volume, cfg: SimConfig, records,
                    detectors, source=None, seed: int = 1234,
                    n_lanes: int = 4096) -> ReplayResult:
    """Replay detected-photon records into per-detector absorption
    Jacobian volumes (DESIGN.md §replay).

    ``records`` is the ``(n, 4)`` uint32 ``[id_lo, id_hi, det, gate]``
    array from :func:`detected_records` (or a forward ``SimResult``
    directly).  ``volume``/``cfg``/``detectors``/``source``/``seed``
    must match the forward run — the determinism contract then makes
    every replayed trajectory bit-identical, which
    ``ReplayResult.replayed_det``/``gate`` let callers assert.

    Records are replayed in fixed-size lane batches through one jitted
    two-pass transport; the Jacobian is accumulated on the host in
    float64.
    """
    if isinstance(records, SimResult):
        records = detected_records(records)
    records = np.asarray(records, np.uint32).reshape(-1, 4)
    detectors = as_detectors(detectors)
    n_det = len(detectors)
    if n_det == 0:
        raise ValueError("replay_jacobian needs the forward run's "
                         "detectors")
    if records.shape[0] and int(records[:, 2].max()) >= n_det:
        raise ValueError(
            f"record refers to detector {int(records[:, 2].max())} but "
            f"only {n_det} detectors were given — records and detectors "
            f"must come from the same forward run")
    # replays bake tmax/gates/physics from cfg; steps_per_round is a
    # forward-engine batching knob with no trajectory effect, so any
    # forward cfg maps onto the same replay
    cfg = dataclasses.replace(cfg, steps_per_round=1)
    n_rec = records.shape[0]
    nx, ny, nz = volume.shape
    n_lanes = max(1, min(int(n_lanes), max(n_rec, 1)))
    fn = jax.jit(_build_replay_fn(volume.shape, volume.unitinmm, cfg,
                                  n_lanes, n_det, source,
                                  det_geometry(detectors)))
    labels_flat = volume.labels.reshape(-1)

    jac = np.zeros((nx * ny * nz * n_det,), np.float64)
    w_exit = np.zeros((n_rec,), np.float32)
    gate = np.full((n_rec,), -1, np.int32)
    rdet = np.full((n_rec,), -1, np.int32)
    for start in range(0, n_rec, n_lanes):
        batch = records[start: start + n_lanes]
        nb = batch.shape[0]
        pad = n_lanes - nb
        id_lo = np.concatenate([batch[:, 0], np.zeros(pad, np.uint32)])
        id_hi = np.concatenate([batch[:, 1], np.zeros(pad, np.uint32)])
        didx = np.concatenate([batch[:, 2].astype(np.int32),
                               np.full(pad, -1, np.int32)])
        active = np.concatenate([np.ones(nb, bool), np.zeros(pad, bool)])
        jac_b, w_b, g_b, rd_b = fn(labels_flat, volume.media,
                                   jnp.asarray(id_lo), jnp.asarray(id_hi),
                                   jnp.asarray(didx), jnp.asarray(active),
                                   seed)
        jac += np.asarray(jac_b, np.float64)
        w_exit[start: start + nb] = np.asarray(w_b)[:nb]
        gate[start: start + nb] = np.asarray(g_b)[:nb]
        rdet[start: start + nb] = np.asarray(rd_b)[:nb]

    return ReplayResult(
        jacobian=jac.reshape(nx, ny, nz, n_det),
        w_exit=w_exit,
        det=records[:, 2].astype(np.int32),
        gate=gate,
        replayed_det=rdet,
        n_records=n_rec,
    )
