"""Detected-photon replay: bit-exact re-simulation + absorption Jacobians.

The counter-seeded RNG (repro.core.rng) makes every photon's trajectory
a pure function of ``(seed, photon_id)`` — any photon can be
re-simulated bit-exactly on any device, any engine, any time.  The
detected-photon id buffer (``SimResult.det_rec``, DESIGN.md §replay)
tells us *which* photon ids reached each detector.  This module
combines the two into the workload every image-reconstruction pipeline
downstream of MCX-CL consumes: the absorption sensitivity (Jacobian)
volume of each detector reading.

For a detected packet exiting with weight ``w`` after a path spending
``L_v`` mm in voxel ``v`` (exact Beer-Lambert deposition),

    w = w0 * exp(-sum_v mua_v * L_v)   =>   dw/dmua_v = -w * L_v.

Summing over a detector's packets gives the exact first-order
sensitivity of its detected weight.  :func:`replay_jacobian` therefore
re-launches exactly the recorded ids in two lock-step passes:

  pass A  re-runs the trajectories and reads off each packet's exit
          weight, detector and exit gate (bit-identical to the forward
          run by the determinism contract);
  pass B  re-runs them again (the RNG makes both passes identical) and
          scatter-adds ``w_exit * seg_len`` of every transport segment
          into the Jacobian column of the packet's recorded detector
          (and, with ``gate_resolved=True``, its recorded exit time
          gate — the ``(nvox, n_det)`` scatter widens to
          ``(nvox, n_det, ntg)``).

Both passes run in **fused rounds** of ``cfg.steps_per_round``
segments through a pluggable round executor (DESIGN.md §replay,
§rounds): ``engine="jnp"`` advances the segments in-graph,
``engine="pallas"`` dispatches the photon-step kernel
(repro.kernels.photon_step), which accumulates the Jacobian scatter
in-kernel.  Trajectories — and therefore the per-record outputs
``w_exit``/``gate``/``replayed_det`` — are bit-identical across
engines, fused-round depths and batch sizes; the Jacobian agrees to
fp-accumulation order (bit-identical too when the Pallas grid is a
single block).  Passing ``mesh=`` shards each record batch across the
mesh's devices with ``shard_map`` (one ``psum`` per batch, the same
collective structure as the forward ``simulate_sharded``), turning
million-record Jacobians into a device-parallel fan-out instead of a
host-side loop.

The per-medium row sums of the result equal the forward run's
``det_ppath`` (weight-weighted partial pathlengths) — the consistency
check :func:`repro.core.analysis.jacobian_medium_sums` exposes and
tests/test_replay.py pins, alongside a finite-difference validation
against a perturbed forward run.

Replay cost is ~2x forward transport for the detected subset only —
typically a tiny fraction of the campaign — and is embarrassingly
parallel over records (chunked over fixed-size lane batches, sharded
over devices when a mesh is given).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photon as ph
from repro.core import rng as xrng
from repro.core.simulator import ENGINES, SimResult
from repro.core.volume import SimConfig, Volume
from repro.detectors import (as_detectors, det_geometry, update_capture,
                             validate_detectors)
from repro.sources import as_source


class ReplayResult(NamedTuple):
    """Output of :func:`replay_jacobian`."""

    jacobian: np.ndarray   # (nx, ny, nz, n_det) float64 — or
    #                        (nx, ny, nz, n_det, ntg) with
    #                        gate_resolved=True, the extra axis keyed by
    #                        each record's exit time gate: J[v, d(, g)] =
    #                        sum over detector-d records of
    #                        w_exit * L_v (weight * mm).  The detected
    #                        weight's first-order response to a voxel
    #                        absorption change is dW_d = -J[., d] . dmua
    #                        (dmua in 1/mm); normalize by launched_w for
    #                        per-unit-weight sensitivities.
    w_exit: np.ndarray     # (n_records,) float32 replayed exit weight
    det: np.ndarray        # (n_records,) int32 detector index (from the
    #                        forward record)
    gate: np.ndarray       # (n_records,) int32 replayed exit time gate
    #                        (-1: the replayed photon was not captured
    #                        by a detector)
    replayed_det: np.ndarray  # (n_records,) int32 detector index
    #                        recomputed from the replayed exit position
    #                        (-1: the replayed photon did not hit a
    #                        detector — always equals ``det`` when
    #                        volume/cfg/source/seed match the forward
    #                        run)
    n_records: int


def detected_records(result: SimResult) -> np.ndarray:
    """Extract the valid detected-photon id records of a forward run.

    Returns an ``(n, 4)`` uint32 array of ``[id_lo, id_hi, det, gate]``
    rows.  Handles both single-run results (scalar ``det_rec_n``) and
    ``simulate_sharded`` results, whose ``det_rec`` is the concatenation
    of every shard's fixed-capacity buffer with per-shard valid counts
    in the rank-1 ``det_rec_n``.
    """
    rec = np.asarray(result.det_rec, np.uint32).reshape(-1, 4)
    n = np.asarray(result.det_rec_n)
    if n.ndim == 0:
        return rec[: int(n)]
    n_shards = n.shape[0]
    if n_shards == 0 or rec.shape[0] % n_shards:
        raise ValueError(
            f"sharded det_rec of {rec.shape[0]} rows does not split over "
            f"{n_shards} shards")
    cap = rec.shape[0] // n_shards
    parts = [rec[i * cap: i * cap + int(k)] for i, k in enumerate(n)]
    return np.concatenate(parts, axis=0) if parts else rec[:0]


def _build_replay_fn(shape, unitinmm, cfg: SimConfig, n_lanes: int,
                     n_det: int, source, det_geom, jac_cols: int,
                     engine: str = "jnp", block_lanes: int = 256,
                     interpret: bool | None = None):
    """Raw (unjitted, shard_map-composable) two-pass replay over one
    batch of ``n_lanes`` records.

    Returns ``fn(labels_flat, media, id_lo, id_hi, jac_col, active,
    seed) -> (jac_flat, w_exit, gate, replayed_det)`` with ``jac_flat``
    of shape ``(nvox * jac_cols,)``; ``jac_col`` is the per-lane fixed
    Jacobian column (``det`` — or ``det * ntg + record_gate`` for
    gate-resolved scatters) and ``active`` masks batch-padding lanes,
    whose contribution is exactly zero regardless of their (0, 0) id.

    Both passes advance ``cfg.steps_per_round`` fused segments per
    round through the selected executor; round boundaries and
    round-local accumulators match between the engines, so a
    single-block Pallas grid reproduces the jnp Jacobian bit-for-bit
    and the per-lane outputs are bit-identical for any blocking.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine: {engine!r} (choose from {ENGINES})")
    source = as_source(source)
    nx, ny, nz = shape
    nvox = nx * ny * nz
    ntg = int(cfg.n_time_gates)
    K = int(cfg.steps_per_round)
    if K < 1:
        raise ValueError(f"cfg.steps_per_round must be >= 1, got {K}")
    if engine == "pallas":
        from repro.kernels.photon_step.photon_step import (
            default_interpret, photon_step_pallas, resolve_block_lanes)

        # same grid-divisibility fallback as the forward executor
        block_lanes = resolve_block_lanes(n_lanes, block_lanes)
        if interpret is None:
            interpret = default_interpret()

    def fn(labels_flat, media, id_lo, id_hi, jac_col, active, seed):
        n_media = media.shape[0]
        ids = xrng.PhotonId(lo=id_lo, hi=id_hi)
        pos, direc, w0, rng = source.sample(ids,
                                            jnp.asarray(seed, jnp.uint32))

        def cond(c):
            return jnp.any(c[0].alive) & (c[-1] < cfg.max_steps)

        # -- pass A: exit weight / gate / replayed detector ------------
        # per-round accumulators start from zero and merge into the
        # carry once per round, mirroring the in-kernel structure so
        # both engines produce bit-identical per-lane outputs (a lane
        # escapes at most once: replay never regenerates)
        def body_a(c):
            st, w_exit, rdet, gate, pp, steps = c
            if engine == "pallas":
                outs = photon_step_pallas(
                    labels_flat, media, st, shape, unitinmm, cfg, K,
                    block_lanes, interpret, ppath=pp, det_geom=det_geom,
                    record=True)
                st, esc_r, pp = outs[0], outs[3], outs[5]
                capd, capg = outs[8], outs[9]
            else:
                def seg(k, sc):
                    st_k, esc_k, capd_k, capg_k = sc
                    res = ph.step(st_k, labels_flat, media, shape,
                                  unitinmm, cfg)
                    g = ph.time_gate_bins(res.dep_t, cfg.tmax_ns, ntg)
                    capd_k, capg_k = update_capture(capd_k, capg_k, res,
                                                    g, det_geom)
                    return (res.state, esc_k + res.esc_w, capd_k, capg_k)

                st, esc_r, capd, capg = jax.lax.fori_loop(
                    0, K, seg,
                    (st, jnp.zeros((n_lanes,), jnp.float32),
                     jnp.full((n_lanes,), -1, jnp.int32),
                     jnp.zeros((n_lanes,), jnp.int32)))
            w_exit = w_exit + esc_r
            rdet = jnp.where(capd >= 0, capd, rdet)
            gate = jnp.where(capd >= 0, capg, gate)
            return (st, w_exit, rdet, gate, pp, steps + K)

        # the Pallas capture path threads the per-lane ppath state; the
        # jnp pass reads none of it, so it carries a width-0 placeholder
        pp_w = n_media if engine == "pallas" else 0
        carry_a = (ph.launch(pos, direc, w0, rng, active, shape),
                   jnp.zeros((n_lanes,), jnp.float32),
                   jnp.full((n_lanes,), -1, jnp.int32),
                   jnp.full((n_lanes,), -1, jnp.int32),
                   jnp.zeros((n_lanes, pp_w), jnp.float32),
                   jnp.int32(0))
        _, w_exit, rdet, gate, _, _ = jax.lax.while_loop(cond, body_a,
                                                         carry_a)

        # -- pass B: scatter w_exit * seg_len into J[., jac_col] -------
        # the counter-seeded RNG re-creates the identical trajectory, so
        # the exit weight from pass A is available from segment one
        wscale = jnp.where(active, w_exit, 0.0)

        def body_b(c):
            st, jac, steps = c
            if engine == "pallas":
                outs = photon_step_pallas(
                    labels_flat, media, st, shape, unitinmm, cfg, K,
                    block_lanes, interpret, jac_w=wscale, jac_col=jac_col,
                    jac_cols=jac_cols)
                st, jac_r = outs[0], outs[-1]
            else:
                def seg(k, sc):
                    st_k, jac_k = sc
                    res = ph.step(st_k, labels_flat, media, shape,
                                  unitinmm, cfg)
                    # seg_len is 0 for dead lanes and wscale 0 for
                    # padding, so masked lanes add exact zeros
                    jac_k = jac_k.at[res.dep_idx * jac_cols + jac_col].add(
                        wscale * res.seg_len)
                    return (res.state, jac_k)

                st, jac_r = jax.lax.fori_loop(
                    0, K, seg,
                    (st, jnp.zeros((nvox * jac_cols,), jnp.float32)))
            return (st, jac + jac_r, steps + K)

        _, jac, _ = jax.lax.while_loop(
            cond, body_b,
            (ph.launch(pos, direc, w0, rng, active, shape),
             jnp.zeros((nvox * jac_cols,), jnp.float32),
             jnp.int32(0)))
        return jac, w_exit, gate, rdet

    return fn


def _batch_arrays(records, start, n_lanes, gate_resolved, ntg):
    """Pad one record batch to ``n_lanes`` lanes; padding lanes carry
    id (0, 0) with ``active=False`` (their launch weight is masked to
    zero, so they transport nothing — even when a *real* detected
    photon has id 0)."""
    batch = records[start: start + n_lanes]
    nb = batch.shape[0]
    pad = n_lanes - nb
    id_lo = np.concatenate([batch[:, 0], np.zeros(pad, np.uint32)])
    id_hi = np.concatenate([batch[:, 1], np.zeros(pad, np.uint32)])
    det = batch[:, 2].astype(np.int32)
    col = det * ntg + batch[:, 3].astype(np.int32) if gate_resolved else det
    col = np.concatenate([col, np.zeros(pad, np.int32)]).astype(np.int32)
    active = np.concatenate([np.ones(nb, bool), np.zeros(pad, bool)])
    return nb, id_lo, id_hi, col, active


def replay_jacobian(volume: Volume, cfg: SimConfig, records,
                    detectors, source=None, seed: int = 1234,
                    n_lanes: int = 4096, engine: str = "jnp",
                    gate_resolved: bool = False, block_lanes: int = 256,
                    interpret: bool | None = None, mesh=None,
                    axis_names: tuple[str, ...] = ("data",),
                    tracer=None) -> ReplayResult:
    """Replay detected-photon records into per-detector absorption
    Jacobian volumes (DESIGN.md §replay).

    ``records`` is the ``(n, 4)`` uint32 ``[id_lo, id_hi, det, gate]``
    array from :func:`detected_records` (or a forward ``SimResult``
    directly).  ``volume``/``cfg``/``detectors``/``source``/``seed``
    must match the forward run — the determinism contract then makes
    every replayed trajectory bit-identical, which
    ``ReplayResult.replayed_det``/``gate`` let callers assert.

    ``engine`` selects the fused-round executor for both transport
    passes (``"jnp"`` | ``"pallas"``; ``block_lanes``/``interpret``
    tune the Pallas executor, ``cfg.steps_per_round`` the round depth).
    ``gate_resolved=True`` widens the scatter to ``(nvox, n_det, ntg)``
    keyed by each record's exit time gate — the gate axis *partitions*
    the ungated Jacobian, so its gate-sum recovers the
    ``gate_resolved=False`` result.  ``mesh`` distributes each record
    batch over the mesh's ``axis_names`` devices via ``shard_map``
    (``n_lanes`` lanes per device, Jacobian psum'd per batch —
    ``repro.core.multidevice.sharded_replay_fn``).

    Records are replayed in fixed-size lane batches through one jitted
    two-pass transport; the Jacobian is accumulated on the host in
    float64.  ``tracer`` (a ``repro.telemetry.Tracer``) records one span
    per batch — blocked on inside the span, tagged with the record count
    so records/s throughput lands on the trace timeline (DESIGN.md
    §observability).
    """
    if isinstance(records, SimResult):
        records = detected_records(records)
    records = np.asarray(records, np.uint32).reshape(-1, 4)
    detectors = as_detectors(detectors)
    n_det = len(detectors)
    if n_det == 0:
        raise ValueError("replay_jacobian needs the forward run's "
                         "detectors")
    validate_detectors(detectors, volume.shape)
    if records.shape[0] and int(records[:, 2].max()) >= n_det:
        raise ValueError(
            f"record refers to detector {int(records[:, 2].max())} but "
            f"only {n_det} detectors were given — records and detectors "
            f"must come from the same forward run")
    ntg = int(cfg.n_time_gates)
    if gate_resolved and records.shape[0] and \
            int(records[:, 3].max()) >= ntg:
        raise ValueError(
            f"record refers to time gate {int(records[:, 3].max())} but "
            f"cfg.n_time_gates={ntg} — gate-resolved replay needs the "
            f"forward run's gate count")
    jac_cols = n_det * ntg if gate_resolved else n_det
    n_rec = records.shape[0]
    nx, ny, nz = volume.shape
    labels_flat = volume.labels.reshape(-1)

    if mesh is not None:
        from repro.core.multidevice import sharded_replay_fn

        n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
        n_lanes = max(1, min(int(n_lanes),
                             -(-max(n_rec, 1) // n_shards)))
        fn = sharded_replay_fn(volume, cfg, detectors, mesh, axis_names,
                               n_lanes, source, engine, gate_resolved,
                               block_lanes, interpret)
        from jax.sharding import NamedSharding, PartitionSpec as P

        lane_sh = NamedSharding(mesh, P(axis_names))
        repl = NamedSharding(mesh, P())
        labels_dev = jax.device_put(labels_flat, repl)
        media_dev = jax.device_put(volume.media, repl)
        batch_lanes = n_shards * n_lanes

        def run_batch(id_lo, id_hi, col, active):
            return fn(labels_dev, media_dev,
                      jax.device_put(jnp.asarray(id_lo), lane_sh),
                      jax.device_put(jnp.asarray(id_hi), lane_sh),
                      jax.device_put(jnp.asarray(col), lane_sh),
                      jax.device_put(jnp.asarray(active), lane_sh),
                      jnp.uint32(seed))
    else:
        n_lanes = max(1, min(int(n_lanes), max(n_rec, 1)))
        raw = _build_replay_fn(volume.shape, volume.unitinmm, cfg, n_lanes,
                               n_det, source, det_geometry(detectors),
                               jac_cols, engine, block_lanes, interpret)
        jit_fn = jax.jit(raw)
        batch_lanes = n_lanes

        def run_batch(id_lo, id_hi, col, active):
            return jit_fn(labels_flat, volume.media, jnp.asarray(id_lo),
                          jnp.asarray(id_hi), jnp.asarray(col),
                          jnp.asarray(active), jnp.uint32(seed))

    jac = np.zeros((nx * ny * nz * jac_cols,), np.float64)  # reprolint: disable=REP301 - host-side Jacobian accumulator
    w_exit = np.zeros((n_rec,), np.float32)
    gate = np.full((n_rec,), -1, np.int32)
    rdet = np.full((n_rec,), -1, np.int32)
    trace_dev = "mesh" if mesh is not None else jax.devices()[0]
    for start in range(0, n_rec, batch_lanes):
        nb, id_lo, id_hi, col, active = _batch_arrays(
            records, start, batch_lanes, gate_resolved, ntg)
        span = None
        if tracer is not None:
            span = tracer.span("replay_batch", device=trace_dev,
                               engine=engine, records=nb, batch_start=start)
        jac_b, w_b, g_b, rd_b = run_batch(id_lo, id_hi, col, active)
        if span is not None:
            jax.block_until_ready(jac_b)
            span.end()
        jac += np.asarray(jac_b, np.float64)  # reprolint: disable=REP301 - host-side Jacobian accumulator
        w_exit[start: start + nb] = np.asarray(w_b)[:nb]
        gate[start: start + nb] = np.asarray(g_b)[:nb]
        rdet[start: start + nb] = np.asarray(rd_b)[:nb]

    shape_out = ((nx, ny, nz, n_det, ntg) if gate_resolved
                 else (nx, ny, nz, n_det))
    return ReplayResult(
        jacobian=jac.reshape(shape_out),
        w_exit=w_exit,
        det=records[:, 2].astype(np.int32),
        gate=gate,
        replayed_det=rdet,
        n_records=n_rec,
    )
