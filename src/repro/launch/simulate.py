"""Photon-simulation launcher (the paper's workload).

  PYTHONPATH=src python -m repro.launch.simulate --bench B1 \
      --photons 100000 --lanes 4096 [--autotune] [--devices all]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro import detectors as D
from repro import scenarios as SC
from repro import telemetry as T
from repro.core import analysis as A
from repro.core import simulator as S
from repro.core import volume as V
from repro.core.multidevice import ChunkScheduler, simulate_sharded


def get_bench(name: str, size: int):
    shape = (size, size, size)
    if name == "B1":
        return V.benchmark_b1(shape), V.SimConfig(do_reflect=False)
    if name in ("B2", "B2a"):
        return V.benchmark_b2(shape), V.SimConfig(do_reflect=True)
    raise ValueError(name)


def _run_scenarios(args, ap, tracer, sinks):
    """--scenarios: batched multi-scenario execution (DESIGN.md §batching)."""
    spec = args.scenarios
    if spec.startswith("@"):
        with open(spec[1:]) as f:
            spec = f.read()
    entries = json.loads(spec)
    if not isinstance(entries, list) or not entries:
        ap.error("--scenarios expects a non-empty JSON list of scenario "
                 "dicts (or @file.json holding one)")
    scenarios = [SC.Scenario.from_dict(e) for e in entries]
    mesh = None
    if args.devices == "all" and len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    cache = SC.default_cache()
    t0 = time.time()
    results = SC.simulate_many(scenarios, n_lanes=args.lanes,
                               engine=args.engine, mesh=mesh, cache=cache,
                               tracer=tracer)
    jax.block_until_ready(results)
    dt = time.time() - t0

    total_photons = sum(sc.n_photons for sc in scenarios)
    keys = {SC.group_key(sc, args.lanes, engine=args.engine)
            for sc in scenarios}
    sharded = f" over {mesh.size} devices" if mesh is not None else ""
    print(f"scenarios: {len(scenarios)} in {dt:.2f}s "
          f"({len(scenarios)/dt:.2f} scenarios/s, "
          f"{total_photons/dt/1e3:.2f} photons/ms total), "
          f"{len(keys)} config shape(s){sharded}")
    st = cache.stats()
    print(f"compile cache: {st['hits']} hits / {st['misses']} misses "
          f"(hit rate {st['hit_rate']:.2f}), {st['entries']} entries, "
          f"{st['evictions']} evictions")
    for i, (sc, res) in enumerate(zip(scenarios, results)):
        bal = A.energy_balance(res)
        line = (f"  scenario {i}: {sc.n_photons} photons seed={sc.seed} "
                f"absorbed={bal['absorbed']:.1f} "
                f"escaped={bal['escaped']:.1f} "
                f"residue={bal['residue_frac']:.2e}")
        if sc.detectors:
            line += f" det_w={np.asarray(res.det_w).sum():.3f}"
        print(line)
    if tracer is not None:
        tracer.counter("scenarios_per_s", len(scenarios) / dt,
                       engine=args.engine)
        tracer.counter("photons_per_s", total_photons / dt,
                       engine=args.engine)
        if args.trace_out:
            path = tracer.save_chrome_trace(args.trace_out)
            print(f"trace timeline: {path} "
                  f"({len(tracer.events)} spans; open in chrome://tracing)")
        for sink in sinks:
            sink.close()
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="B1", choices=["B1", "B2", "B2a"])
    ap.add_argument("--photons", type=int, default=100_000)
    ap.add_argument("--lanes", type=int, default=4096)
    ap.add_argument("--size", type=int, default=60)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--engine", default="jnp", choices=list(S.ENGINES),
                    help="round executor: in-graph jnp loop or the Pallas "
                         "photon-step kernel (DESIGN.md §rounds)")
    ap.add_argument("--steps-per-round", type=int, default=1,
                    help="K: fused transport segments per regeneration/"
                         "flush round")
    ap.add_argument("--autotune", action="store_true",
                    help="Opt2: pilot-sweep the lane count (at the chosen "
                         "steps-per-round)")
    ap.add_argument("--devices", default="one", choices=["one", "all"])
    ap.add_argument("--chunk", type=int, default=0,
                    help=">0: dynamic chunk scheduling (straggler-safe)")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="seeded fault-injection drill for the --chunk "
                         "scheduler (DESIGN.md §resilience): JSON "
                         "FaultInjector config, e.g. '{\"seed\": 1, "
                         "\"p_fail\": 0.2, \"p_nan\": 0.1, \"p_delay\": "
                         "0.2, \"delay_s\": 0.1, \"poison_chunks\": [0], "
                         "\"dropout\": {\"w0:cpu:0\": 2}}'; results stay "
                         "bit-identical to the fault-free run")
    ap.add_argument("--max-retries", type=int, default=None, metavar="N",
                    help="attempt cap per chunk before it is quarantined "
                         "(default: RetryPolicy's 5); requires --chunk")
    ap.add_argument("--chunk-timeout-s", type=float, default=None,
                    metavar="S",
                    help="hard per-chunk deadline: a chunk inflight "
                         "longer re-dispatches speculatively (on top of "
                         "the fitted DeviceModel deadlines); requires "
                         "--chunk")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="S",
                    help="overall wall-clock bound for the chunked run "
                         "(TimeoutError past it instead of waiting "
                         "forever); requires --chunk")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="auto-checkpoint the chunked campaign every N "
                         "merged chunks (atomic Checkpointer); requires "
                         "--chunk and --checkpoint-dir")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="checkpoint directory for --checkpoint-every; "
                         "if it already holds a matching campaign "
                         "checkpoint the run resumes from it")
    ap.add_argument("--source", default=None,
                    help="JSON source spec (repro.sources), e.g. "
                         '\'{"type": "disk", "pos": [30, 30, 0], '
                         '"radius": 5}\'; default: pencil beam')
    ap.add_argument("--time-gates", type=int, default=1,
                    help="bin deposited energy over this many time-of-"
                         "flight gates spanning [0, tmax_ns] (DESIGN.md "
                         "§time-resolved); 1 = CW (default)")
    ap.add_argument("--detectors", default=None,
                    help="JSON detector disks on the z=0 face "
                         "(repro.detectors), e.g. "
                         '\'[{"x": 40, "y": 30, "radius": 2}]\'; records '
                         "per-detector TPSF + mean partial pathlengths")
    ap.add_argument("--save-detected", type=int, default=0, metavar="CAP",
                    help="record detected-photon ids (global photon id, "
                         "detector, exit gate) for replay (DESIGN.md "
                         "§replay); requires --detectors.  CAP is the id-"
                         "buffer capacity PER SIMULATION UNIT: the whole "
                         "run on one device, per shard with --devices "
                         "all, per chunk with --chunk (buffers are "
                         "concatenated host-side) — check the reported "
                         "overflow either way")
    ap.add_argument("--replay", action="store_true",
                    help="after the forward run, replay the recorded "
                         "detected photons into per-detector absorption "
                         "Jacobian volumes (requires --save-detected)")
    ap.add_argument("--replay-engine", default="jnp",
                    choices=list(S.ENGINES),
                    help="round executor for the two replay transport "
                         "passes (DESIGN.md §replay): in-graph jnp loop "
                         "or the Pallas photon-step kernel; with "
                         "--devices all the record batches are "
                         "additionally shard_map'd over every device")
    ap.add_argument("--replay-gate-resolved", action="store_true",
                    help="widen the replay scatter to a time-gate-"
                         "resolved (nvox, n_det, n_time_gates) Jacobian "
                         "keyed by each record's exit gate (requires "
                         "--replay)")
    ap.add_argument("--tmax-ns", type=float, default=None,
                    help="time-of-flight cutoff in ns (default: the "
                         "benchmark config's 5.0); weight still in "
                         "flight at the cutoff is retired as timed-out")
    ap.add_argument("--collect-stats", action="store_true",
                    help="accumulate round-level telemetry counters "
                         "(lane occupancy, relaunches, retired weight) "
                         "onto SimResult.stats (DESIGN.md "
                         "§observability); physics outputs stay "
                         "bit-identical")
    ap.add_argument("--scenarios", default=None, metavar="JSON",
                    help="batched multi-scenario run (repro.scenarios): a "
                         "JSON list of scenario dicts (or @file.json), "
                         "each with keys bench/size/photons/seed/source/"
                         "detectors/time_gates/steps_per_round/tmax_ns/"
                         "do_reflect/id_offset.  Scenarios sharing a "
                         "config shape are vmapped into one executable "
                         "via the compile cache; with --devices all the "
                         "scenario axis is sharded over the mesh.  "
                         "Results are bit-identical to sequential runs")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="stream structured telemetry events (spans, "
                         "counters) as JSON lines to PATH")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the host-side span timeline as Chrome "
                         "trace_event JSON to PATH (open in "
                         "chrome://tracing or Perfetto; per-device "
                         "photons/s feeds telemetry.fit_device_models)")
    args = ap.parse_args(argv)
    if args.save_detected and not args.detectors:
        ap.error("--save-detected requires --detectors")
    if args.replay and not args.save_detected:
        ap.error("--replay requires --save-detected")
    if args.replay_gate_resolved and not args.replay:
        ap.error("--replay-gate-resolved requires --replay")
    for flag in ("chaos", "max_retries", "chunk_timeout_s", "deadline_s"):
        if getattr(args, flag) is not None and not args.chunk:
            ap.error(f"--{flag.replace('_', '-')} requires --chunk")
    if args.checkpoint_every and not (args.chunk and args.checkpoint_dir):
        ap.error("--checkpoint-every requires --chunk and --checkpoint-dir")
    if args.scenarios:
        for flag in ("chunk", "autotune", "save_detected", "replay",
                     "source", "detectors", "collect_stats"):
            if getattr(args, flag):
                ap.error(f"--scenarios is incompatible with "
                         f"--{flag.replace('_', '-')} (scenario dicts "
                         f"carry their own per-scenario config)")

    source = json.loads(args.source) if args.source else None
    detectors = D.as_detectors(
        json.loads(args.detectors)) if args.detectors else None
    vol, cfg = get_bench(args.bench, args.size)
    if args.steps_per_round != 1:
        cfg = dataclasses.replace(cfg, steps_per_round=args.steps_per_round)
    if args.time_gates != 1:
        cfg = dataclasses.replace(cfg, n_time_gates=args.time_gates)
    if args.tmax_ns is not None:
        cfg = dataclasses.replace(cfg, tmax_ns=args.tmax_ns)
    if args.collect_stats:
        cfg = dataclasses.replace(cfg, collect_stats=True)

    sinks = []
    if args.metrics_out:
        sinks.append(T.JsonlSink(args.metrics_out))
    tracer = (T.Tracer(sinks=sinks)
              if (args.trace_out or sinks) else None)
    if args.scenarios:
        return _run_scenarios(args, ap, tracer, sinks)
    lanes = args.lanes
    if args.autotune:
        lanes, timings = S.autotune_lanes(vol, cfg, n_pilot=args.photons // 10,
                                          source=source, engine=args.engine)
        print("autotune:", {k: round(v, 3) for k, v in timings.items()},
              "-> lanes =", lanes)

    t0 = time.time()
    mesh = None
    if args.chunk:
        from repro.resilience import FaultInjector, RetryPolicy

        injector = (FaultInjector(**json.loads(args.chaos))
                    if args.chaos else None)
        policy = (RetryPolicy(max_attempts=args.max_retries)
                  if args.max_retries is not None else None)
        checkpointer = None
        resume = False
        if args.checkpoint_every:
            from repro.checkpoint import Checkpointer

            checkpointer = Checkpointer(args.checkpoint_dir)
            resume = checkpointer.latest_step() is not None
            if resume:
                print(f"resuming from checkpoint step "
                      f"{checkpointer.latest_step()} in "
                      f"{args.checkpoint_dir}")
        sched = ChunkScheduler(vol, cfg, n_lanes=lanes, source=source,
                               engine=args.engine, detectors=detectors,
                               record_detected=args.save_detected,
                               tracer=tracer, fault_injector=injector,
                               retry_policy=policy,
                               chunk_timeout_s=args.chunk_timeout_s,
                               checkpointer=checkpointer,
                               checkpoint_every=args.checkpoint_every)
        res, stats = sched.run(args.photons, args.chunk, seed=args.seed,
                               deadline_s=args.deadline_s, resume=resume)
        print("per-device photons:", stats)
        rep = sched.last_report
        if injector is not None or rep.retries or rep.quarantine_events:
            c = rep.counters()
            print(f"resilience: {c['merged']}/{c['chunks']} chunks merged, "
                  f"{c['retries']} retries, {c['speculative']} speculative, "
                  f"{c['validation_failures']} rejected by merge guard, "
                  f"{c['quarantine_events']} quarantine events, "
                  f"{c['checkpoints']} checkpoints")
    elif args.devices == "all" and len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        span = tracer.span("simulate", device="mesh", engine=args.engine,
                           photons=args.photons) if tracer else None
        res = simulate_sharded(vol, cfg, args.photons, mesh,
                               n_lanes=lanes, seed=args.seed, source=source,
                               engine=args.engine, detectors=detectors,
                               record_detected=args.save_detected)
        if span is not None:
            jax.block_until_ready(res)
            span.end()
    else:
        span = tracer.span("simulate", device=jax.devices()[0],
                           engine=args.engine,
                           photons=args.photons) if tracer else None
        res = S.simulate(vol, cfg, args.photons, lanes, args.seed,
                         source=source, engine=args.engine,
                         detectors=detectors,
                         record_detected=args.save_detected)
        if span is not None:
            jax.block_until_ready(res)
            span.end()
    jax.block_until_ready(res)
    dt = time.time() - t0

    bal = A.energy_balance(res)
    fwd_pps = args.photons / dt
    print(f"{args.bench}: {args.photons} photons in {dt:.2f}s "
          f"({args.photons/dt/1e3:.2f} photons/ms)")
    print(f"energy balance: absorbed={bal['absorbed']:.1f} "
          f"escaped={bal['escaped']:.1f} timed_out={bal['timed_out']:.2e} "
          f"residue={bal['residue_frac']:.2e}")
    timed_frac = bal["timed_out"] / max(bal["launched"], 1e-30)
    if timed_frac > 0.01:
        print(f"WARNING: {timed_frac:.1%} of launched weight "
              f"({bal['timed_out']:.3f}) was retired by the "
              f"tmax_ns={cfg.tmax_ns} time gate / max_steps cap — "
              f"fluence and detector readings are truncated; raise "
              f"--tmax-ns if unintended")
    if res.stats is not None:
        sd = res.stats.to_dict()
        print(f"round stats: {sd['rounds']} rounds "
              f"({sd['regen_rounds']} regenerating, "
              f"{sd['relaunched']} relaunches), lane occupancy "
              f"{sd['lane_occupancy']:.1%} "
              f"({sd['live_segments']:.3g}/{sd['lane_segments']:.3g} "
              f"lane-segments live)")
        if tracer is not None:
            for k, v in sd.items():
                tracer.counter(f"round_stats.{k}", v, bench=args.bench,
                               engine=args.engine)
    phi = A.fluence_cw(res, vol)
    print(f"fluence: max={float(np.max(np.asarray(phi))):.3e} "
          f"nonzero voxels={int(np.sum(np.asarray(phi) > 0))}")
    if cfg.n_time_gates > 1:
        td = np.asarray(A.fluence_td(res, vol))
        per_gate = td.sum(axis=(0, 1, 2))
        print(f"time gates: {cfg.n_time_gates} x {cfg.gate_width_ns:.3f} ns, "
              f"peak gate {int(per_gate.argmax())}")
    if detectors:
        times, curves = A.tpsf(res, cfg)
        tot = np.asarray(res.det_w).sum(axis=1)
        for i, d in enumerate(detectors):
            peak = float(times[int(curves[i].argmax())]) if tot[i] else 0.0
            print(f"detector {i} ({d.x:.0f},{d.y:.0f},r={d.radius:.0f}): "
                  f"weight={tot[i]:.3f} tpsf-peak@{peak:.3f} ns")
        print("mean partial pathlengths (mm/medium):")
        print(np.array_str(A.detector_mean_ppath(res), precision=2))
    if args.save_detected:
        from repro.replay import detected_records, replay_jacobian

        recs = detected_records(res)
        overflow = int(np.asarray(res.det_rec_overflow))
        print(f"detected-photon records: {recs.shape[0]} "
              f"(overflow: {overflow})")
        if overflow > 0:
            print(f"WARNING: {overflow} detector captures were dropped "
                  f"from the id buffer (capacity {args.save_detected} per "
                  f"simulation unit) — det_w still counts them, but "
                  f"replay will miss them; raise --save-detected")
        if args.replay and recs.shape[0]:
            t0 = time.time()
            rep = replay_jacobian(vol, cfg, recs, detectors, source=source,
                                  seed=args.seed, n_lanes=lanes,
                                  engine=args.replay_engine,
                                  gate_resolved=args.replay_gate_resolved,
                                  mesh=mesh, tracer=tracer)
            dt = time.time() - t0
            ok = int((rep.replayed_det == rep.det).sum())
            sharded = f" over {mesh.size} devices" if mesh is not None else ""
            print(f"replay[{args.replay_engine}]: {rep.n_records} photons "
                  f"in {dt:.2f}s ({rep.n_records/dt/1e3:.2f} photons/ms)"
                  f"{sharded}, {ok}/{rep.n_records} detector-exact")
            jac = rep.jacobian
            med = A.jacobian_medium_sums(jac, vol)
            gated = jac if jac.ndim == 4 else jac.sum(axis=-1)
            for i, d in enumerate(detectors):
                nz = int(np.sum(gated[..., i] > 0))
                print(f"  J[det {i}]: sum={gated[..., i].sum():.3e} "
                      f"(weight*mm), nonzero voxels={nz}, per-medium "
                      f"{np.array_str(med[i], precision=3)}")
            if jac.ndim == 5:
                per_gate = jac.sum(axis=(0, 1, 2, 3))
                print(f"  gate-resolved: {jac.shape[-1]} gates, "
                      f"peak gate {int(per_gate.argmax())}")
    if tracer is not None:
        tracer.counter("photons_per_s", fwd_pps,
                       bench=args.bench, engine=args.engine)
        if args.trace_out:
            path = tracer.save_chrome_trace(args.trace_out)
            print(f"trace timeline: {path} "
                  f"({len(tracer.events)} spans; open in chrome://tracing)")
        for sink in sinks:
            sink.close()
    return res


if __name__ == "__main__":
    main()
