"""Training launcher: any --arch, checkpoint/restart, DP modes.

Runs real steps on the local device(s) with a reduced config by default
(full configs are exercised via the dry-run).  Demonstrates the full
fault-tolerance loop: periodic checkpoints (params + opt + data cursor),
``--resume`` restarts from the newest complete checkpoint, and
``--dp_mode shardmap`` runs explicit-collective data parallelism with
optional int8 error-feedback gradient compression (optim/compression.py).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --steps 20 --ckpt_dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.checkpoint import Checkpointer
from repro.data import make_pipeline
from repro.models import api as API
from repro.optim import adamw, apply_updates, compressed_psum


def build(arch: str, smoke: bool, lr: float):
    cfg = C.get_smoke_config(arch) if smoke else C.get_config(arch)
    model = API.build_model(cfg)
    optimizer = adamw(lr=lr)
    return cfg, model, optimizer


def make_dp_shardmap_step(model, optimizer, mesh, compress: bool):
    """Explicit shard_map DP: per-shard grads + (compressed) psum."""
    from jax.sharding import PartitionSpec as P

    def step(params, opt_state, err, batch):
        def loss_fn(p):
            logits = model.forward(p, batch["tokens"])
            return API.cross_entropy(logits, batch["labels"],
                                     batch.get("mask"))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if compress:
            flat, tdef = jax.tree.flatten(grads)
            eflat = tdef.flatten_up_to(err)
            out = [compressed_psum(g, e, "data") for g, e in zip(flat, eflat)]
            grads = tdef.unflatten([o[0] for o in out])
            err = tdef.unflatten([o[1] for o in out])
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, "data"), grads)
        loss = jax.lax.pmean(loss, "data")
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, err, {"loss": loss}

    return jax.jit(jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P(), P(), P("data")),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    ))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=C.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq_len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt_dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt_every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dp_mode", choices=["jit", "shardmap"], default="jit")
    ap.add_argument("--grad_compress", action="store_true")
    ap.add_argument("--data", default=None, help="text file path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, model, optimizer = build(args.arch, args.smoke, args.lr)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = optimizer.init(params)
    pipe = make_pipeline(cfg.vocab, args.batch, args.seq_len,
                         seed=args.seed, path=args.data)
    ckpt = Checkpointer(args.ckpt_dir, keep=3)

    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if args.grad_compress else jax.tree.map(
            lambda p: jnp.zeros((1,), jnp.float32), params)

    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore(
            {"params": params, "opt": opt_state, "data": pipe.state_dict()})
        params, opt_state = state["params"], state["opt"]
        pipe.load_state_dict(state["data"])
        print(f"resumed from step {start_step}")

    if args.dp_mode == "shardmap":
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        step_fn = make_dp_shardmap_step(model, optimizer, mesh,
                                        args.grad_compress)
    else:
        train_step, _ = API.make_train_step(model, optimizer)
        jstep = jax.jit(train_step)
        step_fn = None

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        if args.dp_mode == "shardmap":
            params, opt_state, err, metrics = step_fn(
                params, opt_state, err, batch)
        else:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state,
                                 "data": pipe.state_dict()},
                      extra={"arch": args.arch, "loss": loss})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
