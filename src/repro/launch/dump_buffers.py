import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Debug tool: compile one cell and print the largest HLO tensors +
memory_analysis fields.  Usage:
  PYTHONPATH=src python -m repro.launch.dump_buffers --arch X --shape Y
"""

import argparse
import re

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    from repro.launch import dryrun as DR

    # reuse run_cell but keep the compiled object: monkeypatch-free rerun
    import repro.launch.dryrun as mod
    cfg = None
    from repro import configs as C
    from repro.launch.mesh import make_production_mesh
    from repro.models import api as API
    from repro.models.config import SHAPES
    from repro.optim import adamw
    from repro.sharding import hints
    from repro.sharding import partition as SH
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = C.get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    batch_axes, model_axis = SH._axes(mesh)
    model = API.build_model(cfg)
    fsdp = shape.step_kind == "train"
    param_shapes = API.param_specs(model)
    pspecs = SH.param_partition_specs(param_shapes, cfg, mesh, fsdp=fsdp)
    batch_shapes = API.input_specs(cfg, shape)
    bspecs = SH.batch_specs(batch_shapes, mesh)
    sizes = dict(mesh.shape)
    ep = bool(cfg.n_experts) and cfg.n_experts % sizes[model_axis] == 0
    n_dp = 1
    for a in batch_axes:
        n_dp *= sizes[a]

    with hints.activation_hints(batch_axes, model_axis, expert_parallel=ep,
                                n_data_shards=n_dp), \
            jax.sharding.set_mesh(mesh):
        if shape.step_kind == "train":
            optimizer = adamw()
            opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
            ospecs = SH.opt_state_specs_like(pspecs, opt_shapes)
            step_fn, _ = API.make_train_step(model, optimizer)
            co = jax.jit(
                step_fn,
                in_shardings=(SH.to_shardings(pspecs, mesh),
                              SH.to_shardings(ospecs, mesh),
                              SH.to_shardings(bspecs, mesh)),
                out_shardings=(SH.to_shardings(pspecs, mesh),
                               SH.to_shardings(ospecs, mesh),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, batch_shapes).compile()
        elif shape.step_kind == "prefill":
            step_fn = API.make_prefill_step(model)
            co = jax.jit(
                step_fn,
                in_shardings=(SH.to_shardings(pspecs, mesh),
                              SH.to_shardings(bspecs, mesh)),
            ).lower(param_shapes, batch_shapes).compile()
        else:
            cache_shapes = API.cache_specs(model, shape.global_batch,
                                           shape.seq_len)
            cspecs = SH.cache_specs_tree(cache_shapes, cfg, mesh)
            step_fn = API.make_serve_step(model)
            co = jax.jit(
                step_fn,
                in_shardings=(SH.to_shardings(pspecs, mesh),
                              SH.to_shardings(cspecs, mesh),
                              SH.to_shardings(bspecs, mesh)),
                out_shardings=(NamedSharding(mesh, P()),
                               SH.to_shardings(cspecs, mesh)),
                donate_argnums=(1,),
            ).lower(param_shapes, cache_shapes, batch_shapes).compile()

    mem = co.memory_analysis()
    for f in dir(mem):
        if f.endswith("bytes"):
            print(f"{f}: {getattr(mem, f)/2**30:.2f} GiB")
    hlo = co.as_text()
    sizes_by_shape = {}
    for m in re.finditer(r"(bf16|f32|f16|s32|u32|s8|u8|pred)\[([0-9,]+)\]",
                         hlo):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        bytes_ = n * {"bf16": 2, "f16": 2, "pred": 1, "s8": 1,
                      "u8": 1}.get(dt, 4)
        key = f"{dt}[{dims}]"
        prev = sizes_by_shape.get(key, (0, 0))
        sizes_by_shape[key] = (bytes_, prev[1] + 1)
    for k, (b, cnt) in sorted(sizes_by_shape.items(),
                              key=lambda kv: -kv[1][0])[: args.top]:
        print(f"{b/2**30:8.2f} GiB x{cnt:4d}  {k}")


if __name__ == "__main__":
    main()
