import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay first — jax locks the device count
# on first init.  (That also rules out `from __future__ import`.)

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the appropriate step (train_step / prefill / serve)
with the production in/out shardings, ``.lower()`` it against
ShapeDtypeStruct stand-ins (no allocation), ``.compile()`` it, and
extract:

  * ``compiled.memory_analysis()``   — per-device bytes (does it fit?),
  * ``compiled.cost_analysis()``     — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized HLO text (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute).

Results go to a JSON report consumed by benchmarks/roofline.py and
EXPERIMENTS.md.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.launch.mesh import make_production_mesh
from repro.models import api as API
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import adamw
from repro.sharding import hints
from repro.sharding import partition as SH
from jax.sharding import NamedSharding, PartitionSpec as P

# TPU v5e-class hardware constants (per chip) for the roofline terms
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link (~per-chip usable)

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=\s*(\([^)]*\)|\S+)\s")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|u64)"
                       r"\[([0-9,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in the HLO."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(
            r".*=\s*((?:\([^)]*\))|(?:\S+))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", stripped)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[op] = out.get(op, 0.0) + float(nbytes)
    return out


def _cost_get(cost: dict, key: str) -> float:
    try:
        return float(cost.get(key, 0.0))
    except Exception:
        return 0.0


def analytic_terms(cfg, shape, n_chips: int, fsdp: bool,
                   n_total: float, n_active: float) -> dict:
    """First-principles roofline terms (per chip, seconds).

    XLA:CPU's cost_analysis counts while-loop (scan) bodies ONCE, so its
    FLOP/byte totals undercount scanned layer stacks; these closed-form
    estimates are the primary roofline numbers (EXPERIMENTS.md §Roofline
    documents the cross-check).  First-order formulas:

      FLOPs  = mult * N_active * tokens  (+ attention 4*L*B*S^2*H*hd*fb)
      bytes  = weight traffic + activation traffic + KV-cache traffic
      coll   = fsdp weight all-gather + grad reduce + TP activation
               reductions (train); TP reductions (serve)
    """
    L = cfg.n_layers + cfg.n_encoder_layers
    b, s = shape.global_batch, shape.seq_len
    tokens = b * (1 if shape.step_kind == "decode" else s)
    train = shape.step_kind == "train"
    mult = 6.0 if train else 2.0
    fb = 3.0 if train else 1.0  # fwd + 2x bwd

    flops = mult * n_active * tokens
    if cfg.n_heads and shape.step_kind != "decode":
        s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        flops += 4.0 * L * b * s * s_eff * cfg.n_heads * cfg.head_dim * fb / 2
    if cfg.n_heads and shape.step_kind == "decode":
        s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        flops += 4.0 * L * b * s_eff * cfg.n_heads * cfg.head_dim

    wbytes = 2.0 * n_total            # bf16 weights, one read
    if train:
        wbytes = 2.0 * n_total * 3 + 12.0 * n_total  # fwd+bwd+update, adam
    act = 2.0 * tokens * cfg.d_model * L * (4 if train else 2)
    cache = 0.0
    if shape.step_kind == "decode":
        if cfg.use_mla:
            cache = 2.0 * b * s * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * L
        elif cfg.n_kv_heads:
            s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
            cache = 2.0 * 2 * b * s_eff * cfg.n_kv_heads * cfg.head_dim * L
        if cfg.ssm_state:
            cache += 4.0 * b * cfg.ssm_nheads * cfg.ssm_state * \
                cfg.ssm_headdim * L
    bytes_total = wbytes + act + cache

    # collectives (global bytes moved, then / chips for per-link time)
    coll = 0.0
    if train:
        if fsdp:
            coll += 2.0 * n_total * 2          # weight AG fwd+bwd
        coll += 2.0 * n_total * 2              # grad RS + param AG (or AR)
        # TP activation reductions: ~4 per layer of (tokens, d_model)
        coll += 4.0 * L * tokens * cfg.d_model * 2
    else:
        coll += 2.0 * L * tokens * cfg.d_model * 2  # TP reductions
    return {
        "analytic_flops": flops,
        "analytic_bytes": bytes_total,
        "analytic_coll_bytes": coll,
        "analytic_compute_s": flops / (n_chips * PEAK_FLOPS_BF16),
        "analytic_memory_s": bytes_total / (n_chips * HBM_BW),
        "analytic_collective_s": coll / (n_chips * ICI_BW),
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig):
    """6*N*D (dense) / 6*N_active*D (MoE) useful-model-FLOPs estimate.
    Returns (model_flops, n_total_params, n_active_params)."""
    model = API.build_model(cfg)
    specs = API.param_specs(model)
    import numpy as np

    def leaf_count(tree):
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))

    n_total = leaf_count(specs)
    if cfg.n_experts and cfg.top_k:
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        expert_params = sum(
            int(np.prod(l.shape)) for path, l in flat
            if any("ffn" in str(getattr(k, "key", k)) for k in path)
            and l.shape and l.ndim >= 3 and l.shape[-3] == cfg.n_experts
        )
        n_active = n_total - expert_params + expert_params * cfg.top_k / cfg.n_experts
    else:
        n_active = n_total
    tokens = shape.global_batch * (1 if shape.step_kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.step_kind == "train" else 2.0
    return mult * n_active * tokens, float(n_total), float(n_active)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp=None, cfg_override=None) -> dict:
    """Lower+compile one cell; returns the roofline record."""
    cfg = cfg_override or C.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes, model_axis = SH._axes(mesh)
    n_chips = mesh.size
    model = API.build_model(cfg)
    if fsdp is None:
        fsdp = shape.step_kind == "train"

    param_shapes = API.param_specs(model)
    pspecs = SH.param_partition_specs(param_shapes, cfg, mesh, fsdp=fsdp)
    batch_shapes = API.input_specs(cfg, shape)
    bspecs = SH.batch_specs(batch_shapes, mesh)

    ep = bool(cfg.n_experts) and model_axis is not None and \
        cfg.n_experts % dict(mesh.shape)[model_axis] == 0
    sizes = dict(mesh.shape)
    n_dp = 1
    for a in batch_axes:
        n_dp *= sizes[a]
    import contextlib
    stack = contextlib.ExitStack()
    stack.enter_context(hints.activation_hints(batch_axes, model_axis,
                                               expert_parallel=ep,
                                               n_data_shards=n_dp))
    stack.enter_context(jax.sharding.set_mesh(mesh))
    t0 = time.time()
    if shape.step_kind == "train":
        optimizer = adamw()
        opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
        ospecs = SH.opt_state_specs_like(pspecs, opt_shapes)
        step_fn, _ = API.make_train_step(model, optimizer)
        jitted = jax.jit(
            step_fn,
            in_shardings=(SH.to_shardings(pspecs, mesh),
                          SH.to_shardings(ospecs, mesh),
                          SH.to_shardings(bspecs, mesh)),
            out_shardings=(SH.to_shardings(pspecs, mesh),
                           SH.to_shardings(ospecs, mesh),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),  # params/opt update in place
        )
        lowered = jitted.lower(param_shapes, opt_shapes, batch_shapes)
    elif shape.step_kind == "prefill":
        step_fn = API.make_prefill_step(model)
        jitted = jax.jit(
            step_fn,
            in_shardings=(SH.to_shardings(pspecs, mesh),
                          SH.to_shardings(bspecs, mesh)),
        )
        lowered = jitted.lower(param_shapes, batch_shapes)
    else:  # decode
        cache_shapes = API.cache_specs(model, shape.global_batch,
                                       shape.seq_len)
        cspecs = SH.cache_specs_tree(cache_shapes, cfg, mesh,
                                     seq_shard=bool(
                                         os.environ.get("REPRO_CACHE_SEQ")))
        step_fn = API.make_serve_step(model)
        jitted = jax.jit(
            step_fn,
            in_shardings=(SH.to_shardings(pspecs, mesh),
                          SH.to_shardings(cspecs, mesh),
                          SH.to_shardings(bspecs, mesh)),
            out_shardings=(NamedSharding(mesh, P()),
                           SH.to_shardings(cspecs, mesh)),
            donate_argnums=(1,),  # KV/SSM cache updates in place
        )
        lowered = jitted.lower(param_shapes, cache_shapes, batch_shapes)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    stack.close()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # collectives inside the scanned layer body appear once in the HLO
    # text but execute once per layer: scale by the stack depth
    n_loop = cfg.n_layers + cfg.n_encoder_layers
    coll_total = sum(coll.values()) * max(n_loop, 1)

    # XLA:CPU cost_analysis counts while-loop bodies once — per-device
    # values reported as *lower bounds*, cross-checked by the analytic
    # closed forms below (which drive the bottleneck classification)
    hlo_flops_dev = _cost_get(cost, "flops")
    hlo_bytes_dev = _cost_get(cost, "bytes accessed")
    mf, n_total, n_active = model_flops(cfg, shape)
    ana = analytic_terms(cfg, shape, n_chips, fsdp, n_total, n_active)

    t_compute = ana["analytic_compute_s"]
    t_memory = ana["analytic_memory_s"]
    t_coll = ana["analytic_collective_s"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    hlo_flops_global = hlo_flops_dev * n_chips * max(n_loop, 1)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "step_kind": shape.step_kind,
        "fsdp": fsdp,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_dev_body_once": hlo_flops_dev,
        "hlo_bytes_per_dev_body_once": hlo_bytes_dev,
        "collective_bytes": coll_total,
        "collectives_body_once": coll,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)),
        },
        **terms,
        **ana,
        "hlo_collective_s": coll_total / (n_chips * ICI_BW),
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf,
        "n_params": n_total,
        "n_active_params": n_active,
        "useful_flops_frac": min(
            mf / max(ana["analytic_flops"], 1.0), 1.0),
        "roofline_frac": (
            mf / (n_chips * PEAK_FLOPS_BF16)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--fsdp", default=None,
                    help="override fsdp on/off (default: train only)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        todo = [(a, s.name) for a, s in C.cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    fsdp = None if args.fsdp is None else args.fsdp == "on"
    failures = 0
    for arch, shape_name in todo:
        for multi_pod in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
            try:
                rec = run_cell(arch, shape_name, multi_pod, fsdp=fsdp)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"[OK] {tag}: compile={rec['compile_s']}s "
                      f"bottleneck={rec['bottleneck']} "
                      f"terms=({rec['compute_s']:.3e},{rec['memory_s']:.3e},"
                      f"{rec['collective_s']:.3e})s "
                      f"peak/dev={rec['bytes_per_device']['peak']/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
