"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module touches no jax device state — required for the dry-run's
host-device-count trick to work and for smoke tests to keep seeing one
device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(axis: str = "data"):
    """All locally visible devices on one axis (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), (axis,))
