"""Detector subsystem: TPSF recording on the z=0 (illuminated) face.

MCX-CL's primary diffuse-optics output besides the fluence volume is the
set of *detected photons*: packets that exit the domain through a
user-defined detector aperture, recorded with their time-of-flight and
per-medium partial pathlengths.  Those records give the detector
time-point-spread function (TPSF) and allow re-scaling detected weight
for perturbed absorption coefficients without re-simulating
(``analysis.rescale_detected``).

This module adapts that to the lock-step engine (DESIGN.md
§time-resolved):

  * A :class:`Detector` is a disk on the z=0 face — ``(x, y)`` center
    and ``radius`` in voxel units.  Detectors are static trace-time
    configuration, like sources.
  * Capture is evaluated with the same z=0-face predicate as the
    exitance image (``photon.Z_EXIT_FACE_VOX``), so every detected
    packet is a subset of the exitance energy.
  * Fixed-shape accumulators instead of per-photon record lists (the
    lock-step engine cannot grow a buffer): per detector the engine
    keeps a ``(n_det, n_time_gates)`` detected-weight TPSF histogram
    and a ``(n_det, n_media)`` weight-weighted partial-pathlength sum.
    Dividing the latter by the detector's total detected weight gives
    the mean partial pathlength per medium — the first-order statistic
    MCX's per-photon records are most commonly reduced to.
  * Overlapping detectors: a photon is credited to the *first* detector
    (lowest index) whose disk contains the exit point, mirroring MCX's
    first-match semantics.

``detector_bins`` is pure jnp and shared by the engine, the pure-jnp
oracle and the Pallas kernel so all three capture identically.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.photon import Z_EXIT_FACE_VOX


@dataclasses.dataclass(frozen=True)
class Detector:
    """One detector disk on the z=0 face (voxel units)."""

    x: float
    y: float
    radius: float

    def __post_init__(self):
        if self.radius <= 0:
            raise ValueError(f"detector radius must be > 0, got {self.radius}")


def as_detectors(spec) -> tuple[Detector, ...]:
    """Coerce a detector spec into a tuple of :class:`Detector`.

    Accepts ``None`` (no detectors), an iterable of :class:`Detector`,
    ``(x, y, radius)`` triples, or ``{"x": .., "y": .., "radius": ..}``
    dicts (the CLI's ``--detectors`` JSON form).
    """
    if spec is None:
        return ()
    out = []
    for d in spec:
        if isinstance(d, Detector):
            out.append(d)
        elif isinstance(d, dict):
            out.append(Detector(float(d["x"]), float(d["y"]),
                                float(d["radius"])))
        else:
            x, y, r = d
            out.append(Detector(float(x), float(y), float(r)))
    return tuple(out)


def to_dicts(detectors: Sequence[Detector]) -> list[dict]:
    """JSON-friendly campaign config (inverse of :func:`as_detectors`)."""
    return [{"x": d.x, "y": d.y, "radius": d.radius} for d in detectors]


def validate_detectors(detectors: Sequence[Detector],
                       shape: tuple[int, int, int]) -> None:
    """Reject detector disks that cannot capture anything on this volume.

    A detector lies on the z=0 face, so its disk must intersect the
    ``[0, nx] x [0, ny]`` footprint of the volume; a disk placed fully
    outside (or tangent to) the footprint silently records zero weight
    forever — almost always a units mistake (mm vs voxel) or a detector
    meant for a different volume.  Called at ``make_simulator`` time so
    the error carries the actionable context, not a mid-campaign NaN
    hunt.
    """
    nx, ny = float(shape[0]), float(shape[1])
    for i, d in enumerate(detectors):
        # distance from the disk center to the closest point of the
        # footprint rectangle (0 when the center lies inside it)
        dx = max(0.0 - d.x, 0.0, d.x - nx)
        dy = max(0.0 - d.y, 0.0, d.y - ny)
        if dx * dx + dy * dy >= d.radius * d.radius:
            raise ValueError(
                f"detector {i} (x={d.x}, y={d.y}, radius={d.radius}) lies "
                f"entirely outside the z=0 face of the volume (footprint "
                f"[0, {nx}] x [0, {ny}] voxels) and can never capture a "
                f"photon — detector coordinates are in voxel units on the "
                f"z=0 face; move the disk inside the footprint or enlarge "
                f"its radius")


def det_geometry(detectors: Sequence[Detector]) -> jnp.ndarray:
    """(n_det, 3) float32 rows of (x, y, radius^2) for the capture test."""
    rows = [[d.x, d.y, d.radius * d.radius] for d in detectors]
    return jnp.asarray(np.asarray(rows, np.float32).reshape(-1, 3))


def detector_bins(esc_pos, esc_w, det_geom):
    """Match z=0-face escapes against the detector disks.

    ``det_geom`` is the (n_det, 3) array from :func:`det_geometry`.
    Returns ``(det_idx, w)``: per lane the index of the first detector
    whose disk contains the exit point, and the weight to credit it
    (0 for lanes that did not exit through the z=0 face or missed every
    disk; their index is 0 so the masked scatter is in-range).
    """
    z_exit = esc_pos[:, 2] < Z_EXIT_FACE_VOX
    dx = esc_pos[:, None, 0] - det_geom[None, :, 0]   # (N, n_det)
    dy = esc_pos[:, None, 1] - det_geom[None, :, 1]
    inside = (dx * dx + dy * dy) <= det_geom[None, :, 2]
    hit_any = jnp.any(inside, axis=1) & z_exit & (esc_w > 0)
    det_idx = jnp.argmax(inside, axis=1).astype(jnp.int32)  # first match
    return det_idx, jnp.where(hit_any, esc_w, 0.0)


def accumulate_capture(pp, dw, dp, res, gate, det_geom, ntg):
    """One segment of detector bookkeeping, shared by the jnp engine,
    the Pallas kernel and the ref oracle so all three capture
    identically (the same contract as ``exitance_bins``).

    Adds the segment's pathlength to the per-lane per-medium ``pp``
    (N, n_media) BEFORE testing capture — a photon escaping this
    segment is recorded with the final segment included — then
    histograms detected weight into the flat gate-major ``dw``
    (n_det * ntg,) and the weighted pathlength sums ``dp``
    (n_det, n_media).  ``res`` is the segment's ``photon.StepResult``,
    ``gate`` its per-lane time-gate index.  Returns the updated
    ``(pp, dw, dp)``.
    """
    n_media = pp.shape[1]
    med_cols = jnp.arange(n_media, dtype=jnp.int32)[None, :]
    pp = pp + jnp.where(res.seg_med[:, None] == med_cols,
                        res.seg_len[:, None], 0.0)
    didx, dwgt = detector_bins(res.esc_pos, res.esc_w, det_geom)
    dw = dw.at[didx * ntg + gate].add(dwgt)
    dp = dp.at[didx].add(dwgt[:, None] * pp)
    return pp, dw, dp


def update_capture(cap_det, cap_gate, res, gate, det_geom):
    """One segment of detected-photon id bookkeeping (DESIGN.md §replay).

    ``cap_det``/``cap_gate`` are per-lane int32 state for the current
    fused round: the detector index (-1: not captured this round) and
    exit time gate of the lane's capture.  A lane captures at most once
    per round — escape kills the lane and regeneration only runs
    between rounds — so a plain masked select is race-free.  Shared by
    the jnp round executor, the Pallas kernel and the ref oracle so all
    three record identically (the ``detector_bins`` call is common
    subexpression with :func:`accumulate_capture` and fuses away under
    jit).
    """
    didx, dwgt = detector_bins(res.esc_pos, res.esc_w, det_geom)
    newly = dwgt > 0
    cap_det = jnp.where(newly, didx, cap_det)
    cap_gate = jnp.where(newly, gate, cap_gate)
    return cap_det, cap_gate
