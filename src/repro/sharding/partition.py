"""Parameter / activation partition rules (DP + FSDP + TP + EP).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Conventions (MaxText-style):

  * batch axes   = ("pod", "data")    — DP over pods and the data axis;
  * fsdp axes    = batch axes         — in train mode, every weight
    matrix additionally shards its non-TP dim over the DP axes (ZeRO-3):
    671B-param deepseek does not fit 512 x 16 GB any other way.  GSPMD
    all-gathers one scanned layer at a time inside the loop body;
  * "model" axis = TP: head dims / FFN hidden / MoE experts.

Expert placement: experts shard on "model" when E is divisible by the
axis size (deepseek 256/16), otherwise the per-expert FFN dim shards
(mixtral 8 experts -> TP within experts).  Uneven head counts (phi3: 40
heads on 16-way TP) are allowed — GSPMD pads; see DESIGN.md.

Rules are keyed on the parameter's path, matching on the *trailing*
dimensions so the same rule serves plain stacks (L, ...), nested VLM
stacks (G, K, ...) and unstacked leaves.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any


def _axes(mesh: Mesh):
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    return batch_axes, model_axis


def _pad_leading(spec_tail: tuple, ndim: int) -> P:
    """Left-pad a trailing-dims spec with None for stack dims."""
    pad = (None,) * (ndim - len(spec_tail))
    return P(*(pad + spec_tail))


def _enforce_divisible(spec: P, shape: tuple, axis_sizes: dict) -> P:
    """Explicit in_shardings (unlike constraints) require every sharded
    dim to divide evenly; drop the sharding of dims that don't (e.g.
    odd vocab sizes 50280/32001/51865, kv_heads=8 on a 16-way axis)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= axis_sizes.get(a, 1)
        out.append(entry if dim % prod == 0 else None)
    return P(*out)


def _rule(path: str, shape: tuple, cfg: ModelConfig, batch_axes, model_axis,
          fsdp: bool, axis_sizes: dict):
    """Return the trailing-dims partition spec for one parameter."""
    f = batch_axes if (fsdp and batch_axes) else None
    m = model_axis
    nd = len(shape)

    # Embedding tables: vocab over the model axis ONLY, d_model dim
    # replicated.  Measured alternatives (EXPERIMENTS.md §Perf): fsdp on
    # the d_model (contraction) dim makes GSPMD replicate the (B,S,V)
    # activations (131 GiB/dev); vocab over (data x model) conflicts with
    # the batch's data sharding and replicates the lm_head input
    # (8 GiB/dev).  Model-only vocab sharding makes the logits matmul
    # communication-free: (B/dp, S, D) @ (D, V/tp) -> (B/dp, S, V/tp).
    if path.endswith("embed"):
        return P(m, None)
    if "lm_head" in path:
        return P(None, m)
    if path.endswith(("scale", "a_log", "dt_bias", "d_skip", "conv_b",
                      "meta")):
        return P(*((None,) * nd))
    if "conv_w" in path:
        return _pad_leading((None, None), nd)
    if "router" in path:
        return _pad_leading((f, None), nd)

    # MoE expert tensors: trailing (E, D, F) or (E, F, D)
    if any(s in path for s in ("ffn", "shared")) and nd >= 3 and \
            shape[-3] == cfg.n_experts and cfg.n_experts > 0:
        ep = (m is not None and cfg.n_experts % axis_sizes.get(m, 1) == 0)
        if "wd" in path:
            return _pad_leading((m, None, f) if ep else (None, m, f), nd)
        return _pad_leading((m, f, None) if ep else (None, f, m), nd)

    # column-parallel (input-dim fsdp, output-dim TP)
    if any(s in path for s in ("wq", "wk", "wv", "wg", "wu", "in_proj",
                               "wq_b", "wkv_b", "wq_a")):
        return _pad_leading((f, m), nd)
    # kv_a latent projection: small odd output dim — replicate outputs
    if "wkv_a" in path:
        return _pad_leading((f, None), nd)
    # row-parallel (input-dim TP, output-dim fsdp)
    if any(s in path for s in ("wo", "wd", "out_proj")):
        return _pad_leading((m, f), nd)
    # fallback: shard nothing
    return P(*((None,) * nd))


def param_partition_specs(param_shapes: PyTree, cfg: ModelConfig, mesh: Mesh,
                          *, fsdp: bool = True) -> PyTree:
    """PartitionSpec pytree matching ``param_shapes`` (ShapeDtypeStructs)."""
    batch_axes, model_axis = _axes(mesh)
    axis_sizes = dict(mesh.shape)

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        spec = _rule(path, leaf.shape, cfg, batch_axes, model_axis, fsdp,
                     axis_sizes)
        return _enforce_divisible(spec, leaf.shape, axis_sizes)

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def batch_specs(batch_tree: PyTree, mesh: Mesh) -> PyTree:
    """Shard the global batch dim over the DP axes; everything else replicated."""
    batch_axes, _ = _axes(mesh)
    ba = batch_axes if batch_axes else None
    axis_sizes = dict(mesh.shape)

    def one(path_tuple, leaf):
        name = str(getattr(path_tuple[-1], "key", path_tuple[-1]))
        if name == "pos" or leaf.ndim == 0:
            return P()
        spec = P(*((ba,) + (None,) * (leaf.ndim - 1)))
        return _enforce_divisible(spec, leaf.shape, axis_sizes)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_specs_tree(cache_shapes: PyTree, cfg: ModelConfig, mesh: Mesh,
                     *, seq_shard: bool = False) -> PyTree:
    """Decode caches: (L, B, ...) -> batch dim over DP axes, head/latent
    dims over the model axis where aligned.

    ``seq_shard=True`` shards the cache *length* dim over the model axis
    instead (flash-decoding style context parallelism): archs whose
    kv_heads don't divide the TP width (8 kv on 16-way) otherwise
    replicate the entire cache across the model axis — the dominant
    decode memory + collective cost (EXPERIMENTS.md §Perf hillclimb).
    """
    batch_axes, m = _axes(mesh)
    ba = batch_axes if batch_axes else None
    axis_sizes = dict(mesh.shape)
    s_ax, kv_ax = (m, None) if seq_shard else (None, m)

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        nd = leaf.ndim
        if path.endswith(("/k", "/v")):        # (L, B, S, KV, hd)
            spec = _pad_leading((ba, s_ax, kv_ax, None), nd)
        elif "c_kv" in path or "k_rope" in path:  # (L, B, S, lora)
            spec = _pad_leading((ba, s_ax, None), nd)
        elif path.endswith("/h"):               # (L, B, H, N, P)
            spec = _pad_leading((ba, m, None, None), nd)
        elif path.endswith("/conv"):            # (L, B, k, conv_dim)
            spec = _pad_leading((ba, None, None), nd)
        else:
            spec = P(*((None,) * nd))
        return _enforce_divisible(spec, leaf.shape, axis_sizes)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def to_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs_like(param_specs: PyTree, opt_state_shapes) -> PyTree:
    """AdamW state: moments inherit param specs; step replicated."""
    from repro.optim.adamw import AdamWState

    return AdamWState(step=P(), mu=param_specs, nu=param_specs)
