"""Activation-sharding hints for model code.

Model code calls ``constrain(x, *dims)`` with *logical* dims ("batch",
"model", "seq", None).  When a launcher has activated hints (dry-run,
train, serve), these lower to ``with_sharding_constraint``; in
single-device smoke tests they are no-ops.  This is how the big
intermediates (fp32 logits above all) get their model-axis sharding
instead of relying on GSPMD propagation, which replicates them.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict = {"batch": None, "model": None, "ep": False, "dp": 1}
_ENABLED = False


@contextlib.contextmanager
def activation_hints(batch_axes, model_axis, *, expert_parallel=False,
                     n_data_shards=1):
    """Enable logical->mesh-axis resolution inside this context.

    ``expert_parallel`` switches the MoE logical dims: with EP, "expert"
    maps to the model axis and "ffn" is unsharded; without EP (expert
    count < axis size, e.g. mixtral-8x7b) experts replicate and the
    per-expert FFN dim carries the model axis.  ``n_data_shards`` tells
    the MoE dispatch how many shard-local routing groups to use — a
    global argsort/scatter cannot be partitioned by GSPMD and replicates
    the dispatch buffers.
    """
    global _ENABLED, _ACTIVE
    prev = (_ENABLED, dict(_ACTIVE))
    _ENABLED = True
    _ACTIVE = {"batch": batch_axes, "model": model_axis,
               "ep": expert_parallel, "dp": max(int(n_data_shards), 1)}
    try:
        yield
    finally:
        _ENABLED, _ACTIVE = prev[0], prev[1]


def data_shard_count() -> int:
    return _ACTIVE["dp"] if _ENABLED else 1


def resolve(*dims) -> Optional[P]:
    if not _ENABLED:
        return None
    out = []
    for d in dims:
        if d is None:
            out.append(None)
        elif d == "batch":
            out.append(_ACTIVE["batch"])
        elif d in ("model", "seq"):  # "seq" = sequence parallelism on model
            out.append(_ACTIVE["model"])
        elif d == "expert":
            out.append(_ACTIVE["model"] if _ACTIVE["ep"] else None)
        elif d == "ffn":
            out.append(None if _ACTIVE["ep"] else _ACTIVE["model"])
        else:
            raise ValueError(f"unknown logical dim {d}")
    return P(*out)


def constrain(x, *dims):
    spec = resolve(*dims)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
