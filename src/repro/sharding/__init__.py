from repro.sharding.partition import (  # noqa: F401
    batch_specs, cache_specs_tree, param_partition_specs, to_shardings,
)
