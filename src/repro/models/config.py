"""Architecture configuration dataclass shared by all model families."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                     # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0             # per-expert hidden dim
    capacity_factor: float = 1.25
    first_dense_layers: int = 0   # deepseek: first k layers stay dense

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- attention variants ---
    sliding_window: int = 0       # 0 = full attention (mixtral SWA = 4096)

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (hymba) ---
    meta_tokens: int = 0

    # --- vlm ---
    cross_attn_every: int = 0     # insert a cross-attn layer every N layers
    n_image_tokens: int = 0

    # --- encdec (whisper) ---
    n_encoder_layers: int = 0
    encoder_frames: int = 1500    # stub audio frontend sequence length

    # --- training ---
    remat: bool = True

    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def kv_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.kind in ("ssm", "hybrid") or self.sliding_window > 0

    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str
    seq_len: int
    global_batch: int
    step_kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
