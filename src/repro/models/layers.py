"""Shared neural net layers: norms, RoPE, MLPs, attention variants.

All attention paths use a chunked online-softmax ("flash-style")
formulation written with lax.scan so the S x S score matrix is never
materialized — required for the 32k prefill and 4k x 256-batch train
cells to fit the per-device memory budget.  A Pallas TPU kernel can be
swapped in via ``attention_impl="pallas"`` (kernels/flash_attention);
the XLA path is the portable default and the oracle.

Parameters are plain nested dicts of jnp arrays.  Layer stacks are
created by vmapping the per-layer init over a leading layer axis and
consumed with lax.scan (MaxText-style), keeping HLO size O(1 layer).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import hints


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}


def linear(p, x):
    return x @ p["w"]


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


def rope(x, positions, theta):
    """Rotary embedding.  x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model, d_ff, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": init_linear(kg, d_model, d_ff, dtype),
        "wu": init_linear(ku, d_model, d_ff, dtype),
        "wd": init_linear(kd, d_ff, d_model, dtype, scale=d_ff ** -0.5),
    }


def swiglu(p, x):
    return linear(p["wd"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wu"], x))


# ---------------------------------------------------------------------------
# chunked (flash-style) scaled dot-product attention — the XLA oracle
# ---------------------------------------------------------------------------

def _mask_for(qp, kp, causal, window, sk_valid):
    mask = kp[None, :] <= qp[:, None] if causal else jnp.ones(
        (qp.shape[0], kp.shape[0]), bool)
    if window:
        mask = mask & (kp[None, :] > qp[:, None] - window)
    return mask & (kp[None, :] < sk_valid)


def _flash_forward(q, k, v, causal, q_offset, window, cq, ck, sk_valid):
    """Chunked online-softmax forward.  Blocked inputs:
    q: (B, nq, cq, KV, G, D); k: (B, nk, ck, KV, D); v: (..., Dv).
    Returns out (B, nq, cq, KV, G, Dv) and lse (B, nq, cq, KV, G)."""
    b, nq, _, kv, groups, d = q.shape
    nk = k.shape[1]
    dv = v.shape[-1]
    scale = d ** -0.5
    q_pos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)

    def q_block(args):
        qb, qp = args  # (B, cq, KV, G, D), (cq,)

        def kv_step(carry, blk):
            m, l, acc = carry
            kb, vb, kp = blk
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb, kb) * scale
            s = s.astype(jnp.float32)
            mask = _mask_for(qp, kp, causal, window, sk_valid)
            s = jnp.where(mask[None, None, None, :, :], s, -jnp.inf)
            # running max kept at a finite floor so a fully-masked chunk
            # (sliding window / padding) yields p == 0, never exp(-inf+inf)
            m_new = jnp.maximum(jnp.maximum(m, s.max(axis=-1)), -1e30)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, groups, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, groups, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, groups, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k.transpose(1, 0, 2, 3, 4), v.transpose(1, 0, 2, 3, 4), k_pos),
        )
        safe_l = jnp.where(l > 0, l, 1.0)
        out = acc / safe_l[..., None]            # (B, KV, G, cq, Dv)
        lse = m + jnp.log(safe_l)                # (B, KV, G, cq)
        return out.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    outs, lses = jax.lax.map(q_block, (q.transpose(1, 0, 2, 3, 4, 5), q_pos))
    # (nq, B, cq, KV, G, ...) -> (B, nq, cq, KV, G, ...)
    return outs.transpose(1, 0, 2, 3, 4, 5), lses.transpose(1, 0, 2, 3, 4)


def _blocked(q, k, v, cq, ck, kv, groups):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    nq = -(-sq // cq)
    nk = -(-sk // ck)
    q = jnp.pad(q, ((0, 0), (0, nq * cq - sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * ck - sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * ck - sk), (0, 0), (0, 0)))
    return (q.reshape(b, nq, cq, kv, groups, d),
            k.reshape(b, nk, ck, kv, d),
            v.reshape(b, nk, ck, kv, dv))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_attention_core(q, k, v, causal, q_offset, window, cq, ck):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    groups = h // kv
    qb, kb, vb = _blocked(q, k, v, cq, ck, kv, groups)
    out, _ = _flash_forward(qb, kb, vb, causal, q_offset, window, cq, ck,
                            k.shape[1])
    nq = qb.shape[1]
    dv = v.shape[-1]
    out = out.reshape(b, nq * cq, h, dv)[:, :sq]
    return out.astype(q.dtype)


def _core_fwd(q, k, v, causal, q_offset, window, cq, ck):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    groups = h // kv
    qb, kb, vb = _blocked(q, k, v, cq, ck, kv, groups)
    out, lse = _flash_forward(qb, kb, vb, causal, q_offset, window, cq, ck,
                              k.shape[1])
    nq = qb.shape[1]
    dv = v.shape[-1]
    out_flat = out.reshape(b, nq * cq, h, dv)[:, :sq].astype(q.dtype)
    return out_flat, (q, k, v, out, lse)


def _core_bwd(causal, q_offset, window, cq, ck, res, dout):
    """Flash-attention backward: recompute per-chunk probabilities, never
    store S_q x S_k.  Two sweeps: q-major for dq, kv-major for dk/dv."""
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    groups = h // kv
    dv_dim = v.shape[-1]
    scale = d ** -0.5
    qb, kb, vb = _blocked(q, k, v, cq, ck, kv, groups)
    nq, nk = qb.shape[1], kb.shape[1]
    dob = jnp.pad(dout.astype(jnp.float32),
                  ((0, 0), (0, nq * cq - sq), (0, 0), (0, 0)))
    dob = dob.reshape(b, nq, cq, kv, groups, dv_dim)
    # D_i = rowsum(dout * out)
    delta = jnp.sum(dob * out, axis=-1)          # (B, nq, cq, KV, G)
    q_pos = q_offset + jnp.arange(nq * cq).reshape(nq, cq)
    k_pos = jnp.arange(nk * ck).reshape(nk, ck)

    kc = kb.transpose(1, 0, 2, 3, 4)
    vc = vb.transpose(1, 0, 2, 3, 4)

    # ---- sweep 1: dq (q-major, scan kv chunks) ----
    def dq_block(args):
        qq, do_, dl_, ls_, qp = args

        def kv_step(dq_acc, blk):
            kk, vv, kp = blk
            s = jnp.einsum("bqkgd,bckd->bkgqc", qq, kk).astype(jnp.float32)
            s = s * scale
            mask = _mask_for(qp, kp, causal, window, sk)
            p = jnp.where(mask[None, None, None, :, :],
                          jnp.exp(s - ls_.transpose(0, 2, 3, 1)[..., None]),
                          0.0)
            dp = jnp.einsum("bqkge,bcke->bkgqc", do_, vv).astype(jnp.float32)
            ds = p * (dp - delta_t[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum(
                "bkgqc,bckd->bqkgd", ds.astype(kk.dtype), kk
            ).astype(jnp.float32)
            return dq_acc, None

        delta_t = dl_.transpose(0, 2, 3, 1)      # (B, KV, G, cq)
        dq0 = jnp.zeros_like(qq, jnp.float32)
        dq, _ = jax.lax.scan(kv_step, dq0, (kc, vc, k_pos))
        return dq

    dqs = jax.lax.map(
        dq_block,
        (qb.transpose(1, 0, 2, 3, 4, 5), dob.transpose(1, 0, 2, 3, 4, 5),
         delta.transpose(1, 0, 2, 3, 4), lse.transpose(1, 0, 2, 3, 4), q_pos))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * cq, h, d)[:, :sq]

    # ---- sweep 2: dk/dv (kv-major, scan q chunks) ----
    qc = qb.transpose(1, 0, 2, 3, 4, 5)
    doc = dob.transpose(1, 0, 2, 3, 4, 5)
    dlc = delta.transpose(1, 0, 2, 3, 4)
    lsc = lse.transpose(1, 0, 2, 3, 4)

    def dkv_block(args):
        kk, vv, kp = args

        def q_step(carry, blk):
            dk_acc, dv_acc = carry
            qq, do_, dl_, ls_, qp = blk
            s = jnp.einsum("bqkgd,bckd->bkgqc", qq, kk).astype(jnp.float32)
            s = s * scale
            mask = _mask_for(qp, kp, causal, window, sk)
            p = jnp.where(mask[None, None, None, :, :],
                          jnp.exp(s - ls_.transpose(0, 2, 3, 1)[..., None]),
                          0.0)
            dv_acc = dv_acc + jnp.einsum(
                "bkgqc,bqkge->bcke", p.astype(do_.dtype), do_
            ).astype(jnp.float32)
            dp = jnp.einsum("bqkge,bcke->bkgqc", do_, vv).astype(jnp.float32)
            ds = p * (dp - dl_.transpose(0, 2, 3, 1)[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bkgqc,bqkgd->bckd", ds.astype(qq.dtype), qq
            ).astype(jnp.float32)
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros_like(kk, jnp.float32)
        dv0 = jnp.zeros_like(vv, jnp.float32)
        (dk, dvv), _ = jax.lax.scan(q_step, (dk0, dv0),
                                    (qc, doc, dlc, lsc, q_pos))
        return dk, dvv

    dks, dvs = jax.lax.map(dkv_block, (kc, vc, k_pos))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, nk * ck, kv, d)[:, :sk]
    dvv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nk * ck, kv, dv_dim)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dvv.astype(v.dtype)


_chunked_attention_core.defvjp(_core_fwd, _core_bwd)


def _chunked_attention(q, k, v, *, causal, q_offset=0, window=0,
                       chunk_q=512, chunk_k=1024):
    """Online-softmax attention without materializing S_q x S_k.

    q/k: (B, Sq|Sk, H|KV, D); v: (B, Sk, KV, Dv) — Dv may differ from D
    (MLA).  ``q_offset`` is the absolute position of q[0] (prefill
    chunking / decode).  ``window`` > 0 applies a sliding-window causal
    mask.  Returns (B, Sq, H, Dv).

    Differentiable via a flash-style custom VJP (_core_bwd) that
    recomputes chunk probabilities instead of storing them — without it,
    autodiff through the online-softmax scan keeps every (cq x ck) score
    block alive and the train cells blow past HBM (EXPERIMENTS.md §Perf).
    """
    sq, sk = q.shape[1], k.shape[1]
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    return _chunked_attention_core(q, k, v, causal, q_offset, window, cq, ck)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding-window / cross) + decode
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d_model=None):
    d_model = d_model or cfg.d_model
    hd = cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    return {
        "wq": init_linear(kq, d_model, cfg.n_heads * hd, dt),
        "wk": init_linear(kk, d_model, cfg.n_kv_heads * hd, dt),
        "wv": init_linear(kv, d_model, cfg.n_kv_heads * hd, dt),
        "wo": init_linear(ko, cfg.n_heads * hd, d_model, dt,
                          scale=(cfg.n_heads * hd) ** -0.5),
    }


def attention(p, x, cfg: ModelConfig, *, positions=None, causal=True,
              window=0, kv_x=None, use_rope=True):
    """Self- (or cross-, via kv_x) attention over a full sequence."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    src = kv_x if kv_x is not None else x
    sk = src.shape[1]
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], src).reshape(b, sk, cfg.n_kv_heads, hd)
    v = linear(p["wv"], src).reshape(b, sk, cfg.n_kv_heads, hd)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = rope(q, pos, cfg.rope_theta)
        if kv_x is None:
            k = rope(k, pos, cfg.rope_theta)
    out = _chunked_attention(q, k, v, causal=causal and kv_x is None,
                             window=window)
    return linear(p["wo"], out.reshape(b, s, cfg.n_heads * hd))


def attention_decode(p, x, cache, pos, cfg: ModelConfig, *, window=0):
    """Single-token decode with an in-place KV cache update.

    cache: dict(k=(B, S_cache, KV, D), v=...).  For sliding-window
    attention the cache is a ring buffer of length ``window`` indexed by
    pos % window, bounding decode memory for the long_500k cell.
    """
    b, s1, _ = x.shape  # s1 == 1
    hd = cfg.head_dim
    q = linear(p["wq"], x).reshape(b, s1, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, s1, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s1, cfg.n_kv_heads, hd)
    posb = jnp.full((b, 1), pos)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)

    s_cache = cache["k"].shape[1]
    slot = pos % s_cache if window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))

    kv = cfg.n_kv_heads
    groups = cfg.n_heads // kv
    qg = q.reshape(b, kv, groups, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, ck) * hd ** -0.5
    # decode scores scale with the cache length; keep them batch-sharded
    scores = hints.constrain(scores.astype(jnp.float32),
                             "batch", None, None, None)
    idx = jnp.arange(s_cache)
    if window:
        # ring buffer holds the last min(pos+1, window) tokens; before the
        # first wrap only slots [0, pos] are populated, afterwards all are
        valid = jnp.where(pos + 1 >= s_cache,
                          jnp.ones((s_cache,), bool),
                          idx < jnp.minimum(pos + 1, s_cache))
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return linear(p["wo"], out), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    dt = cfg.jax_dtype
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    qk_nope, qk_rope, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    h = cfg.n_heads
    return {
        "wq_a": init_linear(ks[0], d, cfg.q_lora_rank, dt),
        "q_norm": init_rmsnorm(cfg.q_lora_rank, dt),
        "wq_b": init_linear(ks[1], cfg.q_lora_rank,
                            h * (qk_nope + qk_rope), dt),
        "wkv_a": init_linear(ks[2], d, cfg.kv_lora_rank + qk_rope, dt),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank, dt),
        "wkv_b": init_linear(ks[3], cfg.kv_lora_rank,
                             h * (qk_nope + v_hd), dt),
        "wo": init_linear(ks[4], h * v_hd, d, dt, scale=(h * v_hd) ** -0.5),
    }


def mla_attention(p, x, cfg: ModelConfig, *, positions=None):
    """Full-sequence MLA (train/prefill): expand latents, chunked attn."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    pos = positions if positions is not None else jnp.arange(s)[None, :]

    q = linear(p["wq_b"], rmsnorm(p["q_norm"], linear(p["wq_a"], x)))
    q = q.reshape(b, s, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)

    kv_a = linear(p["wkv_a"], x)
    c_kv, k_rope = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    k_rope = rope(k_rope.reshape(b, s, 1, rdim), pos, cfg.rope_theta)
    kv = linear(p["wkv_b"], rmsnorm(p["kv_norm"], c_kv))
    kv = kv.reshape(b, s, h, nope + vdim)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rdim))], axis=-1
    )
    out = _chunked_attention(q_full, k_full, v, causal=True)
    return linear(p["wo"], out.reshape(b, s, h * vdim))


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed-form MLA decode: cache is the compressed latent + rope key.

    cache: dict(c_kv=(B, S, kv_lora_rank), k_rope=(B, S, rope_dim)) — the
    entire reason MLA exists: ~9x smaller KV cache than GQA-128.
    """
    b = x.shape[0]
    h = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    posb = jnp.full((b, 1), pos)

    q = linear(p["wq_b"], rmsnorm(p["q_norm"], linear(p["wq_a"], x)))
    q = q.reshape(b, 1, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, posb, cfg.rope_theta)[:, 0]  # (B, H, rdim)

    kv_a = linear(p["wkv_a"], x)  # (B, 1, lr + rdim)
    c_kv_new = rmsnorm(p["kv_norm"], kv_a[..., :lr])
    k_rope_new = rope(kv_a[..., lr:].reshape(b, 1, 1, rdim), posb,
                      cfg.rope_theta).reshape(b, 1, rdim)

    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))

    # absorb W_uk into the query: score = q_nope W_uk . c_kv + q_rope . k_rope
    wkv_b = p["wkv_b"]["w"].reshape(lr, h, nope + vdim)
    w_uk = wkv_b[..., :nope]          # (lr, H, nope)
    w_uv = wkv_b[..., nope:]          # (lr, H, vdim)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0], w_uk)  # (B, H, lr)

    s_cache = c_cache.shape[1]
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_lat, c_cache)
        + jnp.einsum("bhr,bsr->bhs", q_rope, r_cache)
    ).astype(jnp.float32) * (nope + rdim) ** -0.5
    valid = jnp.arange(s_cache) <= pos
    scores = jnp.where(valid[None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
    o_lat = jnp.einsum("bhs,bsl->bhl", probs, c_cache)      # (B, H, lr)
    out = jnp.einsum("bhl,lhv->bhv", o_lat, w_uv)           # (B, H, vdim)
    out = out.reshape(b, 1, h * vdim)
    return linear(p["wo"], out), {"c_kv": c_cache, "k_rope": r_cache}
