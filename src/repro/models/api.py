"""Public model API: build models, steps, and dry-run input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the corresponding step — weak-type-correct, shardable, zero
allocation — which is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.transformer import Model
from repro.optim import adamw, apply_updates

PyTree = Any


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy; logits fp32 (B, S, V).

    The label logit is extracted with a masked reduction instead of
    take_along_axis: a gather over the model-axis-sharded vocab dim
    would force GSPMD to all-gather the full (B,S,V) logits (measured
    31 GiB/device on llama3.2-1b/train_4k — EXPERIMENTS.md §Perf); the
    masked sum reduces shard-locally and all-reduces only (B,S) scalars.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    onehot = labels[..., None] == vocab_iota
    true_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ll = true_logit - lse
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(model: Model, optimizer=None):
    optimizer = optimizer or adamw()

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits = model.forward(p, batch["tokens"],
                                   ctx_embeds=batch.get("ctx"))
            return cross_entropy(logits, batch["labels"], batch.get("mask"))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return train_step, optimizer


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.forward(params, batch["tokens"],
                             ctx_embeds=batch.get("ctx"))

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(
            params, cache, batch["tokens"], batch["pos"],
            ctx_embeds=batch.get("ctx"))
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# dry-run stand-ins
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _ctx_spec(cfg: ModelConfig, batch: int):
    if cfg.kind == "vlm":
        return _sds((batch, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    if cfg.kind == "encdec":
        return _sds((batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the step inputs of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.step_kind == "train":
        batch = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    elif shape.step_kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
    else:  # decode: one new token against a seq_len-deep cache
        batch = {
            "tokens": _sds((b, 1), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    ctx = _ctx_spec(cfg, b)
    if ctx is not None:
        batch["ctx"] = ctx
    return batch


def param_specs(model: Model, seed: int = 0) -> PyTree:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(model.init, jax.random.PRNGKey(seed))


def cache_specs(model: Model, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(
        functools.partial(model.init_cache, batch, max_len))


def opt_state_specs(model: Model, optimizer) -> PyTree:
    params = param_specs(model)
    return jax.eval_shape(optimizer.init, params)


def count_params(specs: PyTree) -> int:
    import numpy as np

    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(specs)))
