"""Mixture-of-experts FFN with sort-based capacity dispatch.

Token-choice top-k routing (softmax over expert logits), GShard-style
fixed capacity per expert, dispatch via argsort + scatter into an
(E, C, d) buffer, batched expert GEMMs (``ecd,edf->ecf``), and weighted
un-permute.  This formulation is pure XLA (no shard_map), so GSPMD can
shard it two ways (sharding/partition.py picks per arch):

  * expert-parallel:  expert dim E on the "model" mesh axis when E is
    divisible by it (deepseek-v3: 256 experts / 16 = 16 per device);
  * tensor-parallel:  per-expert hidden dim d_ff on "model" when E is
    small (mixtral: 8 experts, d_ff 14336 = 16 x 896).

Tokens overflowing an expert's capacity are dropped (contribute zero),
standard GShard semantics; tests check the no-drop regime against a
dense reference.  DeepSeek's shared experts are dense SwiGLU branches
added unconditionally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import hints


def init_moe(key, cfg: ModelConfig):
    dt = cfg.jax_dtype
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) * d**-0.5)
                   .astype(jnp.float32)},
        "wg": (jax.random.normal(ks[1], (e, d, f)) * d**-0.5).astype(dt),
        "wu": (jax.random.normal(ks[2], (e, d, f)) * d**-0.5).astype(dt),
        "wd": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        params["shared"] = L.init_swiglu(
            ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts, dt)
    return params


def _dispatch_group(xg, top_p, top_e, p, cfg):
    """Shard-local dispatch + expert GEMMs for one routing group.

    xg: (Tl, d); top_p/top_e: (Tl, k).  Returns (Tl, d) fp32.
    """
    tl, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * tl * k / e) or 1

    flat_e = top_e.reshape(-1)                          # (Tl*k,)
    order = jnp.argsort(flat_e)                         # stable
    sorted_e = flat_e[order]
    # rank of each sorted slot within its expert
    same = jnp.cumsum(jnp.ones_like(sorted_e)) - 1
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = same - seg_start[sorted_e]
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, e * cap)  # drop bucket

    tok_idx = order // k                                 # source token
    buf = jnp.zeros((e * cap + 1, d), cfg.jax_dtype)
    buf = buf.at[dest].set(xg[tok_idx].astype(cfg.jax_dtype))
    buf = buf[: e * cap].reshape(e, cap, d)

    # --- expert compute: batched GEMMs ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"])          # (E, C, d)

    # --- un-permute with routing weights ---
    y_flat = jnp.concatenate(
        [y.reshape(e * cap, d), jnp.zeros((1, d), y.dtype)], axis=0
    )
    slot_y = y_flat[jnp.where(keep, dest, e * cap)]     # (Tl*k, d)
    w_slot = top_p.reshape(-1)[order] * keep            # dropped -> 0
    contrib = slot_y * w_slot[:, None].astype(y.dtype)
    return jnp.zeros((tl, d), jnp.float32).at[tok_idx].add(
        contrib.astype(jnp.float32))


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d).

    Routing is computed per *data shard group* (hints.data_shard_count):
    a single global argsort/scatter cannot be partitioned by GSPMD and
    replicates every dispatch buffer (122 GiB/dev on mixtral/prefill_32k
    before this change — EXPERIMENTS.md §Perf).  With G groups vmapped
    over the data axis, dispatch is shard-local (capacity per group) and
    the expert GEMMs carry E over the model axis (EP) or d_ff over it
    (TP) per the arch's divisibility.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = hints.data_shard_count()
    if t % g:
        g = 1
    xf = x.reshape(t, d)

    # --- route (always fp32: routing is precision-sensitive) ---
    logits = (xf.astype(jnp.float32) @ p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)              # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    xg = hints.constrain(xf.reshape(g, t // g, d), "batch", None, None)
    tp = hints.constrain(top_p.reshape(g, t // g, k), "batch", None, None)
    te = hints.constrain(top_e.reshape(g, t // g, k), "batch", None, None)
    out = jax.vmap(lambda a, bb, c: _dispatch_group(a, bb, c, p, cfg))(
        xg, tp, te)
    out = hints.constrain(out, "batch", None, None).reshape(t, d)

    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], xf).astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype)


def moe_ffn_dense_reference(p, x, cfg: ModelConfig):
    """Oracle: compute every expert on every token (no capacity, no drops)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], top_e
    ].set(top_p)                                         # (T, E)

    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wg"]))
    h = h * jnp.einsum("td,edf->tef", xf, p["wu"])
    y = jnp.einsum("tef,efd->ted", h, p["wd"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gates)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], xf).astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype)
