"""Mamba-2 (SSD, state-space duality) mixer — train scan + O(1) decode.

Chunked SSD algorithm (arXiv:2405.21060 §6): the sequence is split into
chunks of length L; within a chunk the recurrence is computed as a
masked attention-like quadratic form, across chunks a (cheap) scan
carries the (H, P, N) state.  Decode is the pure recurrence: constant
memory and compute per token, which is what makes the long_500k cell
feasible for the ssm/hybrid archs (DESIGN.md §long-context).

The layer carries its own causal depthwise conv (width ssm_conv) over
the x/B/C streams as in the reference implementation; its rolling state
is part of the decode cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import hints


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    h = cfg.ssm_nheads
    p = cfg.ssm_headdim
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    return d_in, h, p, g, n


def init_ssm(key, cfg: ModelConfig):
    dt = cfg.jax_dtype
    d_in, h, p, g, n = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_in + 2 * g * n
    ks = jax.random.split(key, 6)
    return {
        # projects to [z, x, B, C, dt]
        "in_proj": L.init_linear(
            ks[0], d, 2 * d_in + 2 * g * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim))
                   * cfg.ssm_conv**-0.5).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": L.init_rmsnorm(d_in, dt),
        "out_proj": L.init_linear(ks[2], d_in, d, dt, scale=d_in**-0.5),
    }


def _split_proj(p, x, cfg):
    d_in, h, _, g, n = _dims(cfg)
    zxbcdt = L.linear(p["in_proj"], x)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * g * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * g * n :]
    return z, xbc, dt_raw


def _causal_conv(p, xbc):
    """Depthwise causal conv over the sequence axis.  xbc: (B, S, C)."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i]
        for i in range(k)
    )
    return jax.nn.silu(out + p["conv_b"])


def _segsum(a):
    """Stable lower-triangular cumulative-sum matrix of log-decays.

    a: (..., L) log decay per step.  Returns (..., L, L) with
    out[i, j] = sum_{k=j+1..i} a_k for j <= i, -inf above diagonal.
    """
    l = a.shape[-1]
    cums = jnp.cumsum(a, axis=-1)
    diff = cums[..., :, None] - cums[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, a_log, b, c, d_skip, chunk=128):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); b,c: (B,S,G,N).

    Returns y: (B, S, H, P).  fp32 state math throughout.
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    reps = h // g
    l = min(chunk, s)
    nc = -(-s // l)
    pad = nc * l - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, l, h).astype(jnp.float32)
    bc = b.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    # broadcast kv groups over heads
    bh = jnp.repeat(bc, reps, axis=3)  # (B, nc, L, H, N)
    ch = jnp.repeat(cc, reps, axis=3)

    alog = dtc * a_log[None, None, None, :] * -1.0  # A negative: decay
    # within-chunk decay matrix (B, nc, H, L, L) — the SSD memory hot
    # spot; shard heads over the model axis (45 GiB/dev replicated
    # otherwise, EXPERIMENTS.md §Perf)
    seg = _segsum(alog.transpose(0, 1, 3, 2))
    decay = hints.constrain(jnp.exp(seg), "batch", None, "model", None, None)

    # intra-chunk (quadratic, attention-like)
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh)
    scores = hints.constrain(scores, "batch", None, "model", None, None)
    m = scores * decay
    y_intra = jnp.einsum("bchls,bcsh,bcshp->bclhp", m, dtc, xc)
    y_intra = hints.constrain(y_intra, "batch", None, None, "model", None)

    # chunk-final states: (B, nc, H, N, P)
    decay_to_end = jnp.exp(
        jnp.cumsum(alog, axis=2)[:, :, -1:, :] - jnp.cumsum(alog, axis=2)
    )  # (B, nc, L, H)
    states = jnp.einsum(
        "bclhn,bclh,bclh,bclhp->bchnp", bh, decay_to_end, dtc, xc
    )

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(jnp.sum(alog, axis=2))  # (B, nc, H)

    def scan_fn(h_prev, inp):
        st, dec = inp  # (B, H, N, P), (B, H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_before = h_before.transpose(1, 0, 2, 3, 4)  # (B, nc, H, N, P)

    # inter-chunk contribution
    decay_from_start = jnp.exp(jnp.cumsum(alog, axis=2))  # (B, nc, L, H)
    y_inter = jnp.einsum(
        "bclhn,bclh,bchnp->bclhp", ch, decay_from_start, h_before
    )

    y = y_intra + y_inter + xc * d_skip[None, None, None, :, None]
    y = y.reshape(bsz, nc * l, h, p)[:, :s]
    return y


def ssm_forward(p, x, cfg: ModelConfig):
    """Full-sequence Mamba-2 mixer.  x: (B, S, d_model)."""
    d_in, h, hp, g, n = _dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc = _causal_conv(p, xbc)
    xs = xbc[..., :d_in]
    b = xbc[..., d_in : d_in + g * n]
    c = xbc[..., d_in + g * n :]
    bsz, s, _ = x.shape
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a_log = jnp.exp(p["a_log"])
    y = ssd_scan(
        xs.reshape(bsz, s, h, hp),
        dt,
        a_log,
        b.reshape(bsz, s, g, n),
        c.reshape(bsz, s, g, n),
        p["d_skip"],
    )
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.linear(p["out_proj"], y)


def init_ssm_cache(cfg: ModelConfig, batch, dtype):
    d_in, h, p, g, n = _dims(cfg)
    conv_dim = d_in + 2 * g * n
    return {
        "h": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """One-token recurrent update.  x: (B, 1, d_model)."""
    d_in, h, hp, g, n = _dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)

    # rolling conv state
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                           axis=1)  # (B, k, C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:]

    xs = conv_out[..., :d_in]
    b = conv_out[..., d_in : d_in + g * n]
    c = conv_out[..., d_in + g * n :]
    bsz = x.shape[0]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt)      # (B, H)
    xh = xs.reshape(bsz, h, hp).astype(jnp.float32)
    bh = jnp.repeat(b.reshape(bsz, g, n), h // g, axis=1)  # (B, H, N)
    ch = jnp.repeat(c.reshape(bsz, g, n), h // g, axis=1)

    h_new = (cache["h"] * a[..., None, None]
             + jnp.einsum("bh,bhn,bhp->bhnp", dt, bh, xh))
    y = jnp.einsum("bhn,bhnp->bhp", ch, h_new) + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.linear(p["out_proj"], y), {"h": h_new, "conv": new_conv}
