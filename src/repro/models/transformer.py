"""Model assembly for all assigned architectures.

One ``Model`` facade with three entry points:

  * ``forward(params, batch)``            — full-sequence logits (train/prefill)
  * ``init_cache(batch_size, max_len)``   — decode cache pytree (ShapeDtype-
                                            compatible, so the dry-run can
                                            build it without allocation)
  * ``decode_step(params, cache, tok, pos)`` — one-token serve step

Layer stacks are scanned (params stacked on a leading layer axis) so HLO
size stays O(1 layer) even for deepseek's 61 layers at 512 devices —
critical for dry-run compile times.  Heterogeneous stacks (deepseek's
first-k-dense, the VLM's every-5th-cross-attn) are expressed as scans
over homogeneous groups.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.sharding import hints

PyTree = Any


# ---------------------------------------------------------------------------
# per-layer blocks: init + forward + decode
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str):
    dt = cfg.jax_dtype
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": L.init_rmsnorm(cfg.d_model, dt)}
    if kind in ("dense", "moe", "vlm_self"):
        p["attn"] = (L.init_mla(ks[0], cfg) if cfg.use_mla
                     else L.init_attention(ks[0], cfg))
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        if kind == "moe":
            p["ffn"] = M.init_moe(ks[1], cfg)
        else:
            p["ffn"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    elif kind == "ssm":
        p["mixer"] = SSM.init_ssm(ks[0], cfg)
    elif kind == "hybrid":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["mixer"] = SSM.init_ssm(ks[1], cfg)
        p["attn_norm"] = L.init_rmsnorm(cfg.d_model, dt)
        p["mixer_norm"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ffn"] = L.init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dt)
    elif kind == "cross":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ffn"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    elif kind == "enc":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ffn"] = L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    elif kind == "encdec_dec":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["xattn"] = L.init_attention(ks[1], cfg)
        p["ln_x"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ffn"] = L.init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dt)
    else:
        raise ValueError(kind)
    return p


def _block_forward(p, x, cfg: ModelConfig, kind: str, *, ctx=None):
    """Full-sequence block.  ctx = encoder output / image tokens for cross."""
    if kind in ("dense", "moe", "vlm_self"):
        h = L.rmsnorm(p["ln1"], x)
        if cfg.use_mla:
            a = L.mla_attention(p["attn"], h, cfg)
        else:
            a = L.attention(p["attn"], h, cfg, window=cfg.sliding_window)
        x = x + a
        h = L.rmsnorm(p["ln2"], x)
        f = (M.moe_ffn(p["ffn"], h, cfg) if kind == "moe"
             else L.swiglu(p["ffn"], h))
        return x + f
    if kind == "ssm":
        return x + SSM.ssm_forward(p["mixer"], L.rmsnorm(p["ln1"], x), cfg)
    if kind == "hybrid":
        h = L.rmsnorm(p["ln1"], x)
        a = L.attention(p["attn"], h, cfg, window=cfg.sliding_window)
        s = SSM.ssm_forward(p["mixer"], h, cfg)
        mixed = 0.5 * (L.rmsnorm(p["attn_norm"], a)
                       + L.rmsnorm(p["mixer_norm"], s))
        x = x + mixed
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x))
    if kind == "cross":
        h = L.rmsnorm(p["ln1"], x)
        a = L.attention(p["attn"], h, cfg, kv_x=ctx, causal=False,
                        use_rope=False)
        x = x + a
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x))
    if kind == "enc":
        h = L.rmsnorm(p["ln1"], x)
        a = L.attention(p["attn"], h, cfg, causal=False, use_rope=False)
        x = x + a
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x))
    if kind == "encdec_dec":
        h = L.rmsnorm(p["ln1"], x)
        x = x + L.attention(p["attn"], h, cfg)
        h = L.rmsnorm(p["ln_x"], x)
        x = x + L.attention(p["xattn"], h, cfg, kv_x=ctx, causal=False,
                            use_rope=False)
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x))
    raise ValueError(kind)


def _block_decode(p, x, cache, pos, cfg: ModelConfig, kind: str, *, ctx=None):
    """One-token block step; returns (x, new_cache)."""
    if kind in ("dense", "moe", "vlm_self"):
        h = L.rmsnorm(p["ln1"], x)
        if cfg.use_mla:
            a, cache_a = L.mla_decode(p["attn"], h, cache["attn"], pos, cfg)
        else:
            a, cache_a = L.attention_decode(p["attn"], h, cache["attn"], pos,
                                            cfg, window=cfg.sliding_window)
        x = x + a
        h = L.rmsnorm(p["ln2"], x)
        f = (M.moe_ffn(p["ffn"], h, cfg) if kind == "moe"
             else L.swiglu(p["ffn"], h))
        return x + f, {"attn": cache_a}
    if kind == "ssm":
        y, c = SSM.ssm_decode(p["mixer"], L.rmsnorm(p["ln1"], x), cache["ssm"],
                              cfg)
        return x + y, {"ssm": c}
    if kind == "hybrid":
        h = L.rmsnorm(p["ln1"], x)
        a, cache_a = L.attention_decode(p["attn"], h, cache["attn"], pos, cfg,
                                        window=cfg.sliding_window)
        s, cache_s = SSM.ssm_decode(p["mixer"], h, cache["ssm"], cfg)
        mixed = 0.5 * (L.rmsnorm(p["attn_norm"], a)
                       + L.rmsnorm(p["mixer_norm"], s))
        x = x + mixed
        x = x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x))
        return x, {"attn": cache_a, "ssm": cache_s}
    if kind == "cross":
        h = L.rmsnorm(p["ln1"], x)
        a = L.attention(p["attn"], h, cfg, kv_x=ctx, causal=False,
                        use_rope=False)
        x = x + a
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x)), {}
    if kind == "encdec_dec":
        h = L.rmsnorm(p["ln1"], x)
        a, cache_a = L.attention_decode(p["attn"], h, cache["attn"], pos, cfg)
        x = x + a
        h = L.rmsnorm(p["ln_x"], x)
        x = x + L.attention(p["xattn"], h, cfg, kv_x=ctx, causal=False,
                            use_rope=False)
        return x + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], x)), {"attn": cache_a}
    raise ValueError(kind)


def _init_block_cache(cfg: ModelConfig, kind: str, batch, max_len, dtype):
    if kind in ("dense", "moe", "vlm_self", "encdec_dec"):
        if cfg.use_mla:
            attn = {
                "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                    dtype),
            }
        else:
            s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            attn = {
                "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
            }
        return {"attn": attn}
    if kind == "ssm":
        return {"ssm": SSM.init_ssm_cache(cfg, batch, dtype)}
    if kind == "hybrid":
        s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        return {
            "attn": {
                "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
            },
            "ssm": SSM.init_ssm_cache(cfg, batch, dtype),
        }
    if kind == "cross":
        return {}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacks: scan over layer groups
# ---------------------------------------------------------------------------

def _stack_init(key, cfg, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _stack_forward(params, x, cfg, kind, *, ctx=None):
    # pin the residual stream to (batch, seq)-sharding at layer
    # boundaries: (1) the per-layer scan saves otherwise pick a
    # batch-replicated layout that poisons the backward matmuls; (2) the
    # seq dim over the model axis is Megatron-style sequence parallelism
    # — layer-boundary ops are per-token, so the saves shrink by the TP
    # width and GSPMD inserts the gathers only inside attention
    # (EXPERIMENTS.md §Perf).
    body = _maybe_remat(
        lambda x_, p_: (hints.constrain(
            _block_forward(p_, x_, cfg, kind, ctx=ctx),
            "batch", "seq", None), None),
        cfg)

    def scan_body(x_, p_):
        return body(x_, p_)

    x, _ = jax.lax.scan(scan_body, x, params)
    return x


def _stack_decode(params, caches, x, pos, cfg, kind, *, ctx=None):
    def scan_body(x_, pc):
        p_, c_ = pc
        x_, c_new = _block_decode(p_, x_, c_, pos, cfg, kind, ctx=ctx)
        return x_, c_new

    x, new_caches = jax.lax.scan(scan_body, x, (params, caches))
    return x, new_caches


def _stack_cache(cfg, kind, n, batch, max_len, dtype):
    one = _init_block_cache(cfg, kind, batch, max_len, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
                        if n else a, one)


# ---------------------------------------------------------------------------
# the Model facade
# ---------------------------------------------------------------------------

class Model:
    """Architecture-dispatching model: build via ``Model(config)``."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = self._layer_groups(cfg)

    @staticmethod
    def _layer_groups(cfg) -> list[tuple[str, int]]:
        """[(block_kind, n_layers), ...] in execution order."""
        if cfg.kind == "dense":
            return [("dense", cfg.n_layers)]
        if cfg.kind == "moe":
            groups = []
            if cfg.first_dense_layers:
                groups.append(("dense", cfg.first_dense_layers))
            groups.append(("moe", cfg.n_layers - cfg.first_dense_layers))
            return groups
        if cfg.kind == "ssm":
            return [("ssm", cfg.n_layers)]
        if cfg.kind == "hybrid":
            return [("hybrid", cfg.n_layers)]
        if cfg.kind == "vlm":
            # pattern: (cross_attn_every - 1) self layers then 1 cross layer
            n_groups = cfg.n_layers // cfg.cross_attn_every
            return [("vlm_group", n_groups)]
        if cfg.kind == "encdec":
            return [("enc", cfg.n_encoder_layers),
                    ("encdec_dec", cfg.n_layers)]
        raise ValueError(cfg.kind)

    # -- init ---------------------------------------------------------------

    def init(self, key) -> PyTree:
        cfg = self.cfg
        dt = cfg.jax_dtype
        k_embed, k_head, k_meta, *k_groups = jax.random.split(
            key, 3 + len(self.groups))
        params: dict = {
            "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model))
                      * cfg.d_model**-0.5).astype(dt),
            "final_norm": L.init_rmsnorm(cfg.d_model, dt),
            "lm_head": L.init_linear(k_head, cfg.d_model, cfg.vocab, dt),
        }
        if cfg.meta_tokens:
            params["meta"] = (jax.random.normal(
                k_meta, (cfg.meta_tokens, cfg.d_model)) * 0.02).astype(dt)
        for (kind, n), kg in zip(self.groups, k_groups):
            if kind == "vlm_group":
                k1, k2 = jax.random.split(kg)
                params["stack_vlm_self"] = _stack_init_nested(
                    k1, cfg, "vlm_self", n, cfg.cross_attn_every - 1)
                params["stack_vlm_cross"] = _stack_init(k2, cfg, "cross", n)
            else:
                params[f"stack_{kind}"] = _stack_init(kg, cfg, kind, n)
        return params

    # -- full-sequence forward (train / prefill) -----------------------------

    def forward(self, params, tokens, *, ctx_embeds=None) -> jnp.ndarray:
        """tokens: (B, S) int32.  ctx_embeds: stub modality context
        (image patches / audio frames), (B, T_ctx, d_model)."""
        cfg = self.cfg
        x = hints.constrain(params["embed"][tokens], "batch", None, None)
        if cfg.meta_tokens:
            meta = jnp.broadcast_to(
                params["meta"], (x.shape[0],) + params["meta"].shape)
            x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)

        if cfg.kind == "encdec":
            enc = ctx_embeds.astype(x.dtype)
            enc = _stack_forward(params["stack_enc"], enc, cfg, "enc")
            x = _stack_forward(params["stack_encdec_dec"], x, cfg,
                               "encdec_dec", ctx=enc)
        elif cfg.kind == "vlm":
            x = _vlm_forward(params, x, cfg, ctx_embeds.astype(x.dtype))
        else:
            for kind, _ in self.groups:
                x = _stack_forward(params[f"stack_{kind}"], x, cfg, kind)

        if cfg.meta_tokens:
            x = x[:, cfg.meta_tokens:]
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.linear(params["lm_head"], x)
        # the (B, S, V) logits dwarf everything else; shard V over the
        # model axis (sharding/hints.py) before the fp32 upcast
        logits = hints.constrain(logits, "batch", None, "model")
        return logits.astype(jnp.float32)

    # -- decode -------------------------------------------------------------

    def init_cache(self, batch, max_len, dtype=None) -> PyTree:
        cfg = self.cfg
        dt = dtype or cfg.jax_dtype
        caches = {}
        for kind, n in self.groups:
            if kind == "enc":
                continue  # encoder is prefill-only context
            if kind == "vlm_group":
                caches["stack_vlm_self"] = jax.tree.map(
                    lambda a: a,  # nested (G, K) stack
                    _stack_cache_nested(cfg, "vlm_self", n,
                                        cfg.cross_attn_every - 1, batch,
                                        max_len, dt))
            else:
                caches[f"stack_{kind}"] = _stack_cache(cfg, kind, n, batch,
                                                       max_len, dt)
        return caches

    def decode_step(self, params, cache, tokens, pos, *, ctx_embeds=None):
        """tokens: (B, 1) int32; pos: scalar int32 absolute position."""
        cfg = self.cfg
        x = params["embed"][tokens]
        new_cache = {}
        if cfg.kind == "encdec":
            enc = ctx_embeds.astype(x.dtype)
            enc = _stack_forward(params["stack_enc"], enc, cfg, "enc")
            x, c = _stack_decode(params["stack_encdec_dec"],
                                 cache["stack_encdec_dec"], x, pos, cfg,
                                 "encdec_dec", ctx=enc)
            new_cache["stack_encdec_dec"] = c
        elif cfg.kind == "vlm":
            x, c = _vlm_decode(params, cache["stack_vlm_self"], x, pos, cfg,
                               ctx_embeds.astype(x.dtype))
            new_cache["stack_vlm_self"] = c
        else:
            for kind, _ in self.groups:
                x, c = _stack_decode(params[f"stack_{kind}"],
                                     cache[f"stack_{kind}"], x, pos, cfg, kind)
                new_cache[f"stack_{kind}"] = c
        x = L.rmsnorm(params["final_norm"], x)
        logits = hints.constrain(L.linear(params["lm_head"], x),
                                 "batch", None, "model")
        return logits.astype(jnp.float32), new_cache


# ---------------------------------------------------------------------------
# VLM pattern: scan over groups of (K self layers + 1 cross layer)
# ---------------------------------------------------------------------------

def _stack_init_nested(key, cfg, kind, n_groups, per_group):
    keys = jax.random.split(key, n_groups * per_group).reshape(
        n_groups, per_group, 2)
    return jax.vmap(jax.vmap(lambda k: _init_block(k, cfg, kind)))(keys)


def _stack_cache_nested(cfg, kind, n_groups, per_group, batch, max_len, dt):
    one = _init_block_cache(cfg, kind, batch, max_len, dt)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups, per_group) + a.shape).copy(),
        one)


def _vlm_forward(params, x, cfg, img):
    self_p = params["stack_vlm_self"]
    cross_p = params["stack_vlm_cross"]
    body_self = _maybe_remat(
        lambda x_, p_: (_block_forward(p_, x_, cfg, "vlm_self"), None), cfg)
    body_cross = _maybe_remat(
        lambda x_, p_: (_block_forward(p_, x_, cfg, "cross", ctx=img), None),
        cfg)

    def group(x_, ps):
        sp, cp = ps
        x_, _ = jax.lax.scan(lambda xx, pp: body_self(xx, pp), x_, sp)
        x_, _ = body_cross(x_, cp)
        return x_, None

    x, _ = jax.lax.scan(group, x, (self_p, cross_p))
    return x


def _vlm_decode(params, cache, x, pos, cfg, img):
    self_p = params["stack_vlm_self"]
    cross_p = params["stack_vlm_cross"]

    def group(x_, pcs):
        sp, cp, cc = pcs

        def inner(xx, pc):
            p_, c_ = pc
            xx, c_new = _block_decode(p_, xx, c_, pos, cfg, "vlm_self")
            return xx, c_new

        x_, c_new = jax.lax.scan(inner, x_, (sp, cc))
        x_, _ = _block_decode(cp, x_, {}, pos, cfg, "cross", ctx=img)
        return x_, c_new

    x, new_cache = jax.lax.scan(group, x, (self_p, cross_p, cache))
    return x, new_cache
