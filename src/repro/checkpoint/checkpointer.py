"""Fault-tolerant checkpointing: atomic, versioned, keep-k.

Serializes arbitrary pytrees (params, optimizer state, data-pipeline
state, MC simulation state) to one .npz per checkpoint plus a JSON
manifest.  Writes go to a temp name + atomic rename, so a crash
mid-write can never corrupt the latest checkpoint; ``restore()`` always
loads the newest complete one.  On a real cluster each process saves
its address-space shard under its process index (``process_suffix``) —
here single-process saves the whole tree.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten_to_arrays(tree: PyTree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,  # reprolint: disable=REP301 - dtype allowlist, not a cast
                             np.uint32, np.uint64, np.int8, np.uint8,
                             np.int16, np.uint16, np.bool_, np.float16):
            arr = arr.astype(np.float32)  # bf16 etc.: no native npz dtype
        out[key] = arr
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 process_suffix: str = ""):
        self.dir = directory
        self.keep = keep
        self.suffix = process_suffix
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}{self.suffix}.npz")

    def save(self, step: int, tree: PyTree, extra: dict | None = None):
        arrays = _flatten_to_arrays(tree)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        os.close(fd)
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, self._path(step))  # atomic
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "extra": extra or {},
        }
        mtmp = self._path(step) + ".manifest.tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, self._path(step) + ".manifest.json")
        self._gc()

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            m = _STEP_RE.search(fn)
            if m and os.path.exists(os.path.join(self.dir, fn)):
                # only count checkpoints whose manifest landed (complete)
                if os.path.exists(os.path.join(self.dir, fn)
                                  + ".manifest.json"):
                    out.append(int(m.group(1)))
        return sorted(set(out))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int | None = None) -> dict:
        """Read one checkpoint's manifest (``step``/``keys``/``extra``)
        without loading the arrays — the resilience layer stamps its
        merge counters into ``extra`` so a restarting campaign (or an
        operator) can inspect progress cheaply."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(self._path(step) + ".manifest.json") as f:
            return json.load(f)

    def restore(self, template: PyTree, step: int | None = None
                ) -> tuple[int, PyTree]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        data = np.load(self._path(step), allow_pickle=False)
        flat = jax.tree_util.tree_flatten_with_path(template)
        paths, treedef = flat[0], flat[1]
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            for ext in ("", ".manifest.json"):
                p = self._path(s) + ext
                if os.path.exists(p):
                    os.unlink(p)
