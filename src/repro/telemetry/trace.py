"""Host-side span recording + Chrome trace export (DESIGN.md
§observability).

The paper's heterogeneous-execution result rests on *measuring*
per-device throughput and feeding it back into work assignment
(Sec. 2.4); cross-vendor portability studies (PAPERS.md) likewise lean
on per-kernel event timing.  This module gives the schedulers that
instrument: a :class:`Tracer` wraps every chunk / batch dispatch in a
monotonic-clock span tagged with device, engine and photon count, and
the recorded timeline exports as Chrome ``trace_event`` JSON
(chrome://tracing, Perfetto) or streams to any
:class:`repro.telemetry.MetricsSink`.

The span records double as *measured throughput samples*:
:func:`fit_device_models` turns a recorded (or re-loaded) timeline into
per-device ``loadbalance.DeviceModel`` fits — chunks of two or more
distinct sizes give the paper's full ``T = a*n + T0`` pilot fit via
``fit_pilot``; equal-size chunks fall back to a throughput-only model
(``t0 = 0``).  That closes the loop the ROADMAP's "true heterogeneous
execution" item is blocked on: dispatch, measure, refit, re-partition.

For real profiler runs, ``Tracer(profiler=True)`` additionally brackets
every span in a ``jax.profiler.TraceAnnotation`` so the host-side spans
line up with XLA's device timeline in TensorBoard/Perfetto captures.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Sequence

from repro.telemetry.sinks import MetricsSink


def device_label(device) -> str:
    """Stable string id of a jax.Device (or pass a string through)."""
    if device is None:
        return "host"
    if isinstance(device, str):
        return device
    return f"{device.platform}:{device.id}"


@dataclasses.dataclass
class SpanEvent:
    """One completed span on the host timeline."""

    name: str
    device: str               # device_label() string
    t0: float                 # monotonic start, seconds
    dur: float                # duration, seconds
    engine: str | None = None
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def photons_per_s(self) -> float | None:
        n = self.args.get("photons", self.args.get("records"))
        if n is None or self.dur <= 0:
            return None
        return float(n) / self.dur

    def to_dict(self) -> dict:
        out = {"type": "span", "name": self.name, "device": self.device,
               "t0": self.t0, "dur_s": self.dur, "engine": self.engine,
               **self.args}
        pps = self.photons_per_s
        if pps is not None:
            out["photons_per_s"] = pps
        return out


class _Span:
    """Open span handle; ``end()`` (or exiting the ``with`` block) seals
    it into the tracer's event list and sinks."""

    def __init__(self, tracer: "Tracer", name: str, device: str,
                 engine: str | None, args: dict):
        self._tracer = tracer
        self.event = SpanEvent(name=name, device=device, t0=0.0, dur=0.0,
                               engine=engine, args=args)
        self._annotation = None
        if tracer.profiler:
            try:
                from jax.profiler import TraceAnnotation

                self._annotation = TraceAnnotation(
                    f"{name}[{device}]")
                self._annotation.__enter__()
            except Exception:  # pragma: no cover - profiler unavailable
                self._annotation = None
        self.event.t0 = time.monotonic()  # reprolint: disable=REP201 - span timing is this module's job

    def end(self, **extra_args) -> SpanEvent:
        self.event.dur = time.monotonic() - self.event.t0  # reprolint: disable=REP201 - span timing is this module's job
        if self._annotation is not None:
            self._annotation.__exit__(None, None, None)
            self._annotation = None
        self.event.args.update(extra_args)
        self._tracer._record(self.event)
        return self.event

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is None:
            self.end()
        elif self._annotation is not None:  # pragma: no cover - error path
            self._annotation.__exit__(None, None, None)
            self._annotation = None


class Tracer:
    """Collect host-side spans + counters; fan out to metrics sinks.

    ``span(...)`` returns an open handle for explicit ``begin``/``end``
    bracketing of async dispatches (begin at dispatch, end when the
    result array is ready); it is also a context manager for the
    synchronous case.  All completed events are kept in ``events`` (for
    in-process consumers like :func:`fit_device_models`) and forwarded
    to every sink as flat dicts.
    """

    def __init__(self, sinks: Sequence[MetricsSink] = (),
                 profiler: bool = False):
        self.sinks = list(sinks)
        self.profiler = bool(profiler)
        self.events: list[SpanEvent] = []

    # -- spans -------------------------------------------------------------

    def span(self, name: str, device=None, engine: str | None = None,
             **args) -> _Span:
        return _Span(self, name, device_label(device), engine, dict(args))

    def _record(self, event: SpanEvent) -> None:
        self.events.append(event)
        self._emit(event.to_dict())

    # -- scalar metrics ----------------------------------------------------

    def counter(self, name: str, value, **labels) -> None:
        """Emit one scalar sample (run summaries, RoundStats fields)."""
        self._emit({"type": "counter", "name": name,
                    "value": value, **labels})

    def _emit(self, record: dict) -> None:
        for sink in self.sinks:
            sink.emit(record)

    # -- chrome trace export ----------------------------------------------

    def chrome_trace(self) -> dict:
        return chrome_trace(self.events)

    def save_chrome_trace(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.chrome_trace(), indent=1) + "\n")
        return path


# ---------------------------------------------------------------------------
# Chrome trace_event JSON (chrome://tracing / Perfetto / speedscope)
# ---------------------------------------------------------------------------

_PID = 0  # one process: the simulation host


def chrome_trace(events: Sequence[SpanEvent]) -> dict:
    """Render span events as a Chrome ``trace_event`` JSON object.

    One trace-viewer *thread* (tid) per device, named via ``M``
    metadata events; each span is a complete ``X`` event with
    microsecond timestamps and the span's args (photon count, engine,
    photons/s) attached for inspection in the viewer.
    """
    tids: dict[str, int] = {}
    trace: list[dict] = [{
        "ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
        "args": {"name": "repro photon transport"},
    }]
    span_rows: list[dict] = []
    for ev in sorted(events, key=lambda e: e.t0):
        tid = tids.setdefault(ev.device, len(tids))
        args = dict(ev.args)
        if ev.engine is not None:
            args["engine"] = ev.engine
        pps = ev.photons_per_s
        if pps is not None:
            args["photons_per_s"] = pps
        span_rows.append({
            "ph": "X", "pid": _PID, "tid": tid, "name": ev.name,
            "cat": "dispatch", "ts": ev.t0 * 1e6, "dur": ev.dur * 1e6,
            "args": args,
        })
    for device, tid in tids.items():
        trace.append({"ph": "M", "pid": _PID, "tid": tid,
                      "name": "thread_name", "args": {"name": device}})
    trace.extend(span_rows)
    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def load_chrome_trace(path_or_obj) -> list[SpanEvent]:
    """Parse a Chrome trace JSON back into :class:`SpanEvent` rows.

    Accepts a path or an already-parsed trace dict.  The inverse of
    :func:`chrome_trace` up to float rounding — the round-trip is what
    lets a saved ``--trace-out`` file feed :func:`fit_device_models`
    (and therefore ``loadbalance.fit_pilot``) in a later process.
    """
    if isinstance(path_or_obj, (str, Path)):
        obj = json.loads(Path(path_or_obj).read_text())
    else:
        obj = path_or_obj
    rows = obj.get("traceEvents", obj) if isinstance(obj, dict) else obj
    tid_names: dict[tuple, str] = {}
    for row in rows:
        if row.get("ph") == "M" and row.get("name") == "thread_name":
            tid_names[(row.get("pid"), row.get("tid"))] = \
                row.get("args", {}).get("name", "")
    events = []
    for row in rows:
        if row.get("ph") != "X":
            continue
        args = dict(row.get("args", {}))
        engine = args.pop("engine", None)
        args.pop("photons_per_s", None)  # derived; recomputed on demand
        device = tid_names.get((row.get("pid"), row.get("tid")),
                               str(row.get("tid")))
        events.append(SpanEvent(
            name=row.get("name", ""), device=device,
            t0=float(row.get("ts", 0.0)) / 1e6,
            dur=float(row.get("dur", 0.0)) / 1e6,
            engine=engine, args=args))
    return events


# ---------------------------------------------------------------------------
# measured-throughput samples -> loadbalance device models
# ---------------------------------------------------------------------------

def device_samples(events: Sequence[SpanEvent],
                   name: str | None = None) -> dict[str, list[tuple]]:
    """Group span events into per-device ``(photons, seconds)`` samples.

    ``name`` filters by span name (``None``: every span carrying a
    ``photons`` or ``records`` arg counts).  The samples are exactly the
    pilot measurements ``loadbalance.fit_pilot`` consumes.
    """
    out: dict[str, list[tuple]] = {}
    for ev in events:
        if name is not None and ev.name != name:
            continue
        n = ev.args.get("photons", ev.args.get("records"))
        if n is None or ev.dur <= 0:
            continue
        out.setdefault(ev.device, []).append((float(n), float(ev.dur)))
    return out


def fit_device_models(events_or_trace, name: str | None = None) -> dict:
    """Fit a ``loadbalance.DeviceModel`` per device from span records.

    ``events_or_trace`` is a list of :class:`SpanEvent` (a live
    ``Tracer.events``) or anything :func:`load_chrome_trace` accepts (a
    saved ``--trace-out`` path).  Fitting follows the shared rule in
    ``loadbalance.model_from_samples`` (full ``T = a*n + T0`` fit when
    the samples span >= 2 distinct photon counts, aggregate-throughput
    fallback otherwise).  The result plugs straight into
    ``loadbalance.PARTITIONERS`` / ``heterogeneous_partition``.
    """
    from repro.core.loadbalance import DeviceModel, model_from_samples

    events = events_or_trace
    if not (isinstance(events, (list, tuple)) and
            all(isinstance(e, SpanEvent) for e in events)):
        events = load_chrome_trace(events_or_trace)
    models: dict[str, DeviceModel] = {}
    for device, samples in device_samples(events, name=name).items():
        model = model_from_samples(samples, name=device)
        if model is not None:
            models[device] = model
    return models
