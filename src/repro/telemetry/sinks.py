"""Metrics sinks: structured event consumers (DESIGN.md §observability).

A sink receives flat JSON-serializable event dicts — span records from
the :class:`repro.telemetry.Tracer`, counter samples, run summaries —
and does something durable with them.  Two backends cover the current
consumers:

  * :class:`InMemorySink` — a list, for tests and for feeding measured
    throughput samples straight back into ``loadbalance.fit_pilot``
    (see ``telemetry.fit_device_models``);
  * :class:`JsonlSink` — one JSON object per line, the CLI's
    ``--metrics-out`` backend (greppable, streamable, append-safe).

Sinks are deliberately dumb: no buffering policy beyond per-event
flush, no schema enforcement beyond "dict in, JSON out".  Anything
smarter (aggregation windows, push gateways) composes on top.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Protocol, runtime_checkable


@runtime_checkable
class MetricsSink(Protocol):
    """Anything with an ``emit(event: dict) -> None``."""

    def emit(self, event: dict) -> None:  # pragma: no cover - protocol
        ...


class InMemorySink:
    """Collect events in a list (tests, in-process consumers)."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def __len__(self) -> int:
        return len(self.events)


class JsonlSink:
    """Append events as JSON lines to ``path`` (the CLI's --metrics-out).

    The file is opened lazily on the first event and flushed per line,
    so a crashed campaign keeps every event emitted before the crash.
    """

    def __init__(self, path):
        self.path = Path(path)
        self._fh = None

    def emit(self, event: dict) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("a")
        self._fh.write(json.dumps(event, default=_jsonable) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _jsonable(obj):
    """Fallback encoder: numpy/jax scalars -> Python numbers."""
    if hasattr(obj, "item"):
        return obj.item()
    return str(obj)
