"""Simulation telemetry: round counters, span timeline, metrics sinks.

Three layers (DESIGN.md §observability):

  * :class:`RoundStats` — per-round physics counters accumulated inside
    both engines when ``SimConfig.collect_stats`` is set, returned on
    ``SimResult.stats``;
  * :class:`Tracer` / :func:`chrome_trace` — host-side span timeline of
    chunk/batch dispatches, exportable as Chrome ``trace_event`` JSON;
  * :class:`MetricsSink` backends (:class:`InMemorySink`,
    :class:`JsonlSink`) — structured event consumers, wired to the CLI's
    ``--metrics-out``.

:func:`fit_device_models` closes the feedback loop: a recorded (or
re-loaded) trace becomes per-device ``loadbalance.DeviceModel`` fits.
"""

from repro.telemetry.sinks import InMemorySink, JsonlSink, MetricsSink
from repro.telemetry.stats import RoundStats
from repro.telemetry.trace import (
    SpanEvent,
    Tracer,
    chrome_trace,
    device_label,
    device_samples,
    fit_device_models,
    load_chrome_trace,
)

__all__ = [
    "InMemorySink",
    "JsonlSink",
    "MetricsSink",
    "RoundStats",
    "SpanEvent",
    "Tracer",
    "chrome_trace",
    "device_label",
    "device_samples",
    "fit_device_models",
    "load_chrome_trace",
]
