"""Round-level simulation counters (DESIGN.md §observability).

The fused round loop (DESIGN.md §rounds) trades regeneration/flush
amortization against masked-lane waste, but until now the trade could
only be *inferred* from end-to-end throughput (the K=32 falloff in
BENCH_fused.json was diagnosed by guesswork).  :class:`RoundStats` makes
it measurable: when ``SimConfig.collect_stats`` is set, both round
executors cheaply accumulate per-round counters into a struct carried in
the while-loop state and returned on ``SimResult.stats``.

Every counter is a pure reduction over values the engine already
computes, added *alongside* the physics accumulators — collecting stats
never reorders or perturbs a physics output (asserted bit-exactly in
tests/test_telemetry.py for both engines).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class RoundStats(NamedTuple):
    """Per-run totals of the round-level counters.

    All fields are scalars (jnp on device, numpy after host merges).
    ``lane_occupancy()`` is the headline derived metric: the fraction of
    executed lane-segments that carried a live photon — 1.0 means no
    masked-lane waste, and its falloff with ``steps_per_round`` is the
    measured form of the DESIGN.md §rounds tradeoff.
    """

    rounds: np.ndarray          # () int32 outer while-loop rounds executed
    regen_rounds: np.ndarray    # () int32 rounds whose regeneration path
    #                             actually relaunched >= 1 photon (the
    #                             lax.cond fast path skipped the rest)
    relaunched: np.ndarray      # () int32 photons launched via regeneration
    #                             (== SimResult.n_launched; reconciled in
    #                             tests)
    live_segments: np.ndarray   # () float32 lane-segments entered with a
    #                             live photon (summed over every segment of
    #                             every round)
    lane_segments: np.ndarray   # () float32 lane-segments executed in
    #                             total: rounds * K * n_lanes — the
    #                             occupancy denominator
    deposited_w: np.ndarray     # () float32 weight deposited (Beer-Lambert
    #                             absorption); reconciles with
    #                             sum(SimResult.energy) to fp order
    escaped_w: np.ndarray       # () float32 weight escaping the domain —
    #                             bit-equal to SimResult.escaped_w (same
    #                             accumulation)
    timed_out_w: np.ndarray     # () float32 weight retired by tmax_ns /
    #                             max_steps — bit-equal to
    #                             SimResult.timed_out_w
    detected_w: np.ndarray      # () float32 weight captured by detector
    #                             disks; reconciles with sum(det_w)

    def lane_occupancy(self) -> float:
        """Live-lane fraction of all executed lane-segments, in [0, 1]."""
        denom = float(self.lane_segments)
        return float(self.live_segments) / denom if denom > 0 else 0.0

    def to_dict(self) -> dict:
        """JSON-friendly counters + derived occupancy (metrics sinks)."""
        out = {k: (int(v) if k in _INT_FIELDS else float(v))
               for k, v in zip(self._fields, self)}
        out["lane_occupancy"] = self.lane_occupancy()
        return out

    @classmethod
    def from_vector(cls, values) -> "RoundStats":
        """Rebuild from a numeric vector in field order (checkpoints)."""
        return cls(*(np.int32(v) if f in _INT_FIELDS else np.float32(v)
                     for f, v in zip(cls._fields, values)))

    @classmethod
    def zeros(cls) -> "RoundStats":
        """Host-side numpy zeros (an accumulator for scheduler merges)."""
        return cls(*(np.int32(0) if f in _INT_FIELDS else np.float32(0.0)
                     for f in cls._fields))

    def add(self, other: "RoundStats") -> "RoundStats":
        """Field-wise sum (host-side merge across shards / chunks).

        Totals are additive across disjoint photon subsets by
        construction; ``lane_occupancy`` of the merged struct is the
        work-weighted mean of the parts.
        """
        return RoundStats(*(np.asarray(a) + np.asarray(b)
                            for a, b in zip(self, other)))


_INT_FIELDS = ("rounds", "regen_rounds", "relaunched")
